"""mixtral-8x7b: MoE 8 experts top-2 with sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, window 4096.
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    tie_embeddings=False,
))
