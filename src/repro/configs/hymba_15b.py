"""hymba-1.5b: parallel attention + mamba heads per layer (hybrid).

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Attention branch uses SWA (the published model
keeps 3 global layers; we use SWA throughout — DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    sliding_window=2048,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2),
))
