"""mamba2-2.7b: attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  64L d_model=2560 d_ff=0 vocab=50280,
ssm_state=128.  The paper's VDPE re-aggregation applies to the in/out
projections only; the SSD scan itself is not a plain GEMM
(DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,               # no MLP: the mamba block is the whole layer
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
))
