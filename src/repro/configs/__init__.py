"""Architecture configs: one module per assigned architecture."""
from .base import (ModelConfig, MoEConfig, SSMConfig, REGISTRY,  # noqa: F401
                   get_config, load_all, register)
from .shapes import SHAPES, ShapeConfig, applicable_shapes  # noqa: F401
