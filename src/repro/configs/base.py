"""Model configuration system: one ModelConfig per assigned architecture.

Every architecture from the assignment is a ``ModelConfig`` registered in
``REGISTRY`` (one module per arch in this package defines and registers it).
``reduced()`` produces the CPU-smoke-test variant of any config; the full
configs are only ever lowered via launch/dryrun.py (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    expand: int = 2
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the unified transformer/SSM stack."""
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # attention behaviour
    sliding_window: Optional[int] = None        # SWA width (mixtral, hymba)
    local_global_period: Optional[int] = None   # gemma2: every 2nd layer global
    attn_logit_softcap: Optional[float] = None  # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    qkv_bias: bool = False                      # qwen1.5
    mlp_act: str = "silu"                       # silu (swiglu) | gelu
    mlp_gated: bool = True                      # GLU (3 mats) vs plain FFN (2)
    rope_theta: float = 10000.0
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # state space
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (seamless)
    n_encoder_layers: int = 0
    # multimodal stub: prefix embeddings provided by input_specs
    prefix_len: int = 0              # patch/frame embedding positions
    # training
    tie_embeddings: bool = True
    scan_unroll: bool = False        # unroll the layer scan (cost audits)
    attn_scores_dtype: str = "float32"   # "bfloat16" halves score traffic
    ssm_intra_dtype: str = "float32"     # SSD intra-chunk tensor dtype
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"  # "int8" = quantized Adam moments
    wsd_schedule: bool = False        # minicpm warmup-stable-decay
    # notes for DESIGN.md arch-applicability
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to a multiple of 256 for clean model-axis sharding."""
        return (self.vocab + 255) // 256 * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode memory: SSM/hybrid/SWA/local-global archs."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.local_global_period is not None)

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + stacked blocks)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
        mlp = (3 if self.mlp_gated else 2) * d * ff
        if self.moe:
            mlp = mlp * self.moe.n_experts + d * self.moe.n_experts
        ssm = 0
        if self.ssm:
            d_in = self.ssm.expand * d
            n_h = d_in // self.ssm.head_dim
            # in_proj (z,x,B,C,dt) + out_proj + A,D + conv
            d_bc = 2 * self.ssm.n_groups * self.ssm.d_state
            ssm = d * (2 * d_in + d_bc + n_h) + d_in * d + 2 * n_h \
                + self.ssm.conv_width * (d_in + d_bc)
        per_layer = mlp + 2 * d
        if self.family == "ssm":
            per_layer += ssm
        elif self.family == "hybrid":
            per_layer += attn + ssm
        else:
            per_layer += attn
        total = self.n_layers * per_layer + self.vocab_padded * d
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (attn + mlp + 2 * d)
            total += enc + self.n_layers * attn      # cross-attention
        if not self.tie_embeddings:
            total += self.vocab_padded * d
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_mlp = (3 if self.mlp_gated else 2) * d * ff
        inactive = self.n_layers * dense_mlp * (self.moe.n_experts
                                                - self.moe.top_k)
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            prefix_len=8 if self.prefix_len else 0,
            sliding_window=16 if self.sliding_window else None,
            moe=dataclasses.replace(self.moe, n_experts=4) if self.moe else None,
            ssm=dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                    chunk=16) if self.ssm else None,
        )


REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if not REGISTRY:
        load_all()
    return REGISTRY[arch_id]


def load_all() -> Dict[str, ModelConfig]:
    """Import every per-arch module so it registers itself."""
    from . import (seamless_m4t_large_v2, gemma2_2b, minicpm_2b,  # noqa: F401
                   deepseek_67b, qwen15_05b, grok1_314b, mixtral_8x7b,
                   hymba_15b, mamba2_27b, llava_next_34b)
    return REGISTRY
