"""grok-1-314b: MoE 8 experts top-2.

[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072.  Uses int8-quantized Adam moments so the train_4k cell fits
the single-pod memory budget (DESIGN.md §5).
"""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2),
    tie_embeddings=False,
    opt_state_dtype="int8",
))
