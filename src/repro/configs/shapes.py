"""Assigned input shapes and per-arch applicability rules."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """All assigned shapes, minus long_500k for pure full-attention archs.

    ``long_500k`` decodes one token against a 524288-token context; that is
    only run for sub-quadratic-memory architectures (SSM, hybrid, SWA,
    local/global alternating) per the assignment.  Every assigned arch here
    is a decoder (seamless is enc-dec), so decode shapes always apply.
    """
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(s)
    return out
