"""The paper's own workloads as selectable configs.

The four evaluated CNNs (plus the two extras referenced in Sections I-II)
are exposed with the same ``--arch`` selection convention as the LM pool;
they run through the photonic accelerator pipeline (cycle-true simulator +
decomposed-VDP numerics) rather than the LM training stack.

    from repro.configs.paper_cnns import CNN_CONFIGS, evaluate_cnn
    evaluate_cnn("efficientnet_b7", accelerator="RMAM", br_gbps=1.0)
"""
from __future__ import annotations

from typing import Dict

from ..cnn.models import MODEL_ZOO, PAPER_CNNS
from ..core import simulator as sim
from ..core import tpc

#: arch-id -> layer-table builder (paper CNNs first, extras after).
CNN_CONFIGS: Dict[str, object] = {name: MODEL_ZOO[name]
                                  for name in MODEL_ZOO}


def evaluate_cnn(arch: str, accelerator: str = "RMAM",
                 br_gbps: float = 1.0, batch: int = 1) -> sim.InferenceReport:
    """Cycle-true FPS / FPS/W for one CNN on one accelerator variant."""
    layers = CNN_CONFIGS[arch]()
    acc = tpc.build_accelerator(accelerator, br_gbps)
    return sim.simulate(acc, layers, batch=batch)


__all__ = ["CNN_CONFIGS", "PAPER_CNNS", "evaluate_cnn"]
