"""seamless-m4t-large-v2: enc-dec multimodal (audio frontend stub).

[arXiv:2308.11596; hf]  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  The speech frontend is a STUB: input_specs() provides
precomputed frame embeddings for the encoder.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                 # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    mlp_act="gelu",
    mlp_gated=False,             # classic transformer FFN
    tie_embeddings=True,
    notes=("Paper technique applies to the frontend's depthwise-separable "
           "conv stack (stubbed) and to mixed enc/dec GEMM sizes."),
))
