"""llava-next-34b: VLM — transformer backbone with anyres patch stub.

[hf:llava-hf/llava-v1.6; unverified]  60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000.  The vision tower is a STUB: input_specs() provides
precomputed anyres patch embeddings as a prefix.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    prefix_len=2880,       # anyres: base 576 + 4 tiles x 576
    tie_embeddings=False,
))
