"""Sharding-aware atomic checkpointing with async save + auto-resume.

Layout:  <dir>/step_<N>/ {meta.json, shard_<proc>.npz}
* Each process writes only its addressable shards (scales to any host
  count; no cross-host gather).
* Atomicity: writes land in step_<N>.tmp_<uuid>/ and are renamed into
  place only after every file is fsync'd — a crash mid-save never corrupts
  the latest checkpoint (restart auto-resumes from the newest complete dir).
* Async: the serialize+write runs on a background thread; the train loop
  only blocks if a previous save is still in flight (bounded staleness 1).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":      # ml_dtypes (bf16, fp8, ...)
            arr = arr.astype(np.float32)       # lossless superset for bf16
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    process_index: Optional[int] = None) -> str:
    proc = jax.process_index() if process_index is None else process_index
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp_{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"shard_{proc}.npz"), **flat)
    meta = {"step": step, "n_leaves": len(flat),
            "keys": sorted(flat.keys())}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, "meta.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       process_index: Optional[int] = None) -> Any:
    """Restore into the structure (and shardings) of ``like``."""
    proc = jax.process_index() if process_index is None else process_index
    path = os.path.join(directory, f"step_{step:08d}", f"shard_{proc}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    import jax.numpy as jnp
    out = []
    for p, leaf in leaves:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            out.append(jax.device_put(jnp.asarray(arr).astype(leaf.dtype)))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


class CheckpointManager:
    """Async save + auto-resume + retention."""

    def __init__(self, directory: str, keep: int = 3,
                 save_every: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree: Any, blocking: bool = False):
        if step % self.save_every:
            return
        self.wait()                      # bounded staleness of one save
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def work():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def resume(self, like: Any) -> Tuple[Optional[int], Any]:
        step = latest_step(self.directory)
        if step is None:
            return None, like
        return step, restore_checkpoint(self.directory, step, like)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
