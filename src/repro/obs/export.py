"""Chrome trace-event export with a dual wall-clock / hardware-clock view.

``chrome_trace`` turns a tracer's :class:`~repro.obs.tracer.SpanRecord`
stream into the Chrome trace-event JSON object format, loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  The trace
carries **two processes**:

* pid 1 — *host (wall clock)*: spans as they actually ran on the host,
  one track per thread, request lifetimes as async begin/end pairs,
  fault/shed/probe instants.
* pid 2 — *photonic hardware (modeled)*: every span that was annotated
  with ``span.hw(instance, seconds)`` is mirrored as a complete event of
  the *modeled* duration from ``core/simulator``, one track per fleet
  instance.  Events on a track are laid end-to-end behind a per-instance
  occupancy cursor (an event starts at the later of its wall start and
  the instance's cursor), so each track reads as cycle-true device
  occupancy: gaps are host overhead, back-to-back blocks are the device
  saturated.

Timestamps are microseconds relative to the earliest event, per the
trace-event spec.  ``tid`` strings are mapped to small integers with
``thread_name`` metadata so strict importers are happy.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from .tracer import SpanRecord

PID_HOST = 1
PID_HW = 2

HOST_PROCESS_NAME = "host (wall clock)"
HW_PROCESS_NAME = "photonic hardware (modeled)"

_VALID_PHASES = frozenset("XibeM")


class _TidMap:
    """First-seen-order mapping of track names to integer tids."""

    def __init__(self) -> None:
        self._ids: Dict[str, int] = {}

    def __call__(self, name: str) -> int:
        tid = self._ids.get(name)
        if tid is None:
            tid = self._ids[name] = len(self._ids) + 1
        return tid

    def items(self):
        return self._ids.items()


def chrome_trace(records: Sequence[SpanRecord]) -> Dict:
    """Render records as a Chrome trace-event JSON document (dict)."""
    events: List[Dict] = []
    if records:
        t_base = min(r.t0 for r in records)
        host_tids = _TidMap()
        hw_tids = _TidMap()
        hw_cursor: Dict[str, float] = {}
        for r in sorted(records, key=lambda r: r.t0):
            ts_us = (r.t0 - t_base) * 1e6
            ev: Dict = {"name": r.name, "cat": r.cat, "ph": r.ph,
                        "pid": PID_HOST, "tid": host_tids(r.tid),
                        "ts": round(ts_us, 3), "args": dict(r.args)}
            if r.ph == "X":
                ev["dur"] = round(r.dur * 1e6, 3)
            elif r.ph == "i":
                ev["s"] = "t"
            elif r.ph in ("b", "e"):
                ev["id"] = r.aid
            events.append(ev)
            if r.ph == "X" and r.hw_instance is not None and r.hw_s:
                # hardware clock: pack onto the instance's occupancy track
                cursor = hw_cursor.get(r.hw_instance, 0.0)
                start = max(ts_us, cursor)
                dur_us = r.hw_s * 1e6
                hw_cursor[r.hw_instance] = start + dur_us
                events.append({
                    "name": r.name, "cat": "hw." + r.cat, "ph": "X",
                    "pid": PID_HW, "tid": hw_tids(r.hw_instance),
                    "ts": round(start, 3), "dur": round(dur_us, 3),
                    "args": dict(r.args, modeled_s=r.hw_s,
                                 instance=r.hw_instance)})
        meta: List[Dict] = [
            {"name": "process_name", "ph": "M", "pid": PID_HOST, "tid": 0,
             "args": {"name": HOST_PROCESS_NAME}},
            {"name": "process_name", "ph": "M", "pid": PID_HW, "tid": 0,
             "args": {"name": HW_PROCESS_NAME}},
        ]
        for name, tid in host_tids.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": PID_HOST,
                         "tid": tid, "args": {"name": name}})
        for name, tid in hw_tids.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": PID_HW,
                         "tid": tid, "args": {"name": name}})
        events = meta + events
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(doc: Dict, require_dual_clock: bool = False) -> int:
    """Check a trace document against the event schema Perfetto expects.

    Raises ``ValueError`` on the first violation; returns the number of
    events otherwise.  With ``require_dual_clock=True`` the trace must
    carry non-metadata events on both the host and hardware processes.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace must be an object with a traceEvents list")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    pids_seen = set()
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: event must be an object")
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            raise ValueError(f"{where}: bad phase {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"{where}: missing/empty name")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                raise ValueError(f"{where}: {field} must be an int")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"{where}: args must be an object")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"{where}: ts must be a number >= 0")
        if not isinstance(ev.get("cat"), str):
            raise ValueError(f"{where}: missing cat")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: dur must be a number >= 0")
        if ph in ("b", "e") and ev.get("id") is None:
            raise ValueError(f"{where}: async event needs an id")
        pids_seen.add(ev["pid"])
    if require_dual_clock and not {PID_HOST, PID_HW} <= pids_seen:
        raise ValueError(
            f"dual-clock trace needs events on pids {PID_HOST} and "
            f"{PID_HW}, saw {sorted(pids_seen)}")
    return len(events)


def hw_occupancy(doc: Dict) -> Dict[str, float]:
    """Total modeled busy seconds per hardware-track instance."""
    busy: Dict[str, float] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("pid") == PID_HW and ev.get("ph") == "X":
            inst = ev.get("args", {}).get("instance", f"tid{ev['tid']}")
            busy[inst] = busy.get(inst, 0.0) + ev.get("dur", 0.0) / 1e6
    return dict(sorted(busy.items()))


def write_trace(path, records_or_doc,
                indent: Optional[int] = None) -> Dict:
    """Serialize records (or a prebuilt document) to a trace JSON file."""
    if isinstance(records_or_doc, dict):
        doc = records_or_doc
    else:
        doc = chrome_trace(tuple(records_or_doc))
    with open(path, "w") as f:
        json.dump(doc, f, indent=indent, sort_keys=False)
        f.write("\n")
    return doc


def load_trace(path) -> Dict:
    with open(path) as f:
        return json.load(f)


def event_census(doc: Dict) -> Dict[str, int]:
    """Event counts per category (metadata events under ``"M"``)."""
    out: Dict[str, int] = {}
    for ev in doc.get("traceEvents", []):
        key = "M" if ev.get("ph") == "M" else ev.get("cat", "?")
        out[key] = out.get(key, 0) + 1
    return dict(sorted(out.items()))
