"""Streaming metrics: log-bucketed histograms, counters, gauges, Prometheus.

The serving telemetry used to keep every request latency in an unbounded
Python list — fine for a 96-request bench, fatal for a fleet serving
millions of requests.  ``LogHistogram`` replaces those lists with
log-bucketed streaming histograms:

* **bounded memory** — bucket indices are ``floor(log(v) / log(growth))``
  clamped to [``min_value``, ``max_value``], so the sparse bucket dict can
  never exceed a few hundred entries no matter how many samples stream
  through;
* **bounded error** — a percentile query returns the geometric midpoint of
  the bucket holding the exact rank, so p50/p99 land within one bucket
  (a ``growth``-factor relative band) of the exact value;
* **mergeable** — two histograms with the same geometry add bucket-wise,
  so per-shard or per-instance histograms roll up losslessly.

``Counter`` / ``Gauge`` / ``MetricsRegistry`` are the matching scrape
surface: the registry renders the Prometheus text exposition format
(counters/gauges as samples, histograms as cumulative ``_bucket``/
``_sum``/``_count`` series) and JSON snapshots that round-trip through
``MetricsRegistry.from_snapshot`` — which is how ``scripts/obs_report.py``
re-renders a finished run's metrics offline.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

#: default bucket growth factor: ~7% wide buckets, so any percentile is
#: reported within a ±7% band of exact (one bucket)
DEFAULT_GROWTH = 1.07


class LogHistogram:
    """Log-bucketed streaming histogram with bounded memory.

    Values are assigned to bucket ``floor(log(v)/log(growth))``; values at
    or below ``min_value`` share one underflow bucket, values above
    ``max_value`` share one overflow bucket, so the index range — and the
    sparse bucket dict — is bounded regardless of the stream length.
    Exact ``count``/``sum``/``min``/``max`` ride along for free.
    """

    __slots__ = ("growth", "min_value", "max_value", "_log_g", "_idx_lo",
                 "_idx_hi", "buckets", "count", "total", "vmin", "vmax",
                 "_lock")

    def __init__(self, growth: float = DEFAULT_GROWTH,
                 min_value: float = 1e-9, max_value: float = 1e9):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if not 0 < min_value < max_value:
            raise ValueError(
                f"need 0 < min_value < max_value, got "
                f"{min_value}, {max_value}")
        self.growth = growth
        self.min_value = min_value
        self.max_value = max_value
        self._log_g = math.log(growth)
        self._idx_lo = self._raw_index(min_value)
        self._idx_hi = self._raw_index(max_value)
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._lock = threading.Lock()

    def _raw_index(self, v: float) -> int:
        return int(math.floor(math.log(v) / self._log_g))

    def index(self, v: float) -> int:
        """Clamped bucket index of a value (underflow/overflow inclusive)."""
        if v <= self.min_value:
            return self._idx_lo
        if v >= self.max_value:
            return self._idx_hi
        return self._raw_index(v)

    def record(self, v: float) -> None:
        v = float(v)
        idx = self.index(v)
        with self._lock:
            self.buckets[idx] = self.buckets.get(idx, 0) + 1
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    def bucket_upper(self, idx: int) -> float:
        """Upper edge of a bucket (Prometheus ``le`` bound)."""
        if idx >= self._idx_hi:
            return math.inf
        return self.growth ** (idx + 1)

    def _representative(self, idx: int) -> float:
        """Geometric midpoint of a bucket, clamped to the observed range."""
        if idx <= self._idx_lo:
            rep = self.min_value
        elif idx >= self._idx_hi:
            rep = self.max_value
        else:
            rep = self.growth ** (idx + 0.5)
        return min(max(rep, self.vmin), self.vmax)

    def percentile(self, q: float) -> float:
        """The q-th percentile, within one bucket of the exact value."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        with self._lock:
            if self.count == 0:
                raise ValueError("percentile of an empty histogram")
            target = max(1, math.ceil(q / 100.0 * self.count))
            cum = 0
            for idx in sorted(self.buckets):
                cum += self.buckets[idx]
                if cum >= target:
                    return self._representative(idx)
            return self.vmax       # unreachable, kept for safety

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Add another histogram's buckets into this one (same geometry)."""
        if (other.growth != self.growth
                or other.min_value != self.min_value
                or other.max_value != self.max_value):
            raise ValueError("cannot merge histograms of different geometry")
        with self._lock:
            for idx, n in other.buckets.items():
                self.buckets[idx] = self.buckets.get(idx, 0) + n
            self.count += other.count
            self.total += other.total
            self.vmin = min(self.vmin, other.vmin)
            self.vmax = max(self.vmax, other.vmax)
        return self

    def clear(self) -> None:
        with self._lock:
            self.buckets.clear()
            self.count = 0
            self.total = 0.0
            self.vmin = math.inf
            self.vmax = -math.inf

    def to_dict(self) -> Dict:
        return {"growth": self.growth, "min_value": self.min_value,
                "max_value": self.max_value, "count": self.count,
                "sum": self.total,
                "min": None if self.count == 0 else self.vmin,
                "max": None if self.count == 0 else self.vmax,
                "buckets": {str(i): n for i, n in sorted(self.buckets.items())}}

    @classmethod
    def from_dict(cls, doc: Dict) -> "LogHistogram":
        h = cls(growth=doc["growth"], min_value=doc["min_value"],
                max_value=doc["max_value"])
        h.buckets = {int(i): int(n) for i, n in doc["buckets"].items()}
        h.count = int(doc["count"])
        h.total = float(doc["sum"])
        if doc.get("min") is not None:
            h.vmin = float(doc["min"])
        if doc.get("max") is not None:
            h.vmax = float(doc["max"])
        return h


class Counter:
    """Monotonic counter (Prometheus ``counter`` type)."""

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up, got inc({n})")
        with self._lock:
            self.value += n

    def clear(self) -> None:
        with self._lock:
            self.value = 0.0


class Gauge:
    """Set-to-current-value metric (Prometheus ``gauge`` type)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def clear(self) -> None:
        self.value = 0.0


LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, str]) -> LabelsKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelsKey, extra: Optional[Tuple[str, str]] = None,
                   ) -> str:
    pairs = list(key) + ([extra] if extra else [])
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


class MetricsRegistry:
    """Named, labeled metric families with Prometheus + JSON export.

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    fixes the family's type (re-declaring a name with another type is a
    ``ValueError``), later calls with the same (name, labels) return the
    existing series — callers hold no references, the registry is the
    single source of truth the scrape renders.
    """

    def __init__(self) -> None:
        self._types: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._series: Dict[str, Dict[LabelsKey, object]] = {}
        self._lock = threading.Lock()

    def _family(self, name: str, kind: str, help: str):
        seen = self._types.get(name)
        if seen is None:
            self._types[name] = kind
            self._help[name] = help
            self._series[name] = {}
        elif seen != kind:
            raise ValueError(
                f"metric {name!r} already registered as {seen}, not {kind}")
        elif help and not self._help[name]:
            self._help[name] = help
        return self._series[name]

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        with self._lock:
            fam = self._family(name, "counter", help)
            return fam.setdefault(_labels_key(labels), Counter())

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        with self._lock:
            fam = self._family(name, "gauge", help)
            return fam.setdefault(_labels_key(labels), Gauge())

    def histogram(self, name: str, help: str = "",
                  growth: float = DEFAULT_GROWTH,
                  **labels: str) -> LogHistogram:
        with self._lock:
            fam = self._family(name, "histogram", help)
            return fam.setdefault(_labels_key(labels),
                                  LogHistogram(growth=growth))

    def reset(self) -> None:
        """Zero every series (families and label sets stay registered)."""
        with self._lock:
            for fam in self._series.values():
                for metric in fam.values():
                    metric.clear()

    # -- export -----------------------------------------------------------

    def prometheus_text(self) -> str:
        """The Prometheus text exposition format of every family."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._types):
                kind = self._types[name]
                if self._help.get(name):
                    lines.append(f"# HELP {name} {self._help[name]}")
                lines.append(f"# TYPE {name} {kind}")
                for key in sorted(self._series[name]):
                    metric = self._series[name][key]
                    if kind in ("counter", "gauge"):
                        lines.append(
                            f"{name}{_render_labels(key)} {metric.value:g}")
                        continue
                    # histogram: cumulative le buckets + _sum/_count
                    cum = 0
                    for idx in sorted(metric.buckets):
                        cum += metric.buckets[idx]
                        le = metric.bucket_upper(idx)
                        le_s = "+Inf" if math.isinf(le) else f"{le:.6g}"
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels(key, ('le', le_s))} {cum}")
                    lines.append(f"{name}_bucket"
                                 f"{_render_labels(key, ('le', '+Inf'))} "
                                 f"{metric.count}")
                    lines.append(
                        f"{name}_sum{_render_labels(key)} {metric.total:g}")
                    lines.append(
                        f"{name}_count{_render_labels(key)} {metric.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict:
        """JSON-able snapshot that round-trips via ``from_snapshot``."""
        out: Dict = {}
        with self._lock:
            for name in sorted(self._types):
                kind = self._types[name]
                series = []
                for key in sorted(self._series[name]):
                    metric = self._series[name][key]
                    row: Dict = {"labels": dict(key)}
                    if kind == "histogram":
                        row["hist"] = metric.to_dict()
                    else:
                        row["value"] = metric.value
                    series.append(row)
                out[name] = {"type": kind, "help": self._help.get(name, ""),
                             "series": series}
        return out

    @classmethod
    def from_snapshot(cls, doc: Dict) -> "MetricsRegistry":
        reg = cls()
        for name, fam in doc.items():
            kind, help = fam["type"], fam.get("help", "")
            for row in fam["series"]:
                labels = row.get("labels", {})
                if kind == "counter":
                    reg.counter(name, help, **labels).inc(row["value"])
                elif kind == "gauge":
                    reg.gauge(name, help, **labels).set(row["value"])
                else:
                    h = LogHistogram.from_dict(row["hist"])
                    with reg._lock:
                        reg._family(name, "histogram", help)[
                            _labels_key(labels)] = h
        return reg
