"""Per-layer hardware attribution for served batches.

The paper's headline claims are utilization claims — reconfigurability
wins because it keeps VDPE/comb-switch hardware busy across mixed-sized
tensors — so batch-level FPS aggregates are not enough: we need to know
*which layer* the modeled time, energy, and utilization went to, under
*which operating point* of the Viterbi plan, and how many reconfiguration
switches the plan pays.

``LayerAttribution`` accumulates :class:`repro.core.simulator.LayerCost`
rows (an exact per-frame decomposition of the simulator's report) across
every served batch, keyed by model and layer.  Because the rows sum to
the report's ``frame_latency_s``/``energy_per_frame_j`` by construction,
``coverage`` — attributed over total modeled time — is 1.0 up to float
rounding, comfortably clearing the >= 95% acceptance bar and leaving the
metric in place to catch future instrumentation drift.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass
class LayerStat:
    """Accumulated cost of one named layer across all served frames."""

    kind: str
    time_s: float = 0.0          # total modeled seconds
    energy_j: float = 0.0        # total modeled joules
    div_samples: float = 0.0
    util_time_s: float = 0.0     # utilization weighted by modeled time
    frames: int = 0
    #: ledger row -> total joules (tpc.LEDGER_COMPONENTS; cells sum to
    #: ``energy_j`` because each LayerCost row's do)
    components: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def utilization(self) -> float:
        """Time-weighted mean MRR utilization of this layer."""
        return self.util_time_s / self.time_s if self.time_s else 0.0

    def as_dict(self) -> Dict:
        return {"kind": self.kind, "time_s": self.time_s,
                "energy_j": self.energy_j,
                "div_samples": self.div_samples,
                "utilization": self.utilization, "frames": self.frames,
                "energy_components_j": dict(self.components)}


@dataclasses.dataclass
class _ModelAttribution:
    point: str
    frames: int = 0
    total_time_s: float = 0.0       # frames x frame_latency from the report
    attributed_time_s: float = 0.0  # sum of per-layer rows
    reconfig_switches: int = 0      # switches in the model's Viterbi plan
    operating_points: Dict[str, str] = dataclasses.field(default_factory=dict)
    layers: Dict[str, LayerStat] = dataclasses.field(default_factory=dict)


class LayerAttribution:
    """Accrues per-layer hardware cost for every served batch."""

    def __init__(self) -> None:
        self._models: Dict[str, _ModelAttribution] = {}

    def record(self, model: str, point: str, rows: Sequence,
               frames: int, frame_latency_s: float,
               op_points: Optional[Dict[str, str]] = None,
               reconfig_switches: int = 0) -> None:
        """Accrue one batch: ``rows`` are per-frame ``LayerCost`` entries,
        scaled here by ``frames``; ``frame_latency_s`` is the report's own
        total, kept separate so ``coverage`` is a real check."""
        m = self._models.get(model)
        if m is None:
            m = self._models[model] = _ModelAttribution(point=point)
        m.frames += frames
        m.total_time_s += frames * frame_latency_s
        # plan facts (switch count, per-layer points) are properties of
        # the model's plan, not of a batch: a batch recorded without them
        # must not clobber what an earlier batch established
        if reconfig_switches:
            m.reconfig_switches = reconfig_switches
        if op_points:
            m.operating_points = dict(op_points)
        for row in rows:
            stat = m.layers.get(row.name)
            if stat is None:
                stat = m.layers[row.name] = LayerStat(kind=row.kind)
            t = row.time_s * frames
            stat.time_s += t
            stat.energy_j += row.energy_j * frames
            stat.div_samples += row.div_samples * frames
            stat.util_time_s += row.utilization * t
            stat.frames += frames
            for c, j in getattr(row, "components", {}).items():
                stat.components[c] = stat.components.get(c, 0.0) + j * frames
            m.attributed_time_s += t

    def coverage(self, model: str) -> float:
        """Fraction of the model's modeled time attributed to named
        layers (1.0 up to float rounding, by construction)."""
        m = self._models[model]
        return m.attributed_time_s / m.total_time_s if m.total_time_s else 0.0

    def top_hotspots(self, model: str, k: int = 5) -> List[Dict]:
        """The k layers with the largest share of modeled time."""
        m = self._models[model]
        total = m.attributed_time_s or 1.0
        ranked = sorted(m.layers.items(), key=lambda kv: -kv[1].time_s)
        return [dict(layer=name, share=stat.time_s / total,
                     point=m.operating_points.get(name, m.point),
                     **stat.as_dict())
                for name, stat in ranked[:k]]

    def models(self) -> List[str]:
        return sorted(self._models)

    def summary(self, top_k: int = 5) -> Dict:
        """The ``summary()["layers"]`` payload: per-model layer table,
        coverage, operating points, and top-k hotspots."""
        out: Dict = {}
        for model in self.models():
            m = self._models[model]
            comps: Dict[str, float] = {}
            for stat in m.layers.values():
                for c, j in stat.components.items():
                    comps[c] = comps.get(c, 0.0) + j
            out[model] = {
                "point": m.point,
                "frames": m.frames,
                "coverage": self.coverage(model),
                "total_time_s": m.total_time_s,
                "attributed_time_s": m.attributed_time_s,
                "energy_components_j": comps,
                "reconfig_switches": m.reconfig_switches,
                "operating_points": dict(m.operating_points),
                "by_layer": {name: stat.as_dict()
                             for name, stat in sorted(m.layers.items())},
                "top": self.top_hotspots(model, top_k),
            }
        return out

    def reset(self) -> None:
        self._models.clear()
