"""Observability: hardware-time tracing, attribution, streaming metrics.

The instrumentation spine of the serving stack, in four pieces:

* :mod:`~repro.obs.tracer` — request/batch/shard spans in a bounded ring
  buffer with per-category sampling and a free no-op path when disabled;
* :mod:`~repro.obs.export` — Chrome trace-event JSON (Perfetto-loadable)
  on two clocks: host wall time and modeled photonic hardware time;
* :mod:`~repro.obs.attribution` — per-layer modeled time/energy/
  utilization accounting for every served batch, with operating points
  and reconfiguration switches from the Viterbi plan;
* :mod:`~repro.obs.metrics` — log-bucketed streaming histograms,
  counters, gauges; Prometheus text and JSON snapshot export.

Pure standard library + the repo's own simulator reports: importable
anywhere without pulling in jax.
"""
from .attribution import LayerAttribution, LayerStat
from .export import (HW_PROCESS_NAME, PID_HOST, PID_HW, chrome_trace,
                     event_census, hw_occupancy, load_trace,
                     validate_chrome_trace, write_trace)
from .metrics import (DEFAULT_GROWTH, Counter, Gauge, LogHistogram,
                      MetricsRegistry)
from .tracer import (NOOP_TRACER, NoopTracer, SpanRecord, Tracer,
                     category_census)

__all__ = [
    "LayerAttribution", "LayerStat",
    "HW_PROCESS_NAME", "PID_HOST", "PID_HW", "chrome_trace",
    "event_census", "hw_occupancy", "load_trace", "validate_chrome_trace",
    "write_trace",
    "DEFAULT_GROWTH", "Counter", "Gauge", "LogHistogram", "MetricsRegistry",
    "NOOP_TRACER", "NoopTracer", "SpanRecord", "Tracer", "category_census",
]
