"""Bounded ring-buffer span tracer for the serving stack.

A request's life — submit, admission, queueing, batch formation, dispatch,
per-shard execution/retry/probe, epilogue — becomes a tree of spans with
structured attributes.  Design constraints, in order:

1. **Disabled must be free.**  ``NOOP_TRACER`` is a stateless singleton
   whose ``span``/``instant`` return a shared do-nothing context manager;
   the hot path when tracing is off is one attribute load and one call
   that does nothing.  The serving stack defaults to it.
2. **Enabled must be bounded.**  Finished spans land in a
   ``deque(maxlen=capacity)`` ring — oldest spans fall off, memory never
   grows with trace length.  Per-category sampling (``sample={"shard":
   0.25}``) deterministically keeps every ``round(1/rate)``-th span of a
   category, so repeated runs trace the same spans.
3. **Dual clocks.**  A span records host wall time (``time_fn``, default
   ``time.perf_counter``); calling ``span.hw(instance, seconds)`` attaches
   the *modeled photonic hardware* duration from ``core/simulator``, which
   :mod:`repro.obs.export` lays out on a second Perfetto process so host
   overhead and cycle-true device occupancy sit side by side.

Span nesting is tracked per thread: a span opened inside another becomes
its child (``parent_id``); worker-thread spans are roots on their own
track (``tid`` defaults to the thread name).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished trace event.

    ``ph`` follows the Chrome trace-event phase alphabet used by the
    exporter: ``"X"`` complete span, ``"i"`` instant, ``"b"``/``"e"``
    async begin/end (paired by ``aid``).  ``hw_instance``/``hw_s``, when
    set, place a mirror event of ``hw_s`` modeled seconds on that
    instance's hardware-clock track.
    """

    name: str
    cat: str
    ph: str
    t0: float
    dur: float
    tid: str
    span_id: int
    parent_id: Optional[int]
    args: Dict[str, Any]
    aid: Optional[int] = None
    hw_instance: Optional[str] = None
    hw_s: Optional[float] = None


class _NoopSpan:
    """Shared, stateless stand-in for a span when tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args: Any) -> None:
        pass

    def hw(self, instance: str, seconds: float) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class NoopTracer:
    """The disabled path: every operation is a constant-time no-op."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, cat: str = "batch",
             tid: Optional[str] = None, **args: Any) -> _NoopSpan:
        return _NOOP_SPAN

    def instant(self, name: str, cat: str = "event",
                tid: Optional[str] = None, **args: Any) -> None:
        pass

    def async_begin(self, name: str, aid: int, cat: str = "request",
                    tid: Optional[str] = None, **args: Any) -> None:
        pass

    def async_end(self, name: str, aid: int, cat: str = "request",
                  tid: Optional[str] = None, **args: Any) -> None:
        pass

    def events(self) -> Tuple[SpanRecord, ...]:
        return ()

    def clear(self) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        return {"enabled": False, "emitted": 0, "retained": 0,
                "dropped_ring": 0, "sampled_out": 0}


#: module-level singleton; ``tracer or NOOP_TRACER`` is the idiom
NOOP_TRACER = NoopTracer()


class _Span:
    """Live span handle produced by :meth:`Tracer.span` (context manager)."""

    __slots__ = ("_tr", "name", "cat", "tid", "args", "t0", "span_id",
                 "parent_id", "hw_instance", "hw_s", "_sampled")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 tid: Optional[str], args: Dict[str, Any], sampled: bool):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self.t0 = 0.0
        self.span_id = 0
        self.parent_id: Optional[int] = None
        self.hw_instance: Optional[str] = None
        self.hw_s: Optional[float] = None
        self._sampled = sampled

    def set(self, **args: Any) -> None:
        """Attach/overwrite structured attributes on the open span."""
        self.args.update(args)

    def hw(self, instance: str, seconds: float) -> None:
        """Mirror this span as ``seconds`` of modeled hardware time."""
        self.hw_instance = instance
        self.hw_s = float(seconds)

    def __enter__(self) -> "_Span":
        tr = self._tr
        self.span_id = next(tr._ids)
        if self._sampled:
            stack = tr._stack()
            self.parent_id = stack[-1] if stack else None
            stack.append(self.span_id)
        self.t0 = tr._time()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tr
        dur = tr._time() - self.t0
        if not self._sampled:
            return False
        stack = tr._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        if self.tid is None:
            self.tid = threading.current_thread().name
        tr._emit(SpanRecord(
            name=self.name, cat=self.cat, ph="X", t0=self.t0, dur=dur,
            tid=self.tid, span_id=self.span_id, parent_id=self.parent_id,
            args=self.args, hw_instance=self.hw_instance, hw_s=self.hw_s))
        return False


class Tracer:
    """Span recorder with a bounded ring and per-category sampling.

    Parameters
    ----------
    capacity:
        Ring size; the newest ``capacity`` finished events are retained.
    sample:
        Optional ``{category: keep_rate}`` map (rate in (0, 1]); a
        category keeps every ``round(1/rate)``-th span, deterministically.
        Unlisted categories are always kept.
    time_fn:
        Host clock (monotonic seconds).  Injectable for tests.
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 sample: Optional[Dict[str, float]] = None,
                 time_fn: Callable[[], float] = time.perf_counter):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self._time = time_fn
        self._buf: deque = deque(maxlen=capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._emitted = 0
        self._sampled_out = 0
        self._periods: Dict[str, int] = {}
        self._cat_seen: Dict[str, int] = {}
        for cat, rate in (sample or {}).items():
            if not 0 < rate <= 1:
                raise ValueError(
                    f"sample rate for {cat!r} must be in (0, 1], got {rate}")
            self._periods[cat] = max(1, round(1.0 / rate))

    # -- internals --------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _keep(self, cat: str) -> bool:
        period = self._periods.get(cat)
        if period is None or period == 1:
            return True
        with self._lock:
            n = self._cat_seen.get(cat, 0)
            self._cat_seen[cat] = n + 1
        if n % period == 0:
            return True
        with self._lock:
            self._sampled_out += 1
        return False

    def _emit(self, rec: SpanRecord) -> None:
        with self._lock:
            self._emitted += 1
            self._buf.append(rec)

    # -- recording API ----------------------------------------------------

    def span(self, name: str, cat: str = "batch",
             tid: Optional[str] = None, **args: Any) -> _Span:
        """Open a span as a context manager; children nest via the
        per-thread stack.  Sampled-out spans still run their body but
        record nothing and don't claim children."""
        return _Span(self, name, cat, tid, dict(args), self._keep(cat))

    def instant(self, name: str, cat: str = "event",
                tid: Optional[str] = None, **args: Any) -> None:
        """Record a zero-duration point event (fault trips, sheds, …)."""
        if not self._keep(cat):
            return
        stack = self._stack()
        self._emit(SpanRecord(
            name=name, cat=cat, ph="i", t0=self._time(), dur=0.0,
            tid=tid or threading.current_thread().name,
            span_id=next(self._ids),
            parent_id=stack[-1] if stack else None, args=dict(args)))

    def async_begin(self, name: str, aid: int, cat: str = "request",
                    tid: Optional[str] = None, **args: Any) -> None:
        """Open one side of an async pair (e.g. a request's queue-to-reply
        life) matched to :meth:`async_end` by ``aid``."""
        self._emit(SpanRecord(
            name=name, cat=cat, ph="b", t0=self._time(), dur=0.0,
            tid=tid or "requests", span_id=next(self._ids),
            parent_id=None, args=dict(args), aid=aid))

    def async_end(self, name: str, aid: int, cat: str = "request",
                  tid: Optional[str] = None, **args: Any) -> None:
        self._emit(SpanRecord(
            name=name, cat=cat, ph="e", t0=self._time(), dur=0.0,
            tid=tid or "requests", span_id=next(self._ids),
            parent_id=None, args=dict(args), aid=aid))

    # -- reading API ------------------------------------------------------

    def events(self) -> Tuple[SpanRecord, ...]:
        """Snapshot of retained events, oldest first."""
        with self._lock:
            return tuple(self._buf)

    def events_by_cat(self, cat: str) -> Tuple[SpanRecord, ...]:
        return tuple(r for r in self.events() if r.cat == cat)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._emitted = 0
            self._sampled_out = 0
            self._cat_seen.clear()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            retained = len(self._buf)
            return {"enabled": True, "emitted": self._emitted,
                    "retained": retained,
                    "dropped_ring": self._emitted - retained,
                    "sampled_out": self._sampled_out}


def category_census(records: Iterable[SpanRecord]) -> Dict[str, int]:
    """Count events per category — the quick shape check for a trace."""
    out: Dict[str, int] = {}
    for r in records:
        out[r.cat] = out.get(r.cat, 0) + 1
    return dict(sorted(out.items()))
