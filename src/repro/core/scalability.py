"""Scalability analysis sweeps (paper Section III-B, Figs. 4-5, Table II).

Sweeps bit precision × bit rate for the AMM / MAM organization families and
reports the maximum supportable VDPE size ``N`` together with the optical
power received at the photodetector — the two quantities plotted in the
paper's Figs. 4 and 5.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from . import photonics as ph

#: Bit rates swept in the paper (Gbps).
PAPER_BIT_RATES_GBPS: Sequence[float] = (1.0, 3.0, 5.0, 10.0)
#: Bit precisions swept in the paper.
PAPER_PRECISIONS: Sequence[int] = (1, 2, 3, 4, 5, 6, 7, 8)

#: Paper Table II — VDPE size N at 4-bit precision (ground truth for tests).
PAPER_TABLE_II: Dict[str, Dict[float, int]] = {
    "RMAM": {1.0: 43, 3.0: 27, 5.0: 22, 10.0: 16},
    "RAMM": {1.0: 31, 3.0: 20, 5.0: 16, 10.0: 12},
    "MAM": {1.0: 44, 3.0: 28, 5.0: 22, 10.0: 16},
    "AMM": {1.0: 31, 3.0: 20, 5.0: 16, 10.0: 12},
}

#: Paper Table IV — comb-switch designs (BR Gbps -> (N, CS_FSR nm, radius µm,
#: number of CS pairs)).  Note the paper's Table IV quotes the *MAM* N values
#: (44→43 rounds to 43/28/22) for the RMAM rows and AMM N values for RAMM.
PAPER_TABLE_IV = {
    "RAMM": {1.0: (31, 4.83, 18.17, 3), 3.0: (20, 5.00, 17.50, 2),
             5.0: (16, None, None, 0)},
    "RMAM": {1.0: (43, 4.65, 18.98, 4), 3.0: (28, 5.35, 16.20, 3),
             5.0: (22, 4.54, 19.49, 2)},
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    arch: str
    precision_bits: int
    bit_rate_gbps: float
    max_n: int
    received_power_dbm: float  # at N = max_n (NaN-free: 0 when max_n == 0)


def sweep(
    arch_name: str,
    precisions: Sequence[int] = PAPER_PRECISIONS,
    bit_rates_gbps: Sequence[float] = PAPER_BIT_RATES_GBPS,
    params: ph.PhotonicParams | None = None,
) -> List[SweepPoint]:
    """Figs. 4-5: max N and received optical power per (precision, BR)."""
    p = params or ph.PhotonicParams()
    arch = ph.ARCHS[arch_name]
    out: List[SweepPoint] = []
    for bits in precisions:
        for br in bit_rates_gbps:
            n = ph.max_vdpe_size(p, arch, bits, br * 1e9)
            rx = ph.received_power_dbm(p, arch, max(n, 1), br * 1e9)
            out.append(SweepPoint(arch_name, bits, br, n, rx))
    return out


def table2(params: ph.PhotonicParams | None = None) -> Dict[str, Dict[float, int]]:
    """Reproduce Table II: N at 4-bit precision for all four organizations."""
    p = params or ph.PhotonicParams()
    out: Dict[str, Dict[float, int]] = {}
    for name in PAPER_TABLE_II:
        arch = ph.ARCHS[name]
        out[name] = {br: ph.max_vdpe_size(p, arch, 4, br * 1e9)
                     for br in PAPER_BIT_RATES_GBPS}
    return out


def table4() -> Dict[str, Dict[float, ph.CombSwitchDesign]]:
    """Reproduce Table IV comb-switch designs from the Table-II N values."""
    out: Dict[str, Dict[float, ph.CombSwitchDesign]] = {}
    for name, rows in PAPER_TABLE_IV.items():
        out[name] = {br: ph.design_comb_switch(n_ref[0])
                     for br, n_ref in rows.items()}
    return out


def operating_n(arch_name: str, br_gbps: float) -> int:
    """The N value an accelerator variant runs at (Table II, 4-bit)."""
    return PAPER_TABLE_II["AMM" if arch_name == "CROSSLIGHT" else arch_name][br_gbps]
