"""The unified operating-point type: one name for "where this runs".

Three divergent representations of an operating point grew up across the
stack: ``serve.telemetry.HardwarePoint`` (accelerator family x bit rate),
``engine.plan.EnginePoint`` (MXU packing geometry + quantization bits),
and the ad-hoc ``tpc.accelerator_at(acc, x=..., reconfigurable=...)``
keyword overrides for comb-switch retuning.  :class:`OperatingPoint`
unifies them: the hardware identity fields lead (so the historical
positional ``HardwarePoint("RMAM", 1.0)`` construction still works via
its thin subclass alias), the comb-switch overrides and engine packing
geometry follow as optional refinements, and the two converters hand
each subsystem exactly the view it consumes:

    op.to_accelerator()  ->  core.tpc.AcceleratorConfig  (simulator view)
    op.to_engine()       ->  engine.plan.EnginePoint     (compiler view)

``to_engine`` imports the engine lazily — core must stay importable
without jax, and the engine imports core, not vice versa.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .tpc import AcceleratorConfig, accelerator_at, build_accelerator


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One fully-specified place for a model to run.

    Hardware identity (``accelerator``, ``bit_rate_gbps``) is always
    set; everything else defaults to "whatever that hardware/engine
    defaults to": ``x``/``reconfigurable`` override the comb-switch
    geometry (what ``accelerator_at`` kwargs used to carry), and the
    ``engine_*``/``block_*``/``bits`` fields override the engine packing
    geometry (what ``EnginePoint`` carries).  ``None`` means "default",
    so a bare ``OperatingPoint("AMM", 5.0)`` is exactly the old
    ``HardwarePoint("AMM", 5.0)``.
    """
    accelerator: str = "RMAM"
    bit_rate_gbps: float = 1.0
    # comb-switch retune overrides (tpc.accelerator_at)
    x: Optional[int] = None
    reconfigurable: Optional[bool] = None
    # engine packing geometry overrides (engine.plan.EnginePoint)
    engine_n: Optional[int] = None
    engine_x: Optional[int] = None
    block_b: Optional[int] = None
    block_o: Optional[int] = None
    block_k: Optional[int] = None
    bits: int = 4

    @property
    def label(self) -> str:
        return f"{self.accelerator}@{self.bit_rate_gbps:g}G"

    def to_accelerator(self) -> AcceleratorConfig:
        """The simulator's view: a built (and, if ``x``/``reconfigurable``
        are set, retuned) :class:`AcceleratorConfig`."""
        acc = build_accelerator(self.accelerator, self.bit_rate_gbps)
        if self.x is not None or self.reconfigurable is not None:
            acc = accelerator_at(acc, x=self.x,
                                 reconfigurable=self.reconfigurable)
        return acc

    def to_engine(self):
        """The compiler's view: an ``engine.plan.EnginePoint`` carrying
        this point's packing geometry (engine defaults where unset)."""
        from ..engine import plan as _plan  # lazy: core must not need jax
        kwargs = {"bits": self.bits}
        for src, dst in (("engine_n", "n"), ("engine_x", "x"),
                         ("block_b", "block_b"), ("block_o", "block_o"),
                         ("block_k", "block_k")):
            v = getattr(self, src)
            if v is not None:
                kwargs[dst] = v
        return _plan.EnginePoint(**kwargs)

    @classmethod
    def from_engine(cls, point, accelerator: str = "RMAM",
                    bit_rate_gbps: float = 1.0) -> "OperatingPoint":
        """Lift an ``EnginePoint`` (plus a hardware identity) into the
        unified type; ``op.to_engine()`` round-trips it."""
        return cls(accelerator=accelerator, bit_rate_gbps=bit_rate_gbps,
                   engine_n=point.n, engine_x=point.x,
                   block_b=point.block_b, block_o=point.block_o,
                   block_k=point.block_k, bits=point.bits)
