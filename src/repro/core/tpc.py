"""Accelerator organizations, component counts, power & area models.

Encodes the paper's peripheral cost tables (Tables V, VI, VII), the
area-proportionate VDPE counts (Table VIII) and builds complete accelerator
operating points for the five evaluated designs:

    RMAM, RAMM          — this paper (reconfigurable, EO-tuned)
    MAM  (HOLYLIGHT)    — fixed-N MAM baseline
    AMM  (DEAP-CNN)     — fixed-N AMM baseline
    CROSSLIGHT          — AMM-family baseline with thermo-optic weight tuning

Power accounting (per TPC unless noted):
    lasers          N diodes x 10 mW optical / 0.1 wall-plug = N x 100 mW
    DIV DACs        full-rate input modulators: MAM N/TPC, AMM M*N/TPC
    DKV DACs        one weight-write DAC per VDPE (serial over its N rings)
    SE chain        per summation element: balanced PD pair + TIA (+ADC)
                    fixed VDPE: 1 SE; reconfigurable: y lane SEs + SE^N
    tuning hold     EO: negligible static hold; TO (CROSSLIGHT): 27.5 mW per
                    VDPE continuous heater hold power
    tile periphery  per 4 TPCs: reduction net, activation, IO, pooling,
                    eDRAM, bus, router (Table VI)

Area accounting mirrors the same component counts with Table V/VI areas and
an MRR footprint of (20 um)^2 (Table I pitch).  The resulting
area-proportionate counts land within ~12% of the paper's Table VIII; the
simulator uses the paper's published Table VIII counts as canonical (they are
the experiment's definition), and `area_proportionate_counts()` reports ours
for comparison (benchmarks/table8_bench).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from . import photonics as ph
from . import scalability as sc
from .mapping import TPCConfig
from .photonics import REAGG_SIZE_X

# ---------------------------------------------------------------------------
# Paper cost tables — the typed component library
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComponentEntry:
    """One device/peripheral class of the cost model (Tables V-VII).

    ``power_w`` is the static per-unit draw; ``energy_per_op_j`` is a
    per-operation switching energy for components charged dynamically by
    the simulator (only the DAC today: one imprinted sample costs
    30 mW x 0.78 ns = 23.4 pJ).
    """
    power_w: float
    area_mm2: float = 0.0
    latency_s: float = 0.0
    energy_per_op_j: float = 0.0


@dataclasses.dataclass(frozen=True)
class ComponentLibrary:
    """Typed home of every per-component cost entry the power/area/energy
    model reads (the paper's Tables V, VI, VII in one place).

    ``entries`` is keyed by component name; bit-rate-dependent ADCs live
    in ``adc`` keyed by GS/s.  ``AcceleratorConfig.power_breakdown()``
    consumes this library to produce per-component watts, so swapping a
    library entry (a what-if ADC, a cheaper laser) reprices the whole
    ledger without touching the accounting code.  The module-level
    ``DAC_POWER``/``TIA_POWER``/... constants below are backward-compat
    aliases derived from :data:`DEFAULT_LIBRARY`.
    """
    entries: Dict[str, ComponentEntry]
    adc: Dict[float, ComponentEntry]

    def __getitem__(self, name: str) -> ComponentEntry:
        return self.entries[name]

    def power(self, name: str) -> float:
        return self.entries[name].power_w

    def area(self, name: str) -> float:
        return self.entries[name].area_mm2

    def latency(self, name: str) -> float:
        return self.entries[name].latency_s

    def adc_at(self, br_gbps: float) -> ComponentEntry:
        return self.adc[br_gbps]


#: The paper's published component costs (Tables V-VII plus the Section
#: V-A laser budget: 10 dBm optical per diode at 10% wall-plug).
DEFAULT_LIBRARY = ComponentLibrary(
    entries={
        # Table VI — peripherals: power (W), area (mm^2), latency (s)
        "dac": ComponentEntry(30e-3, 0.034, 0.78e-9,
                              energy_per_op_j=30e-3 * 0.78e-9),
        "reduction": ComponentEntry(0.05e-3, 0.03e-3, 3.125e-9),
        "activation": ComponentEntry(0.52e-3, 0.6e-3, 0.78e-9),
        "io": ComponentEntry(140.18e-3, 24.4e-3, 0.78e-9),
        "pool": ComponentEntry(0.4e-3, 0.24e-3, 3.125e-9),
        "edram": ComponentEntry(41.1e-3, 166e-3, 1.56e-9),
        "bus": ComponentEntry(7e-3, 9e-3),          # latency: 5 cycles
        "router": ComponentEntry(42e-3, 0.151),     # latency: 2 cycles
        # Table VII — VDP element parameters
        "eo_tuning": ComponentEntry(80e-6, latency_s=20e-9),
        "to_tuning": ComponentEntry(27.5e-3, latency_s=4e-6),
        "tia": ComponentEntry(7.2e-3, latency_s=0.15e-6),
        "pd": ComponentEntry(2.8e-3, latency_s=5.8e-12),
        # Section V-A — one laser diode's wall-plug draw
        "laser": ComponentEntry(ph.dbm_to_watt(10.0) / 0.1),
    },
    adc={  # Table V — per bit rate (GS/s == Gbps here): area, power
        1.0: ComponentEntry(2.55e-3, area_mm2=0.002),
        3.0: ComponentEntry(11e-3, area_mm2=0.021),
        5.0: ComponentEntry(29e-3, area_mm2=0.103),
    },
)

#: Canonical ledger rows of ``power_breakdown()`` / the simulator's
#: per-layer energy decomposition, in reporting order.
LEDGER_COMPONENTS = ("laser", "weight_dac", "div_dac", "adc_pd_tia",
                     "tuning", "memory_noc", "periphery")

#: Table V — ADC (area mm^2, power W) per bit rate: backward-compat alias.
ADC_TABLE: Dict[float, tuple] = {
    br: (e.area_mm2, e.power_w) for br, e in DEFAULT_LIBRARY.adc.items()}

# Backward-compat aliases of the library entries (the historical loose
# module constants; new code should read DEFAULT_LIBRARY / component_powers).
DAC_POWER, DAC_AREA, DAC_LATENCY = (
    DEFAULT_LIBRARY["dac"].power_w, DEFAULT_LIBRARY["dac"].area_mm2,
    DEFAULT_LIBRARY["dac"].latency_s)
REDUCTION_POWER, REDUCTION_AREA, REDUCTION_LATENCY = (
    DEFAULT_LIBRARY["reduction"].power_w,
    DEFAULT_LIBRARY["reduction"].area_mm2,
    DEFAULT_LIBRARY["reduction"].latency_s)
ACTIVATION_POWER, ACTIVATION_AREA, ACTIVATION_LATENCY = (
    DEFAULT_LIBRARY["activation"].power_w,
    DEFAULT_LIBRARY["activation"].area_mm2,
    DEFAULT_LIBRARY["activation"].latency_s)
IO_POWER, IO_AREA, IO_LATENCY = (
    DEFAULT_LIBRARY["io"].power_w, DEFAULT_LIBRARY["io"].area_mm2,
    DEFAULT_LIBRARY["io"].latency_s)
POOL_POWER, POOL_AREA, POOL_LATENCY = (
    DEFAULT_LIBRARY["pool"].power_w, DEFAULT_LIBRARY["pool"].area_mm2,
    DEFAULT_LIBRARY["pool"].latency_s)
EDRAM_POWER, EDRAM_AREA, EDRAM_LATENCY = (
    DEFAULT_LIBRARY["edram"].power_w, DEFAULT_LIBRARY["edram"].area_mm2,
    DEFAULT_LIBRARY["edram"].latency_s)
BUS_POWER, BUS_AREA = (DEFAULT_LIBRARY["bus"].power_w,
                       DEFAULT_LIBRARY["bus"].area_mm2)
ROUTER_POWER, ROUTER_AREA = (DEFAULT_LIBRARY["router"].power_w,
                             DEFAULT_LIBRARY["router"].area_mm2)
EO_TUNING_POWER_PER_FSR = DEFAULT_LIBRARY["eo_tuning"].power_w
EO_TUNING_LATENCY = DEFAULT_LIBRARY["eo_tuning"].latency_s
TO_TUNING_POWER_PER_FSR = DEFAULT_LIBRARY["to_tuning"].power_w
TO_TUNING_LATENCY = DEFAULT_LIBRARY["to_tuning"].latency_s
TIA_POWER, TIA_LATENCY = (DEFAULT_LIBRARY["tia"].power_w,
                          DEFAULT_LIBRARY["tia"].latency_s)
PD_POWER, PD_LATENCY = (DEFAULT_LIBRARY["pd"].power_w,
                        DEFAULT_LIBRARY["pd"].latency_s)

#: DIV DAC idle-power floor (fraction of the 30 mW full-rate figure).
#: Recalibrated (0.10 -> 0.15) by the §Energy-model study: a constrained
#: joint fit of (this fraction, simulator.SUPPLY_POINTS_PER_NS) against
#: the paper's Fig. 10-11 gmean ratios, subject to the tier-1 fidelity
#: bounds (benchmarks/fig10_11_fps.py records the fit; EXPERIMENTS.md
#: §Energy model documents the method and the before/after ratios).
DIV_DAC_STATIC_FRACTION = 0.15
#: DIV DAC switching energy per imprinted sample: 30 mW x 0.78 ns.
DIV_DAC_ENERGY_PER_SAMPLE_J = DEFAULT_LIBRARY["dac"].energy_per_op_j

#: MRR footprint from the Table I pitch (20 um between ring centers).
MRR_AREA_MM2 = (20e-3) ** 2
#: A comb-switch pair occupies the area of 6 MRRs (Section V-B discussion).
CS_PAIR_AREA_MM2 = 6 * MRR_AREA_MM2

#: Latency of retuning the comb switches to a different operating point
#: (x width / Mode-1 bypass) between two layers: every CS pair on a VDPE
#: retunes in parallel, one EO ring-tuning step (Table VII).  This is the
#: per-switch penalty the reconfiguration-aware planner charges when two
#: consecutive layers run at different operating points.
RECONFIG_SWITCH_LATENCY_S = EO_TUNING_LATENCY

TPCS_PER_TILE = 4

#: Table VIII — area-proportionate VDPE counts (canonical for Figs. 10-11).
PAPER_TABLE_VIII: Dict[str, Dict[float, int]] = {
    "RMAM": {1.0: 512, 3.0: 512, 5.0: 512},
    "RAMM": {1.0: 587, 3.0: 576, 5.0: 567},
    "MAM": {1.0: 568, 3.0: 562, 5.0: 547},
    "AMM": {1.0: 656, 3.0: 629, 5.0: 620},
    # CROSSLIGHT counts are not listed in Table VIII; it is AMM-family
    # hardware (plus TO heaters with negligible area), so AMM counts apply.
    "CROSSLIGHT": {1.0: 656, 3.0: 629, 5.0: 620},
}


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """One fully-specified accelerator operating point.

    ``x`` is the comb-switch re-aggregation width the accelerator is
    currently tuned to (paper Eq. 13 sets the CS ring FSR from it).  The
    reconfiguration-aware planner (engine/plan.py) sweeps operating points
    that differ only in (x, reconfigurable) — retuning the comb switches
    between layers — so the field is part of the frozen identity the
    simulator memo keys on.
    """
    name: str                  # RMAM/RAMM/MAM/AMM/CROSSLIGHT
    br_gbps: float
    n: int                     # VDPE size (Table II)
    n_vdpe: int                # total VDPEs (Table VIII, area-proportionate)
    reconfigurable: bool
    tuning: str                # "EO" | "TO"
    x: int = REAGG_SIZE_X      # comb-switch re-aggregation width

    @property
    def org(self) -> str:
        return "MAM" if self.name in ("MAM", "RMAM") else "AMM"

    @property
    def m(self) -> int:
        return self.n           # paper: M = N VDPEs per TPC

    @property
    def y(self) -> int:
        return (ph.num_comb_switch_pairs(self.n, self.x)
                if self.reconfigurable else 0)

    @property
    def n_tpc(self) -> int:
        return max(1, round(self.n_vdpe / self.m))

    @property
    def n_tiles(self) -> int:
        return max(1, math.ceil(self.n_tpc / TPCS_PER_TILE))

    @property
    def tpc_config(self) -> TPCConfig:
        return TPCConfig(org=self.org, n=self.n, m=self.m,
                         reconfigurable=self.reconfigurable, x=self.x)

    @property
    def cycle_time_s(self) -> float:
        return 1.0 / (self.br_gbps * 1e9)

    @property
    def tuning_latency_s(self) -> float:
        return EO_TUNING_LATENCY if self.tuning == "EO" else TO_TUNING_LATENCY

    @property
    def weight_load_latency_s(self) -> float:
        """Retune rings + serially write N weights through the VDPE's DAC."""
        return self.tuning_latency_s + self.n * DAC_LATENCY

    @property
    def ses_per_vdpe(self) -> int:
        """Summation elements: y lane SEs + the Mode-1 SE^N."""
        return self.y + 1 if self.reconfigurable else 1

    # -- power ---------------------------------------------------------------

    @property
    def div_dac_count(self) -> int:
        """Full-rate input DACs: MAM shares one DIV element per TPC."""
        per_tpc = self.n if self.org == "MAM" else self.m * self.n
        return self.n_tpc * per_tpc

    def power_breakdown(self, library: Optional[ComponentLibrary] = None,
                        ) -> Dict[str, float]:
        """Static watts by ledger component (:data:`LEDGER_COMPONENTS`).

        The component-level energy ledger's power side: one row per
        canonical component class, summing (exactly — ``power_static_w``
        is *defined* as this sum) to the accelerator's always-on draw.

        laser       N diodes/TPC at the Section V-A wall-plug budget
        weight_dac  one DKV write DAC per VDPE
        div_dac     the input DACs' idle floor (DIV_DAC_STATIC_FRACTION
                    x 30 mW each; switching is charged per sample by the
                    simulator)
        adc_pd_tia  per-SE receive chain: balanced PD pair + TIA + ADC,
                    (y + 1) SEs per reconfigurable VDPE
        tuning      ring-tuning hold (EO hold for RMAM-family, TO heater
                    hold for CROSSLIGHT)
        memory_noc  per-tile eDRAM + bus + router (the Fig. 9 mesh)
        periphery   per-tile reduction net, activation, IO, pooling
        """
        lib = DEFAULT_LIBRARY if library is None else library
        n, m, n_tpc = self.n, self.m, self.n_tpc
        se_w = (2 * lib.power("pd") + lib.power("tia")
                + lib.adc_at(self.br_gbps).power_w)
        tune_w = lib.power("to_tuning" if self.tuning == "TO"
                           else "eo_tuning")
        return {
            "laser": n_tpc * n * lib.power("laser"),
            "weight_dac": n_tpc * m * lib.power("dac"),
            "div_dac": (self.div_dac_count * lib.power("dac")
                        * DIV_DAC_STATIC_FRACTION),
            "adc_pd_tia": n_tpc * m * self.ses_per_vdpe * se_w,
            "tuning": n_tpc * m * tune_w,
            "memory_noc": self.n_tiles * (lib.power("edram")
                                          + lib.power("bus")
                                          + lib.power("router")),
            "periphery": self.n_tiles * (lib.power("reduction")
                                         + lib.power("activation")
                                         + lib.power("io")
                                         + lib.power("pool")),
        }

    def power_static_w(self) -> float:
        """Always-on power: everything except DIV-DAC dynamic switching.

        Defined as the sum of :meth:`power_breakdown` rows, so the
        per-component ledger decomposes it exactly.  DIV DACs contribute
        only their idle floor (DIV_DAC_STATIC_FRACTION x 30 mW); their
        switching energy is charged per imprinted sample by the simulator
        (23.4 pJ = 30 mW x 0.78 ns), which is what lets a supply-starved
        AMM TPC's 961 input DACs idle instead of burning full rate power.
        """
        return sum(self.power_breakdown().values())

    def power_w(self) -> float:
        """Peak device power (all DIV DACs switching at full rate)."""
        return (self.power_static_w()
                + self.div_dac_count * DAC_POWER * (1 - DIV_DAC_STATIC_FRACTION))

    # -- area ----------------------------------------------------------------

    def area_mm2(self) -> float:
        n, m, n_tpc = self.n, self.m, self.n_tpc
        adc_area = ADC_TABLE[self.br_gbps][0]
        per_vdpe = n * MRR_AREA_MM2                        # DKV rings
        per_vdpe += self.y * CS_PAIR_AREA_MM2              # comb switches
        per_vdpe += self.ses_per_vdpe * adc_area           # lane ADCs
        per_vdpe += DAC_AREA                               # weight-write DAC
        if self.org == "AMM":
            per_vdpe += n * (MRR_AREA_MM2 + 0)             # private DIV rings
            per_vdpe += n * DAC_AREA / m                   # (DIV DACs below)
        per_tpc = m * per_vdpe
        if self.org == "MAM":
            per_tpc += n * (MRR_AREA_MM2 + DAC_AREA)       # shared DIV block
        else:
            per_tpc += m * n * DAC_AREA * 0                # counted per-VDPE
        tile = (REDUCTION_AREA + ACTIVATION_AREA + IO_AREA + POOL_AREA
                + EDRAM_AREA + BUS_AREA + ROUTER_AREA)
        return n_tpc * per_tpc + self.n_tiles * tile


def build_accelerator(name: str, br_gbps: float,
                      n_vdpe: int | None = None) -> AcceleratorConfig:
    """Build an accelerator at its Table II operating point."""
    n = sc.operating_n(name, br_gbps)
    if n_vdpe is None:
        n_vdpe = PAPER_TABLE_VIII[name][br_gbps]
    return AcceleratorConfig(
        name=name, br_gbps=br_gbps, n=n, n_vdpe=n_vdpe,
        reconfigurable=name in ("RMAM", "RAMM"),
        tuning="TO" if name == "CROSSLIGHT" else "EO",
    )


def accelerator_at(acc: AcceleratorConfig, opt=None,
                   *, x: int | None = None,
                   reconfigurable: bool | None = None) -> AcceleratorConfig:
    """The same accelerator retuned to a different comb-switch point.

    Accepts a ``mapping.PointOption``-like object (anything with ``x`` and
    ``reconfigurable``) or explicit keyword overrides.  The MRR hardware is
    unchanged — only the CS geometry (and therefore y, mode selection, and
    the lane-SE power share) moves, which is exactly what the paper's RCA
    retunes between layers.
    """
    if opt is not None:
        x = opt.x if x is None else x
        reconfigurable = (opt.reconfigurable if reconfigurable is None
                          else reconfigurable)
    return dataclasses.replace(
        acc,
        x=acc.x if x is None else x,
        reconfigurable=(acc.reconfigurable if reconfigurable is None
                        else reconfigurable))


def component_powers(acc: AcceleratorConfig,
                     library: Optional[ComponentLibrary] = None,
                     ) -> Dict[str, float]:
    """Per-component static watts of an accelerator (the ledger's power
    rows) — the accessor that replaces piecemeal star-imports of the
    loose ``DAC_POWER``/``TIA_POWER``/... module constants."""
    return acc.power_breakdown(library)


ACCELERATORS = ("RMAM", "RAMM", "MAM", "AMM", "CROSSLIGHT")
PAPER_BIT_RATES = (1.0, 3.0, 5.0)


def area_proportionate_counts(br_gbps: float,
                              reference: str = "RMAM",
                              ref_count: int = 512) -> Dict[str, int]:
    """Our area model's Table VIII: equalize area with RMAM @ ref_count."""
    ref = build_accelerator(reference, br_gbps, n_vdpe=ref_count)
    target = ref.area_mm2()
    out = {reference: ref_count}
    for name in ACCELERATORS:
        if name == reference:
            continue
        probe = build_accelerator(name, br_gbps, n_vdpe=ref_count)
        per_vdpe = probe.area_mm2() / ref_count   # ~linear in count
        out[name] = max(1, round(target / per_vdpe))
    return out
