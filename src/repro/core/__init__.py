# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .operating_point import OperatingPoint  # noqa: F401
from .tpc import (ComponentEntry, ComponentLibrary,  # noqa: F401
                  DEFAULT_LIBRARY, LEDGER_COMPONENTS, component_powers)
