"""Transaction-level, cycle-true simulator of CNN inference on MRR TPCs.

Weight-stationary dataflow (Section VI-A).  Per layer, each ``PassGroup``
from core/mapping.py is scheduled as:

    rounds      = ceil(max(passes / n_tpc, 1))
    overheads   = rounds x (ring retune + serial weight-DAC write + TIA fill)
    stream time = max(compute-bound, input-supply-bound)
        compute-bound = passes x stream_cycles / BR / n_tpc
        supply-bound  = passes x stream_cycles x supply_points / B_supply

``B_supply`` is the accelerator-wide input-delivery bandwidth (global memory
+ NoC mesh of Fig. 9) in fresh 4-bit input points per ns.  Kernel-parallel
(MAM-family) TPCs amortize one DIV fetch over M kernels per cycle;
position-parallel (AMM-family) TPCs fetch M fresh patches per cycle, so the
supply bound is what separates the organizations once per-pass overheads are
paid.  Recalibrated jointly with the DIV-DAC idle fraction against the
paper's Figs. 10-11 gmean ratios (see EXPERIMENTS.md §Energy model; the
original anchor was the RMAM@1Gbps line rate of 516 points/ns).

Energy: static power is charged for the full frame latency and decomposed
into the component ledger of ``AcceleratorConfig.power_breakdown()``
(laser, weight-DAC, DIV-DAC idle, ADC/PD/TIA, tuning, memory/NoC,
periphery); DIV DAC switching is charged per imprinted sample (23.4 pJ)
into the ``div_dac`` row, so a supply-starved organization's input DACs
idle instead of burning full-rate power.  Per-layer ``LayerCost`` rows and
their per-component cells sum *exactly* to ``energy_per_frame_j`` —
attribution and the energy ledger are decompositions, not estimates.
FPS/W == 1/energy-per-frame, matching the paper's static-amortization
argument.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..cnn.layers import LayerSpec
from . import tpc as tpc_mod
from .mapping import LayerMapping, map_layer
from .tpc import (ACTIVATION_LATENCY, AcceleratorConfig,
                  DIV_DAC_ENERGY_PER_SAMPLE_J, POOL_LATENCY,
                  REDUCTION_LATENCY, TIA_LATENCY, build_accelerator)

#: Accelerator-wide input-supply bandwidth, fresh 4-bit points per ns.
#: Originally anchored at the RMAM@1Gbps line rate (12 TPCs x 43 pts/ns
#: = 516); recalibrated to 420 by the §Energy-model study — a constrained
#: joint fit with tpc.DIV_DAC_STATIC_FRACTION against the paper's
#: Figs. 10-11 gmean ratios, subject to the tier-1 fidelity bounds
#: (benchmarks/fig10_11_fps.py records the fit).
SUPPLY_POINTS_PER_NS = 420.0


@dataclasses.dataclass(frozen=True)
class LayerReport:
    mapping: LayerMapping
    rounds: int
    time_s: float
    div_samples: int          # DIV DAC sample writes for the layer
    utilization: float


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Per-frame attribution of one layer of an :class:`InferenceReport`.

    The rows are an exact decomposition: summing ``time_s`` over a
    report's rows reproduces ``frame_latency_s`` and summing ``energy_j``
    reproduces ``energy_per_frame_j`` (static power is charged to each
    layer for its own stream time; DIV-DAC switching per its samples), so
    attribution coverage is 100% by construction.  ``components`` splits
    ``energy_j`` one level further — by the canonical ledger rows of
    ``tpc.LEDGER_COMPONENTS`` — and ``energy_j`` is *defined* as the sum
    of its cells, so the component ledger decomposes exactly too.
    """

    name: str
    kind: str
    time_s: float             # modeled seconds per frame
    energy_j: float           # == sum(components.values()), per frame
    utilization: float        # MRR utilization of the layer's mapping
    div_samples: float        # DIV DAC sample writes per frame
    rounds: int
    #: ledger row -> joules per frame (static share per component for this
    #: layer's time; DIV-DAC switching folded into the ``div_dac`` row)
    components: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class InferenceReport:
    accelerator: AcceleratorConfig
    layers: List[LayerReport]
    batch: int
    #: original (non-canonical) layer names; LayerReports are memoized on
    #: shape-identical canonical specs, which drop the name
    layer_names: Optional[Tuple[str, ...]] = None

    @property
    def frame_latency_s(self) -> float:
        return sum(l.time_s for l in self.layers) / self.batch

    @property
    def fps(self) -> float:
        return 1.0 / self.frame_latency_s

    @property
    def energy_per_frame_j(self) -> float:
        static = self.accelerator.power_static_w() * self.frame_latency_s
        dyn = (sum(l.div_samples for l in self.layers)
               * DIV_DAC_ENERGY_PER_SAMPLE_J / self.batch)
        return static + dyn

    @property
    def avg_power_w(self) -> float:
        """Frame-averaged wall power (energy per frame over frame time)."""
        return self.energy_per_frame_j / self.frame_latency_s

    @property
    def power_w(self) -> float:
        """Deprecated alias of :attr:`avg_power_w` (this is frame-averaged
        wall power, NOT peak device power — see :attr:`peak_power_w`)."""
        import warnings
        warnings.warn("InferenceReport.power_w is deprecated; use "
                      "avg_power_w (frame-averaged) or peak_power_w "
                      "(device peak)", DeprecationWarning, stacklevel=2)
        return self.avg_power_w

    @property
    def peak_power_w(self) -> float:
        """Peak device power (every DIV DAC at full rate) — the
        AcceleratorConfig passthrough benchmarks used to recompute."""
        return self.accelerator.power_w()

    @property
    def fps_per_watt(self) -> float:
        return 1.0 / self.energy_per_frame_j

    @property
    def mean_utilization(self) -> float:
        used = sum(l.mapping.used_mrr_cycles for l in self.layers)
        active = sum(l.mapping.active_mrr_cycles for l in self.layers)
        return used / max(active, 1)

    def energy_breakdown(self) -> Dict[str, float]:
        """Per-frame joules by ledger component (sums to
        ``energy_per_frame_j`` up to float rounding): each component's
        static watts charged for the frame latency, DIV-DAC switching
        folded into the ``div_dac`` row."""
        t = self.frame_latency_s
        out = {c: p * t
               for c, p in self.accelerator.power_breakdown().items()}
        out["div_dac"] += (sum(l.div_samples for l in self.layers)
                           * DIV_DAC_ENERGY_PER_SAMPLE_J / self.batch)
        return out

    def layer_costs(self) -> List[LayerCost]:
        """Exact per-layer, per-frame breakdown (see :class:`LayerCost`)."""
        breakdown = self.accelerator.power_breakdown()
        out: List[LayerCost] = []
        for i, l in enumerate(self.layers):
            if self.layer_names is not None and i < len(self.layer_names):
                name = self.layer_names[i]
            else:
                name = f"layer{i}"
            t = l.time_s / self.batch
            comps = {c: p * t for c, p in breakdown.items()}
            comps["div_dac"] += (l.div_samples
                                 * DIV_DAC_ENERGY_PER_SAMPLE_J / self.batch)
            out.append(LayerCost(
                name=name, kind=l.mapping.layer.kind.value, time_s=t,
                energy_j=sum(comps.values()),
                utilization=l.utilization,
                div_samples=l.div_samples / self.batch, rounds=l.rounds,
                components=comps))
        return out


def simulate_layer(acc: AcceleratorConfig, layer: LayerSpec,
                   batch: int = 1,
                   supply_points_per_ns: float = SUPPLY_POINTS_PER_NS,
                   ) -> LayerReport:
    """Schedule one layer's pass groups; vectorized over groups + memoized.

    Memoized on (AcceleratorConfig, LayerSpec.canonical(), batch, supply):
    the paper CNNs repeat layer shapes heavily (e.g. Xception's 8 identical
    middle-flow blocks), so the Figs. 10-11 sweep hits this cache far more
    often than it misses.  The returned LayerReport is shared — treat it as
    immutable.
    """
    return _simulate_layer_cached(acc, layer.canonical(), batch,
                                  supply_points_per_ns)


@functools.lru_cache(maxsize=65536)
def _simulate_layer_cached(acc: AcceleratorConfig, layer: LayerSpec,
                           batch: int,
                           supply_points_per_ns: float) -> LayerReport:
    mapping = map_layer(acc.tpc_config, layer)
    overhead = acc.weight_load_latency_s + TIA_LATENCY
    groups = mapping.groups
    passes = np.array([g.passes for g in groups], np.float64)
    stream = np.array([g.stream_cycles for g in groups], np.float64)
    supply = np.array([g.supply_points for g in groups], np.float64)
    g_rounds = np.ceil(np.maximum(passes / acc.n_tpc, 1.0))
    cycles = passes * stream * batch
    t_compute = cycles * (acc.cycle_time_s / acc.n_tpc)
    t_supply = cycles * supply / supply_points_per_ns * 1e-9
    post = (REDUCTION_LATENCY * math.ceil(math.log2(max(mapping.n_chunks, 2)))
            + ACTIVATION_LATENCY + POOL_LATENCY)
    time_s = float((g_rounds * overhead
                    + np.maximum(t_compute, t_supply)).sum()) + post
    return LayerReport(mapping=mapping, rounds=int(g_rounds.sum()),
                       time_s=time_s,
                       div_samples=int((cycles * supply).sum()),
                       utilization=mapping.utilization)


# cache controls surface on the public entry point
simulate_layer.cache_info = _simulate_layer_cached.cache_info
simulate_layer.cache_clear = _simulate_layer_cached.cache_clear


def simulate(acc: AcceleratorConfig, layers: Sequence[LayerSpec],
             batch: int = 1,
             supply_points_per_ns: float = SUPPLY_POINTS_PER_NS,
             ) -> InferenceReport:
    reports = [simulate_layer(acc, l, batch, supply_points_per_ns)
               for l in layers]
    return InferenceReport(accelerator=acc, layers=reports, batch=batch,
                           layer_names=tuple(l.name for l in layers))


def gmean(values: Iterable[float]) -> float:
    vals = list(values)
    if not vals:
        raise ValueError("gmean of an empty sequence is undefined")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def evaluate_suite(
    cnn_tables: Dict[str, Sequence[LayerSpec]],
    accelerators: Sequence[str] = tpc_mod.ACCELERATORS,
    bit_rates: Sequence[float] = tpc_mod.PAPER_BIT_RATES,
    batch: int = 1,
) -> Dict[str, Dict[float, Dict[str, InferenceReport]]]:
    """Figs. 10-11 sweep: accelerator x bit-rate x CNN -> report."""
    out: Dict[str, Dict[float, Dict[str, InferenceReport]]] = {}
    for name in accelerators:
        out[name] = {}
        for br in bit_rates:
            acc = build_accelerator(name, br)
            out[name][br] = {cnn: simulate(acc, layers, batch)
                             for cnn, layers in cnn_tables.items()}
    return out


def normalized_fps(results, reference=("RMAM", 1.0)) -> Dict:
    """Normalize FPS to the reference accelerator's per-CNN FPS (Fig. 10)."""
    ref = results[reference[0]][reference[1]]
    return {
        name: {br: {cnn: rep.fps / ref[cnn].fps
                    for cnn, rep in by_cnn.items()}
               for br, by_cnn in by_br.items()}
        for name, by_br in results.items()
    }


def normalized_fps_per_watt(results, reference=("RMAM", 1.0)) -> Dict:
    ref = results[reference[0]][reference[1]]
    return {
        name: {br: {cnn: rep.fps_per_watt / ref[cnn].fps_per_watt
                    for cnn, rep in by_cnn.items()}
               for br, by_cnn in by_br.items()}
        for name, by_br in results.items()
    }
