"""JAX numerics for decomposed VDP execution (paper Section II-B, Fig. 2).

This module is the *functional* counterpart of the scheduling model: it
executes a convolution exactly the way the accelerator does —

    flatten kernels to DKVs, im2col inputs to DIVs        (Fig. 2)
    quantize both sides to 4-bit symmetric integers       (Sec. III-B)
    slice the contraction per the Case-1/2/3 plan         (Sec. V-B)
    per-slice segmented dot products (psums)              (VDPEs)
    integer psum accumulation                             (reduction network)
    dequantize                                            (post-processing)

and the central invariant — *slicing + psum reduction is bit-identical to
the direct quantized GEMM* (integer accumulation is associative) — is what
tests/test_vdp_numerics.py property-checks.  An optional analog-noise model
injects the Eq. 9/10 photodetector noise at the summation elements for
accuracy studies.
"""
from __future__ import annotations

import math
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .mapping import TPCConfig, slice_plan
from . import photonics as ph
from .photonics import InfeasiblePrecisionError  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# 4-bit symmetric quantization
# ---------------------------------------------------------------------------

def inv_qmax(bits: int) -> jnp.float32:
    """1/qmax as an explicit f32 constant multiplier.

    The DAC scale is max|x| / qmax; written as a *division by the literal
    qmax* it is regime-unstable — XLA's simplifier rewrites division by a
    compile-time constant into a reciprocal multiply under jit, so an
    eagerly computed scale and a whole-model-jitted one differ by 1 ulp,
    which the quantizer's round() amplifies into integer flips.  Doing the
    reciprocal multiply explicitly makes eager, per-kernel-jit and
    whole-model-jit (engine/pipeline.py) produce bit-identical scales.
    """
    return jnp.float32(1.0 / (2 ** (bits - 1) - 1))


def quantize_symmetric(x: jax.Array, bits: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization to ``bits`` signed levels.

    Returns (q, scale) with q int8-valued in [-(2^(b-1)-1), 2^(b-1)-1].
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) * inv_qmax(bits)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale_a: jax.Array, scale_b: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * (scale_a * scale_b)


# ---------------------------------------------------------------------------
# Tensor decomposition: DIVs and DKVs (Fig. 2)
# ---------------------------------------------------------------------------

def out_hw(h: int, w: int, k: int, stride: int = 1,
           padding: str = "SAME") -> Tuple[int, int]:
    if padding == "SAME":
        return math.ceil(h / stride), math.ceil(w / stride)
    return (h - k) // stride + 1, (w - k) // stride + 1


def im2col(x: jax.Array, k: int, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """Extract flattened K x K x D patches (DIVs).

    x: (H, W, D)  ->  (H_out * W_out, K*K*D), matching the row-major
    flattening of `dkv_matrix` so that patch . dkv == conv output point.
    """
    h, w, d = x.shape
    if padding == "SAME":
        h_out = math.ceil(h / stride)
        w_out = math.ceil(w / stride)
        pad_h = max((h_out - 1) * stride + k - h, 0)
        pad_w = max((w_out - 1) * stride + k - w, 0)
        x = jnp.pad(x, ((pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    else:
        h_out = (h - k) // stride + 1
        w_out = (w - k) // stride + 1
    patches = []
    for di in range(k):
        for dj in range(k):
            patches.append(x[di:di + stride * h_out:stride,
                             dj:dj + stride * w_out:stride, :])
    # (H_out, W_out, K*K, D) -> (P, K*K*D)
    stacked = jnp.stack(patches, axis=2)
    return stacked.reshape(h_out * w_out, k * k * d)


def dkv_matrix(kernels: jax.Array) -> jax.Array:
    """Flatten (F, K, K, D) kernel tensors into the (F, S) DKV matrix."""
    f = kernels.shape[0]
    return kernels.reshape(f, -1)


# ---------------------------------------------------------------------------
# Decomposed VDP execution
# ---------------------------------------------------------------------------

def direct_quantized_gemm(divs_q: jax.Array, dkvs_q: jax.Array) -> jax.Array:
    """Reference: one exact int32 GEMM over the full contraction."""
    return jax.lax.dot_general(
        divs_q.astype(jnp.int32), dkvs_q.astype(jnp.int32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32)


def sliced_vdp_gemm(divs_q: jax.Array, dkvs_q: jax.Array,
                    tpc: TPCConfig) -> jax.Array:
    """Execute the GEMM through the accelerator's slice plan.

    Each slice group produces integer psums on its VDPE lanes; the psum
    reduction network accumulates them.  Integer associativity makes this
    bit-identical to `direct_quantized_gemm` — the invariant the whole
    accelerator design rests on.
    """
    s = divs_q.shape[1]
    out = jnp.zeros((divs_q.shape[0], dkvs_q.shape[0]), jnp.int32)
    off = 0
    for mode, width, count in slice_plan(tpc, s):
        for _ in range(count):
            a = divs_q[:, off:off + width].astype(jnp.int32)
            b = dkvs_q[:, off:off + width].astype(jnp.int32)
            out = out + jax.lax.dot_general(
                a, b, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            off += width
    return out


def mode2_packed_vdp(divs_q: jax.Array, small_dkvs_q: jax.Array,
                     x: int, y: int, n: int) -> jax.Array:
    """Case-3 Mode 2: y whole DKVs of size S <= x ride one VDPE pass.

    Emulates the comb-switch re-aggregation: the y DKVs are packed onto one
    N-lane VDPE (lane g occupies wavelengths [g*x, g*x + S)); the per-lane
    summation elements produce y results per pass.  Numerically this is a
    block-diagonal GEMM; returns (P, y) integer VDP results.
    """
    s = small_dkvs_q.shape[1]
    assert s <= x and y * x <= n
    # pack: lanes g hold dkv g at offset g*x; off-lane weights are zero
    packed = jnp.zeros((n,), jnp.int32)
    packs = []
    for g in range(y):
        w = jnp.zeros((n,), jnp.int32)
        w = w.at[g * x:g * x + s].set(small_dkvs_q[g].astype(jnp.int32))
        packs.append(w)
    w_block = jnp.stack(packs, axis=1)              # (N, y) block-diagonal
    # the DIV pattern replicates the patch on every lane's wavelengths
    div_rep = jnp.zeros((divs_q.shape[0], n), jnp.int32)
    for g in range(y):
        div_rep = div_rep.at[:, g * x:g * x + s].set(divs_q.astype(jnp.int32))
    return div_rep @ w_block                        # (P, y)


def conv2d_direct(x: jax.Array, kernels: jax.Array, stride: int = 1,
                  padding: str = "SAME") -> jax.Array:
    """Float reference conv via lax.conv_general_dilated (HWC, F-KKD)."""
    lhs = x[None].astype(jnp.float32)               # NHWC
    rhs = jnp.transpose(kernels, (1, 2, 3, 0)).astype(jnp.float32)  # HWIO
    out = jax.lax.conv_general_dilated(
        lhs, rhs, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0]


def conv2d_vdp(x: jax.Array, kernels: jax.Array, tpc: TPCConfig,
               stride: int = 1, padding: str = "SAME", bits: int = 4,
               ) -> Tuple[jax.Array, jax.Array]:
    """Quantized conv through the full decomposed-VDP path.

    Returns (vdp_result, direct_quantized_result); both are dequantized
    floats and must agree exactly (same integer accumulations).
    """
    k = kernels.shape[1]
    f = kernels.shape[0]
    divs = im2col(x, k, stride, padding)
    dkvs = dkv_matrix(kernels)
    divs_q, s_a = quantize_symmetric(divs, bits)
    dkvs_q, s_b = quantize_symmetric(dkvs, bits)
    acc_sliced = sliced_vdp_gemm(divs_q, dkvs_q, tpc)
    acc_direct = direct_quantized_gemm(divs_q, dkvs_q)
    ho, wo = out_hw(x.shape[0], x.shape[1], k, stride, padding)
    out_s = dequantize(acc_sliced, s_a, s_b).reshape(ho, wo, f)
    out_d = dequantize(acc_direct, s_a, s_b).reshape(ho, wo, f)
    return out_s, out_d


def depthwise_conv2d_vdp(x: jax.Array, kernels: jax.Array, tpc: TPCConfig,
                         stride: int = 1, padding: str = "SAME",
                         bits: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Depthwise conv through per-channel VDPs (Fig. 2b).

    kernels: (D, K, K).  Returns (vdp, reference) dequantized outputs.
    """
    d = x.shape[-1]
    k = kernels.shape[-1]
    ho, wo = out_hw(x.shape[0], x.shape[1], k, stride, padding)
    outs_v, outs_r = [], []
    for c in range(d):
        divs = im2col(x[..., c:c + 1], k, stride, padding)
        dkv = kernels[c].reshape(1, -1)
        divs_q, s_a = quantize_symmetric(divs, bits)
        dkv_q, s_b = quantize_symmetric(dkv, bits)
        outs_v.append(dequantize(sliced_vdp_gemm(divs_q, dkv_q, tpc), s_a, s_b))
        outs_r.append(dequantize(direct_quantized_gemm(divs_q, dkv_q), s_a, s_b))
    return (jnp.concatenate(outs_v, -1).reshape(ho, wo, d),
            jnp.concatenate(outs_r, -1).reshape(ho, wo, d))


# ---------------------------------------------------------------------------
# Analog noise model (Eq. 9/10) for accuracy studies
# ---------------------------------------------------------------------------

def noisy_vdp_gemm(key: jax.Array, divs_q: jax.Array, dkvs_q: jax.Array,
                   tpc: TPCConfig, br_hz: float = 1e9, bits: int = 4,
                   params: ph.PhotonicParams | None = None) -> jax.Array:
    """Integer GEMM + per-psum Gaussian noise at the summation elements.

    The PD noise current (Eq. 10) at the operating received power maps to an
    equivalent integer-domain sigma via the LSB size at the photodetector:
    one LSB corresponds to the minimum resolvable power step for ``bits``
    (ph.integer_noise_sigma_lsb).

    Raises :class:`repro.core.photonics.InfeasiblePrecisionError` when the
    (bits, BR) point violates the Eq. 9 RIN ceiling — such a point used to
    silently return the *noise-free* result (sigma 0.0), the exact opposite
    of what infeasibility means.
    """
    p = params or ph.PhotonicParams()
    sigma_lsb = ph.integer_noise_sigma_lsb(p, bits, br_hz)
    acc = sliced_vdp_gemm(divs_q, dkvs_q, tpc).astype(jnp.float32)
    n_slices = sum(c for _, _, c in slice_plan(tpc, divs_q.shape[1]))
    noise = (jax.random.normal(key, acc.shape)
             * sigma_lsb * jnp.sqrt(float(n_slices)))
    return jnp.round(acc + noise).astype(jnp.int32)
