"""DKV -> VDPE mapping: the paper's Cases 1/2/3 and Mode 1/2 selection (Sec. V-B).

Given a layer's DKV size S and a TPC operating point (N, M, x, organization),
this module slices the DKV matrix, selects the operating mode per slice, and
produces a ``LayerMapping`` — a list of homogeneous ``PassGroup`` schedules
plus exact utilization accounting — consumed by the cycle-true simulator
(core/simulator.py) and the utilization study (Fig. 6).

Slice/mode selection for reconfigurable VDPEs, y = (N >= 2x ? floor(N/x) : 0):

    Case 1  S >= N   -> floor(S/N) Mode-1 slices of width N; the remainder
                        slice (width < N) is re-aggregated per the Case-2/3
                        rules below (the paper's F^1_(H,c) slice is itself a
                        matrix of DKVs smaller than N, and the reconfigurable
                        VDPE processes it in Mode 2 — this recovers the
                        remainder waste the paper identifies in Scenario 2).
    Case 2  x < S < N -> Mode-2 slices of width x plus a remainder c <= x;
                        y lanes per VDPE carry y different kernels' slices.
    Case 3  S <= x    -> one Mode-2 slice; y whole DKVs per VDPE in parallel.

Non-reconfigurable TPCs (or y == 0) always slice by N in Mode 1.

Dataflows (Section III-A structure dictates who parallelizes over what):

* MAM family (HOLYLIGHT, RMAM) — **kernel-parallel**: ONE DIV element per
  TPC; each cycle all M VDPEs see the same DIV and hold M different kernels
  (x y Mode-2 lanes).  One pass streams the layer's positions.  Depthwise
  convolutions tie kernel c to channel c's patches, so only one VDPE per MAM
  TPC holds a distinct kernel; Mode-2 lanes recover y-way parallelism (the
  shared DIV element imprints each lane's x wavelengths with a different
  channel's patch).

* AMM family (DEAP-CNN, RAMM, CROSSLIGHT) — **position-parallel**: private
  DIV element per VDPE; ONE kernel is broadcast to all M DKV elements while
  the M DIV elements carry M different input patches (DEAP-CNN's conv
  mapping).  One pass streams ceil(P/M) position-groups and fetches M fresh
  patches per cycle — the input-supply bound this creates, together with the
  per-pass overheads paid once per kernel instead of once per M kernels, is
  what the paper's evaluation shows as the AMM-family FPS gap.

Independent TPCs additionally split a layer's *position stream*: when a
layer needs fewer weight passes than there are TPCs, the surplus TPCs take
disjoint position ranges of the same passes (every TPC has its own laser
block and DIV path, so this needs no new hardware paths).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List

from ..cnn.layers import LayerSpec
from .photonics import REAGG_SIZE_X, num_comb_switch_pairs


@dataclasses.dataclass(frozen=True)
class TPCConfig:
    """One TPC operating point."""
    org: str                  # "MAM" | "AMM" (layout family)
    n: int                    # VDPE size (wavelengths / MRRs per VDPE)
    m: int                    # VDPEs per TPC (paper: M = N)
    reconfigurable: bool
    x: int = REAGG_SIZE_X

    @property
    def y(self) -> int:
        return num_comb_switch_pairs(self.n, self.x) if self.reconfigurable else 0

    @property
    def shared_div(self) -> bool:
        return self.org == "MAM"


@dataclasses.dataclass(frozen=True)
class PassGroup:
    """A homogeneous group of weight-stationary passes."""
    mode: int                 # 1 or 2
    width: int                # slice width carried per lane
    n_slices: int             # how many S-slices of this width
    lanes: int                # lane-tiles per VDPE (1 or y)
    passes: int               # total TPC passes for this group
    stream_cycles: int        # DIV symbols streamed per pass
    supply_points: int        # fresh DIV points fetched per stream cycle
    active_vdpes: int         # VDPEs with live work per TPC per pass


@dataclasses.dataclass(frozen=True)
class LayerMapping:
    """Full schedule accounting for one layer on one accelerator."""
    layer: LayerSpec
    case: int                 # paper Case 1/2/3 (0 = fixed-N fallback)
    groups: List[PassGroup]
    used_mrr_cycles: int      # MRR-cycles doing useful pointwise products
    active_mrr_cycles: int    # N * (VDPE-cycles of VDPEs holding live work)

    @property
    def utilization(self) -> float:
        """Fig. 6 metric: utilized VDPE area / total active VDPE area."""
        return self.used_mrr_cycles / max(self.active_mrr_cycles, 1)

    @property
    def n_chunks(self) -> int:
        """psum fan-in per final VDP result."""
        return sum(g.n_slices for g in self.groups)

    @property
    def modes(self) -> set:
        return {g.mode for g in self.groups}


def select_case(tpc: TPCConfig, s: int) -> int:
    if tpc.y == 0:
        return 0
    if s >= tpc.n:
        return 1
    if s > tpc.x:
        return 2
    return 3


def slice_plan(tpc: TPCConfig, s: int) -> List[tuple]:
    """Decompose S into (mode, width, count) slice groups.

    The paper advocates "selecting the most appropriate mapping and mode ...
    that can maximize the MRR utilization and processing throughput"
    (Section V-B), so for a sub-N residue r the planner compares the Mode-1
    cost (1 pass-slot) against the Mode-2 cost (ceil(r/x) slices spread over
    y lanes = ceil(r/x)/y pass-slots) and re-aggregates only when Mode 2 is
    at least as cheap — e.g. r = 37 with (x=9, y=4) stays Mode 1 (5 slices >
    4 lanes) while r = 25 re-aggregates (3 slices < 4 lanes).
    """
    plan: List[tuple] = []
    rem = s
    b = rem // tpc.n
    if b:
        plan.append((1, tpc.n, b))
        rem -= b * tpc.n
    if not rem:
        return plan
    if tpc.y > 0 and math.ceil(rem / tpc.x) <= tpc.y:
        bx = rem // tpc.x
        if bx:
            plan.append((2, tpc.x, bx))
            rem -= bx * tpc.x
        if rem:
            plan.append((2, rem, 1))
    else:
        plan.append((1, rem, 1))
    return plan


def map_layer(tpc: TPCConfig, layer: LayerSpec) -> LayerMapping:
    """Map one layer onto one TPC operating point.

    Memoized on (TPCConfig, LayerSpec.canonical()): the mapping depends
    only on the operating point and the layer's shape, and the Figs. 10-11
    sweep re-maps identical pairs len(bit_rates) x len(repeated shapes)
    times otherwise.  The returned LayerMapping is shared — treat it as
    immutable (its embedded spec is the nameless canonical one).
    """
    return _map_layer_cached(tpc, layer.canonical())


@functools.lru_cache(maxsize=65536)
def _map_layer_cached(tpc: TPCConfig, layer: LayerSpec) -> LayerMapping:
    s = layer.dkv_size
    case = select_case(tpc, s)
    ent = layer.n_entities
    p = layer.n_positions
    groups: List[PassGroup] = []
    used = 0
    active = 0

    for mode, width, count in slice_plan(tpc, s):
        lanes = 1 if mode == 1 else tpc.y
        if tpc.shared_div:
            # kernel-parallel: M VDPEs hold distinct kernels iff shared input
            vdpes_eff = tpc.m if layer.shares_div else 1
            kernels_per_pass = vdpes_eff * lanes
            stream = p
            if layer.shares_div:
                supply = width            # one slice pattern for the TPC
            else:
                supply = lanes * width    # y distinct channel patches
            passes = count * math.ceil(ent / kernels_per_pass)
            # utilization accounting
            full, r = divmod(ent, kernels_per_pass)
            used += count * ent * width * stream
            active += count * (full * vdpes_eff
                               + math.ceil(r / lanes)) * tpc.n * stream
        else:
            # position-parallel: kernels broadcast, M positions in parallel
            vdpes_eff = min(tpc.m, p)
            kernels_per_pass = lanes
            stream = math.ceil(p / tpc.m)
            supply = vdpes_eff * width    # M fresh patches per cycle
            passes = count * math.ceil(ent / kernels_per_pass)
            used += count * ent * width * p
            active += count * math.ceil(ent / lanes) * tpc.n * tpc.m * stream
        groups.append(PassGroup(
            mode=mode, width=width, n_slices=count, lanes=lanes,
            passes=passes, stream_cycles=stream, supply_points=supply,
            active_vdpes=vdpes_eff,
        ))
    return LayerMapping(layer=layer, case=case, groups=groups,
                        used_mrr_cycles=used, active_mrr_cycles=active)


# cache controls surface on the public entry point
map_layer.cache_info = _map_layer_cached.cache_info
map_layer.cache_clear = _map_layer_cached.cache_clear


# ---------------------------------------------------------------------------
# Reconfigurable operating points (the planner's per-layer search space)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PointOption:
    """One comb-switch operating point the RCA can retune to between layers.

    ``reconfigurable=False`` is the fixed (N, S) geometry — comb switches
    bypassed, every slice runs Mode 1 — i.e. what a non-reconfigurable MAM
    TPC does; it is the baseline the planner's uplift is measured against.
    """
    x: int = REAGG_SIZE_X
    reconfigurable: bool = True

    @property
    def label(self) -> str:
        return f"x{self.x}" if self.reconfigurable else "fixed"


FIXED_POINT_OPTION = PointOption(reconfigurable=False)


def point_options(n: int, include_fixed: bool = True,
                  ) -> "tuple[PointOption, ...]":
    """Candidate operating points for a VDPE of size ``n``.

    The canonical paper width (REAGG_SIZE_X) leads — on cost ties the
    planner keeps the earliest option, so the default geometry wins —
    followed by the wider retunings ``n // k`` (fewer, wider Mode-2 lanes;
    Eq. 13 gives each its own CS ring FSR), all honoring the ``N >= 2x``
    comb-switch existence constraint.  ``include_fixed`` appends the
    Mode-1-only fixed geometry.
    """
    xs = [REAGG_SIZE_X] + [n // k for k in (2, 3, 4, 6)]
    seen: List[int] = []
    for x in xs:
        if x >= 2 and n >= 2 * x and x not in seen:
            seen.append(x)
    opts = [PointOption(x=x) for x in seen]
    if include_fixed:
        opts.append(FIXED_POINT_OPTION)
    return tuple(opts)


def tpc_at(tpc: TPCConfig, opt: PointOption) -> TPCConfig:
    """The TPC retuned to ``opt`` (same rings, different CS geometry)."""
    return dataclasses.replace(tpc, x=opt.x,
                               reconfigurable=opt.reconfigurable)


def vdpe_utilization_for_s(tpc: TPCConfig, s: int) -> float:
    """Fig. 6: per-VDPE MRR utilization for an isolated DKV of size ``s``.

    Mode-2 lanes beyond a single entity are assumed filled by other entities
    of the same size (the paper plots per-size utilization with packed lanes).
    """
    used = 0.0
    slices = 0
    for mode, width, count in slice_plan(tpc, s):
        lanes = 1 if mode == 1 else tpc.y
        used += count * lanes * width
        slices += count
    return used / (slices * tpc.n)
