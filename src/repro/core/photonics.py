"""Photonic device & link models for MRR-based TPCs (paper Eqs. 9-13, Tables I/IV).

This module reproduces the scalability analysis of Section III-B and the comb
switch (CS) design of Section V-C:

* Eq. 9/10 — the photodetector optical power ``P_PD-opt`` required to resolve
  ``n`` bits at bit rate ``BR`` given shot, thermal, and RIN noise.  We use the
  standard ENOB form  ``n = (SNDR_dB - 1.76) / 6.02`` with noise bandwidth
  ``BR / sqrt(2)`` (the paper's Eq. 9 folds the bandwidth into the denominator
  of the log argument; the OCR'd grouping of the ``-1.76`` term is ambiguous,
  and the standard ENOB placement is the one that reproduces Table II).

* Eq. 11 — the optical link budget that determines the maximum VDPE size ``N``
  (with M = N waveguides per TPC) that still closes at ``P_laser`` = 10 dBm/λ:

      P_laser >= P_PD-opt + IL_EC + IL_SMF + IL_MRM + IL_MRR
                 + (N-1)·OBL_MRR [+ (N-1)·OBL_MRM for AMM]
                 + IL_WG · (N·d_MRR + d_element)
                 + 10·log10(M) + EL_splitter·log2(M)          (1:M power split)
                 + [y·IL_CS for reconfigurable variants]
                 + penalty(BR)

  AMM aggregates first, so every λ passes the full N-ring DIV modulator array
  (out-of-band loss on N-1 foreign rings) *and* sits d_element = 100 µm from
  its DKV array for thermal isolation; MAM modulates per-λ before aggregation
  (no foreign-modulator OBL, d_element = 0) but pays its own network penalty.

  ``penalty(BR) = PENALTY_A + PENALTY_B · log10(BR / 1 GHz)`` is the network
  penalty (extinction ratio, crosstalk, inter-symbol interference, laser RIN
  — Table I calls it IL_penalty).  ISI and crosstalk are physically
  BR-dependent, so we model the penalty as affine in log-BR with one (A, B)
  pair per organization family (MAM-like, AMM-like).  The two pairs are the
  only calibrated constants in the model; they are fitted once so that
  ``max_vdpe_size`` reproduces the paper's Table II for **all 16**
  (organization × bit-rate) cells exactly, and the fit is locked in by
  tests/test_scalability.py::test_table2_exact.

* Eq. 12/13 — DWDM channel spacing Δ = FSR_mod/(N+1) and the comb-switch FSR
  CS_FSR = N·Δ/x.  The CS ring radius follows R = λ²/(2π·n_g·CS_FSR); with
  n_g = 4.36 (group index, fitted to Table IV) and FSR_mod ≈ 44.8 nm this
  reproduces the paper's Table IV radii to within ~3%.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# physical constants
Q_ELECTRON = 1.602176634e-19  # C
K_BOLTZMANN = 1.380649e-23    # J/K
LAMBDA_0_NM = 1550.0          # C-band center wavelength
GROUP_INDEX = 4.36            # n_g fitted to Table IV CS radii
FSR_MOD_NM = 44.8             # modulator-ring FSR implied by Table IV


@dataclasses.dataclass(frozen=True)
class PhotonicParams:
    """Table I device parameters."""
    laser_power_dbm: float = 10.0     # P_Laser per wavelength
    responsivity: float = 1.2         # R, A/W
    load_resistance: float = 50.0     # R_L, ohm
    dark_current: float = 35e-9       # I_d, A
    temperature: float = 300.0        # K
    rin_db_per_hz: float = -140.0     # RIN
    wall_plug_efficiency: float = 0.1  # eta_WPE
    il_smf_db: float = 0.0            # single-mode fiber
    il_ec_db: float = 1.6             # fiber-to-chip coupling
    il_wg_db_per_mm: float = 0.3      # waveguide propagation
    el_splitter_db: float = 0.01      # splitter excess loss per stage
    il_mrm_db: float = 4.0            # microring modulator insertion loss
    obl_mrm_db: float = 0.01          # out-of-band loss past a foreign MRM
    il_mrr_db: float = 0.01           # weight MRR insertion loss
    obl_mrr_db: float = 0.01          # out-of-band loss past a foreign MRR
    d_mrr_um: float = 20.0            # pitch between adjacent MRRs
    pd_sensitivity_dbm: float = -20.0  # Table VII (reference only)

    @property
    def rin_per_hz(self) -> float:
        return 10.0 ** (self.rin_db_per_hz / 10.0)


@dataclasses.dataclass(frozen=True)
class TPCArch:
    """Organization-dependent link-budget terms (Section III-A/B)."""
    name: str
    penalty_a_db: float          # network penalty at BR = 1 Gbps
    penalty_b_db: float          # penalty slope per decade of BR
    d_element_um: float          # DIV<->DKV thermal isolation spacing
    foreign_mrm_obl: bool        # True for AMM (λ passes N-1 foreign MRMs)
    shared_div: bool             # True for MAM (one DIV element per TPC)
    reconfigurable: bool = False  # RAMM / RMAM add comb-switch loss
    il_cs_db: float = 0.030      # per comb-switch-pair insertion loss (Tab. IV)

    def penalty_db(self, br_hz: float) -> float:
        return self.penalty_a_db + self.penalty_b_db * math.log10(br_hz / 1e9)


# Calibrated (A, B) penalty pairs — see module docstring.  The paper's Table I
# quotes IL_penalty = 4.8 dB (MAM) / 5.8 dB (AMM) at its nominal conditions;
# our affine-in-log-BR fit resolves to similar magnitudes once the fixed
# 4.30 dB margin of the original single-constant model is folded in.
_MAM_PENALTY = (4.8 + 3.35, -0.33)   # = (8.15, -0.33)
_AMM_PENALTY = (5.8 + 3.70, -0.50)   # = (9.50, -0.50)

MAM = TPCArch("MAM", *_MAM_PENALTY, d_element_um=0.0, foreign_mrm_obl=False,
              shared_div=True)
AMM = TPCArch("AMM", *_AMM_PENALTY, d_element_um=100.0, foreign_mrm_obl=True,
              shared_div=False)
RMAM = dataclasses.replace(MAM, name="RMAM", reconfigurable=True)
RAMM = dataclasses.replace(AMM, name="RAMM", reconfigurable=True)
# CROSSLIGHT is an AMM-family design with thermo-optic weight tuning (§VI-A);
# link budget behaves like AMM, the TO tuning penalty is paid in time/power by
# the simulator (core/energy.py), not in optical loss.
CROSSLIGHT = dataclasses.replace(AMM, name="CROSSLIGHT")

ARCHS = {a.name: a for a in (MAM, AMM, RMAM, RAMM, CROSSLIGHT)}

#: Re-aggregation size (paper Section V-B: most common smallest DKV size).
REAGG_SIZE_X = 9


def db_to_lin(db: float) -> float:
    return 10.0 ** (db / 10.0)


def lin_to_db(lin: float) -> float:
    return 10.0 * math.log10(lin)


def dbm_to_watt(dbm: float) -> float:
    return 1e-3 * 10.0 ** (dbm / 10.0)


def watt_to_dbm(watt: float) -> float:
    return 10.0 * math.log10(watt / 1e-3)


# ---------------------------------------------------------------------------
# Eq. 9 / Eq. 10 — photodetector precision vs received optical power
# ---------------------------------------------------------------------------

class InfeasiblePrecisionError(ValueError):
    """A (bits, BR) operating point whose Eq. 9 SNR budget cannot close.

    Raised instead of silently degrading to a noise-free/zero-sigma model:
    above the RIN ceiling no received power resolves the requested
    precision, so any computation claiming that point is fiction.
    """

    def __init__(self, bits: float, br_hz: float, detail: str = ""):
        msg = (f"{bits}-bit precision is not achievable at "
               f"{br_hz / 1e9:g} Gbps under the Eq. 9 SNR budget"
               f"{': ' + detail if detail else ''}")
        super().__init__(msg)
        self.bits = bits
        self.br_hz = br_hz


def noise_current_rms(p: PhotonicParams, pd_power_w: float, br_hz: float) -> float:
    """Eq. 10 noise (A, rms) integrated over noise bandwidth BR/sqrt(2)."""
    bw = br_hz / math.sqrt(2.0)
    shot = 2.0 * Q_ELECTRON * (p.responsivity * pd_power_w + p.dark_current)
    thermal = 4.0 * K_BOLTZMANN * p.temperature / p.load_resistance
    rin = (p.responsivity * pd_power_w) ** 2 * p.rin_per_hz
    return math.sqrt((shot + thermal + rin) * bw)


def achievable_bits(p: PhotonicParams, pd_power_w: float, br_hz: float) -> float:
    """Eq. 9: ENOB at the balanced PD for a given received optical power."""
    signal = p.responsivity * pd_power_w
    noise = noise_current_rms(p, pd_power_w, br_hz)
    sndr_db = 20.0 * math.log10(signal / noise)
    return (sndr_db - 1.76) / 6.02


def pd_power_for_precision(
    p: PhotonicParams, n_bits: float, br_hz: float,
    p_lo: float = 1e-12, p_hi: float = 10.0,
) -> Optional[float]:
    """Invert Eq. 9: minimum P_PD-opt (W) for ``n_bits`` at ``br_hz``.

    Returns None when the RIN-imposed SNR ceiling makes the precision
    unattainable at any power (e.g. 8-bit at 10 Gbps).
    """
    # RIN ceiling: lim P->inf  signal/noise = 1 / sqrt(RIN * bw)
    bw = br_hz / math.sqrt(2.0)
    ceiling_bits = (20.0 * math.log10(1.0 / math.sqrt(p.rin_per_hz * bw)) - 1.76) / 6.02
    if n_bits >= ceiling_bits:
        return None
    if achievable_bits(p, p_hi, br_hz) < n_bits:
        return None
    lo, hi = p_lo, p_hi
    for _ in range(200):
        mid = math.sqrt(lo * hi)  # geometric bisection over decades
        if achievable_bits(p, mid, br_hz) >= n_bits:
            hi = mid
        else:
            lo = mid
    return hi


def integer_noise_sigma_lsb(p: PhotonicParams, n_bits: int,
                            br_hz: float) -> float:
    """Eq. 9/10 PD noise as an integer-domain sigma, in LSBs.

    At the minimum received power that resolves ``n_bits`` (Eq. 9
    inverted), the Eq. 10 noise current maps onto the integer lattice
    through the LSB current step — the signal swing divided into
    ``2**n_bits - 1`` levels.  This is the per-summation-element sigma the
    analog noise model (core/vdp.noisy_vdp_gemm) and the serving stack's
    ANALOG_NOISE fault injection both derive from.

    Raises :class:`InfeasiblePrecisionError` when the RIN ceiling makes
    the precision unattainable at any power (pd_power_for_precision
    returns None) — the old behavior of silently reporting sigma 0.0
    meant an infeasible point masqueraded as a *noise-free* one.
    """
    pd_w = pd_power_for_precision(p, n_bits, br_hz)
    if pd_w is None:
        raise InfeasiblePrecisionError(
            n_bits, br_hz, "RIN ceiling exceeded at any received power")
    noise_a = noise_current_rms(p, pd_w, br_hz)
    signal_a = p.responsivity * pd_w
    lsb = signal_a / (2 ** n_bits - 1)
    return noise_a / lsb


# ---------------------------------------------------------------------------
# Eq. 11 — optical link budget -> maximum VDPE size N
# ---------------------------------------------------------------------------

def num_comb_switch_pairs(n: int, x: int = REAGG_SIZE_X) -> int:
    """y = N >= 2x ? floor(N/x) : 0   (paper Section V-A)."""
    return n // x if n >= 2 * x else 0


def link_loss_db(
    p: PhotonicParams, arch: TPCArch, n: int,
    br_hz: float = 1e9, m: Optional[int] = None,
) -> float:
    """Total optical loss (dB) from laser to PD for VDPE size ``n`` (Eq. 11)."""
    if m is None:
        m = n  # paper's analysis uses M = N
    wg_len_mm = (n * p.d_mrr_um + arch.d_element_um) * 1e-3
    loss = (
        p.il_smf_db
        + p.il_ec_db
        + p.il_mrm_db                       # the λ's own input modulator
        + p.il_mrr_db                       # the λ's own weight ring
        + (n - 1) * p.obl_mrr_db            # past N-1 foreign weight rings
        + p.il_wg_db_per_mm * wg_len_mm
        + arch.penalty_db(br_hz)
    )
    if m > 1:
        loss += lin_to_db(m)                # intrinsic 1:M power split
        loss += p.el_splitter_db * math.log2(m)
    if arch.foreign_mrm_obl:
        loss += (n - 1) * p.obl_mrm_db      # AMM: past N-1 foreign modulators
    if arch.reconfigurable:
        loss += num_comb_switch_pairs(n) * arch.il_cs_db
    return loss


def max_vdpe_size(
    p: PhotonicParams,
    arch: TPCArch,
    n_bits: float,
    br_hz: float,
    n_max: int = 4096,
) -> int:
    """Largest N (with M = N) whose link budget closes at P_laser (Eq. 11).

    Returns 0 when even N = 1 cannot close (paper reports such cells as
    "cannot support any N").
    """
    pd_w = pd_power_for_precision(p, n_bits, br_hz)
    if pd_w is None:
        return 0
    pd_dbm = watt_to_dbm(pd_w)
    budget_db = p.laser_power_dbm - pd_dbm
    best = 0
    for n in range(1, n_max + 1):
        if link_loss_db(p, arch, n, br_hz) <= budget_db:
            best = n
        else:
            break  # loss is monotone in N
    return best


def received_power_dbm(
    p: PhotonicParams, arch: TPCArch, n: int, br_hz: float,
) -> float:
    """Optical power (dBm) reaching the PD for VDPE size ``n`` (Figs. 4-5)."""
    return p.laser_power_dbm - link_loss_db(p, arch, n, br_hz)


def laser_wallplug_power_w(p: PhotonicParams, n_lambda: int) -> float:
    """Electrical wall-plug power of the laser block for ``n_lambda`` diodes."""
    return n_lambda * dbm_to_watt(p.laser_power_dbm) / p.wall_plug_efficiency


# ---------------------------------------------------------------------------
# Eq. 12 / Eq. 13 — comb-switch spectral design (Table IV)
# ---------------------------------------------------------------------------

def channel_spacing_nm(n: int, fsr_mod_nm: float = FSR_MOD_NM) -> float:
    """Eq. 12: Δ = FSR / (N+1)."""
    return fsr_mod_nm / (n + 1)


def comb_switch_fsr_nm(n: int, x: int = REAGG_SIZE_X,
                       fsr_mod_nm: float = FSR_MOD_NM) -> float:
    """Eq. 13: CS_FSR = N·Δ/x."""
    return n * channel_spacing_nm(n, fsr_mod_nm) / x


def comb_switch_radius_um(cs_fsr_nm: float,
                          lambda_nm: float = LAMBDA_0_NM,
                          group_index: float = GROUP_INDEX) -> float:
    """Ring radius for a target FSR: R = λ² / (2π · n_g · FSR)."""
    lam_m = lambda_nm * 1e-9
    fsr_m = cs_fsr_nm * 1e-9
    return lam_m * lam_m / (2.0 * math.pi * group_index * fsr_m) * 1e6


@dataclasses.dataclass(frozen=True)
class CombSwitchDesign:
    """One Table IV row: the CS design for a given (arch, BR) operating point."""
    n: int
    x: int
    y: int                      # number of CS pairs
    cs_fsr_nm: float
    radius_um: float
    insertion_loss_db: float


def design_comb_switch(n: int, x: int = REAGG_SIZE_X,
                       il_cs_db: float = 0.030) -> CombSwitchDesign:
    y = num_comb_switch_pairs(n, x)
    fsr = comb_switch_fsr_nm(n, x)
    return CombSwitchDesign(
        n=n, x=x, y=y, cs_fsr_nm=fsr,
        radius_um=comb_switch_radius_um(fsr),
        insertion_loss_db=il_cs_db if y > 0 else 0.0,
    )
