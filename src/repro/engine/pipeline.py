"""Whole-model jitted pipeline: one XLA dispatch per served batch.

``engine.forward`` walks a plan's layers in a Python loop — one kernel
dispatch plus quantize round-trip per layer, ~L host round-trips per
served batch.  The hardware analogue pays none of that: once DKVs are
imprinted, DIV streams flow through the layer sequence with no dead time.
This module closes the gap on the serving hot path:

    forward_jit(plan, xb)  ->  one jitted callable per (plan, batch bucket)

The callable traces the *entire* layer chain — the quantized-domain
implicit-GEMM conv kernels (input-DAC absmax/quantize fused into the
kernel prologues), the depthwise VPU path, the double-buffered q8 FC
GEMMs, fused dequant epilogues — into a single XLA program, so a served
batch is one dispatch instead of ~L.
Inter-layer activations are XLA temporaries (never returned to the host),
and on accelerator backends the input batch buffer is donated to the
computation; the CPU backend ignores donation, so it is gated off there to
keep test logs clean.

Batch sizes are bucketed to the next power of two: the dynamic batcher
produces ragged final batches, and compiling per exact size would turn
every straggler into a compile stall.  Padding images are all-zero; since
quantization is per image and GEMM rows/grid instances are per image, the
real images' outputs are bit-identical to the unbucketed call (asserted in
tests/test_implicit_conv.py).

The pipeline cache is memoized on the plan object (like plan.get_plan's
pack cache, but keyed by identity — a plan's arrays are the identity of
its imprint), and ``_STATS["compiles"]`` counts actual retraces: a
(plan, bucket) pair compiles exactly once, every later batch in that
bucket reuses the executable.  The serving registry evicts a plan's
pipelines with its imprint (``evict``).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import executor
from .plan import ModelPlan

#: Resident pipeline bound: beyond this many plans the least-recently-used
#: entry (its strong plan reference AND its compiled executables) is
#: dropped, so code that compiles plans outside a PlanRegistry — tests,
#: benchmarks, notebooks — cannot pin every imprint it ever served for
#: process lifetime.  Generous next to any registry capacity.
CACHE_CAPACITY = 16

# id(plan) -> (plan, interpret -> jitted fn), LRU-ordered; the strong plan
# reference pins the id for the entry's lifetime (no reuse-after-free key
# aliasing).
_PIPELINES: "OrderedDict[int, Tuple[ModelPlan, Dict[bool, Callable]]]" = (
    OrderedDict())
_STATS = {"hits": 0, "misses": 0, "compiles": 0, "evictions": 0,
          "dispatches": 0}
# (plan name, batch bucket) -> served-dispatch count; the obs layer reads
# this to show which compiled buckets actually carry serving traffic
_DISPATCH_COUNTS: Dict[Tuple[str, int], int] = {}
# the sharded dispatcher serves shards from a thread pool; cache lookups,
# insertions and LRU reordering must not interleave (jit itself is
# thread-safe — only this bookkeeping needs the lock)
_LOCK = threading.RLock()


def batch_bucket(b: int) -> int:
    """Smallest power of two >= b (the compile-shape bucket)."""
    assert b >= 1, b
    bucket = 1
    while bucket < b:
        bucket *= 2
    return bucket


def _layer_params(plan: ModelPlan) -> tuple:
    """The plan's device arrays, passed as jit arguments (not baked into
    the executable as constants — the imprint stays a buffer, the traced
    program stays small).  Per-layer operating points stay *static*: each
    LayerPlan keeps its own ``point``, so a pipeline executable is keyed
    on the plan's whole per-layer point sequence (a planner-compiled plan
    and a fixed-point plan of the same model trace separately)."""
    return tuple((lp.rhs, lp.w_scale, lp.bias) for lp in plan.layers)


def _build(plan: ModelPlan, interpret: bool) -> Callable:
    def run(params, xb):
        _STATS["compiles"] += 1   # trace-time side effect: counts retraces
        x = xb
        for lp, (rhs, w_scale, bias) in zip(plan.layers, params):
            lp = dataclasses.replace(lp, rhs=rhs, w_scale=w_scale,
                                     bias=bias)
            x = executor.forward_layer(plan, lp, x, interpret=interpret)
        return x

    donate = () if jax.default_backend() == "cpu" else (1,)
    return jax.jit(run, donate_argnums=donate)


def get_pipeline(plan: ModelPlan, interpret: bool | None = None) -> Callable:
    """The plan's jitted whole-model callable (built once per plan).

    jit's own shape cache provides the per-bucket memo: the first batch in
    a bucket traces+compiles (``pipeline_cache_info()["compiles"]`` ticks),
    every later one reuses the executable.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    with _LOCK:
        entry = _PIPELINES.get(id(plan))
        if entry is not None and entry[0] is plan:
            _PIPELINES.move_to_end(id(plan))
            fns = entry[1]
            if interpret in fns:
                _STATS["hits"] += 1
                return fns[interpret]
        else:
            fns = {}
            _PIPELINES[id(plan)] = (plan, fns)
            while len(_PIPELINES) > CACHE_CAPACITY:
                _PIPELINES.popitem(last=False)
                _STATS["evictions"] += 1
        _STATS["misses"] += 1
        fns[interpret] = _build(plan, interpret)
        return fns[interpret]


def forward_jit(plan: ModelPlan, x: jax.Array,
                interpret: bool | None = None) -> jax.Array:
    """Serve a batch through the whole-model jitted pipeline.

    x: NHWC batch (B, H, W, D), or (B, S) rows for FC-first plans.  The
    batch is zero-padded to its power-of-two bucket and the pad rows are
    sliced away after the single dispatch; outputs for the real images are
    bit-identical to ``forward`` (and therefore to the im2col oracle).
    """
    if x.ndim not in (2, 4):
        raise ValueError(
            f"forward_jit serves batches: expected (B, H, W, D) or (B, S), "
            f"got shape {tuple(x.shape)}")
    fn = get_pipeline(plan, interpret)
    b = x.shape[0]
    bucket = batch_bucket(b)
    with _LOCK:
        _STATS["dispatches"] += 1
        key = (plan.name, bucket)
        _DISPATCH_COUNTS[key] = _DISPATCH_COUNTS.get(key, 0) + 1
    if bucket != b:
        pad = [(0, bucket - b)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad)                   # fresh buffer: safe to donate
    elif jax.default_backend() != "cpu":
        # donation consumes the argument buffer; an exact-bucket batch
        # would hand the CALLER's array to XLA, so keep theirs alive and
        # donate a copy instead (the pad path above already owns its
        # buffer; the CPU backend ignores donation entirely)
        x = jnp.array(x, copy=True)
    out = fn(_layer_params(plan), x)
    return out[:b]


# ---------------------------------------------------------------------------
# Guarded pipeline: the SDC corruption/detection path, whole-model jitted
# ---------------------------------------------------------------------------

def _build_guarded(plan: ModelPlan,
                   policy: executor.IntegrityPolicy) -> Callable:
    """Jit the guarded layer chain (executor.forward_layer_guarded).

    The weight-imprint goldens are computed HERE, from the pristine plan
    arrays, and baked into the traced program as Python int constants —
    the comparison point a corrupted resident imprint is caught against.
    Corruption parameters are jit *arguments* (CorruptionArgs), so one
    executable serves clean and corrupted dispatches alike.  No donation:
    the dispatcher may retry the same batch buffer after a detection.
    """
    goldens = tuple(int(executor.weight_imprint_checksum(lp.rhs))
                    for lp in plan.layers)

    def run(params, xb, cargs):
        _STATS["compiles"] += 1
        x = xb
        flags = []
        for i, (lp, (rhs, w_scale, bias)) in enumerate(zip(plan.layers,
                                                           params)):
            lp = dataclasses.replace(lp, rhs=rhs, w_scale=w_scale,
                                     bias=bias)
            check = policy.check_every > 0 and i % policy.check_every == 0
            x, fl = executor.forward_layer_guarded(
                plan, lp, x, cargs, salt=i, check=check, policy=policy,
                golden=goldens[i])
            flags.append(fl)
        return x, jnp.stack(flags)

    return jax.jit(run)


def get_guarded_pipeline(plan: ModelPlan,
                         policy: executor.IntegrityPolicy =
                         executor.DEFAULT_POLICY) -> Callable:
    """The plan's guarded jitted callable, memoized beside the plain one.

    Shares the LRU pipeline store (same eviction lifetime as the plain
    executables); the fns dict keys guarded variants by their (hashable)
    policy, so different cadences coexist.
    """
    with _LOCK:
        entry = _PIPELINES.get(id(plan))
        if entry is not None and entry[0] is plan:
            _PIPELINES.move_to_end(id(plan))
            fns = entry[1]
            key = ("guarded", policy)
            if key in fns:
                _STATS["hits"] += 1
                return fns[key]
        else:
            fns = {}
            _PIPELINES[id(plan)] = (plan, fns)
            while len(_PIPELINES) > CACHE_CAPACITY:
                _PIPELINES.popitem(last=False)
                _STATS["evictions"] += 1
            key = ("guarded", policy)
        _STATS["misses"] += 1
        fns[key] = _build_guarded(plan, policy)
        return fns[key]


def forward_jit_guarded(plan: ModelPlan, x: jax.Array,
                        cargs: Optional[executor.CorruptionArgs] = None,
                        policy: executor.IntegrityPolicy =
                        executor.DEFAULT_POLICY,
                        params: Optional[tuple] = None,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Serve a batch through the guarded pipeline.

    Returns (outputs, flags): outputs as ``forward_jit`` (bit-identical to
    it when ``cargs`` is null and ``params`` are the plan's own — asserted
    in tests/test_sdc.py), flags an (L,) int32 vector of per-layer
    detector bitmasks (executor.DET_*; all zero on a clean dispatch).
    ``params`` overrides the resident weight arrays — the STUCK_MRR
    injection point (engine.corrupted_layer_params builds a corrupted
    imprint) — and defaults to the plan's pristine arrays.
    """
    if x.ndim not in (2, 4):
        raise ValueError(
            f"forward_jit_guarded serves batches: expected (B, H, W, D) or "
            f"(B, S), got shape {tuple(x.shape)}")
    if cargs is None:
        cargs = executor.null_corruption_args()
    fn = get_guarded_pipeline(plan, policy)
    b = x.shape[0]
    bucket = batch_bucket(b)
    with _LOCK:
        _STATS["dispatches"] += 1
        key = (plan.name, bucket)
        _DISPATCH_COUNTS[key] = _DISPATCH_COUNTS.get(key, 0) + 1
    if bucket != b:
        pad = [(0, bucket - b)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, pad)
    out, flags = fn(params if params is not None else _layer_params(plan),
                    x, cargs)
    return out[:b], flags


def corrupted_layer_params(plan: ModelPlan, seed: int,
                           stuck_rings: int) -> tuple:
    """A copy of the plan's packed weight imprint with stuck MRR elements.

    Models STUCK_MRR: ``stuck_rings`` weight elements (uniformly random
    over layers and positions under ``seed``) are pinned to full
    transmission (+qmax; an already-+qmax element flips to -qmax so the
    corruption is never a no-op on the stored value).  Deterministic:
    (plan, seed, stuck_rings) always corrupts the same elements.  Feed the
    result to ``forward_jit_guarded(..., params=...)`` — ABFT cannot see
    this fault (the GEMM faithfully computes with the wrong weights); the
    weight-imprint checksum is the detector that catches it.
    """
    rng = np.random.default_rng(seed)
    rhss = [np.array(lp.rhs) for lp in plan.layers]
    for _ in range(max(0, int(stuck_rings))):
        li = int(rng.integers(len(rhss)))
        flat = rhss[li].reshape(-1)
        idx = int(rng.integers(flat.size))
        qmax = 2 ** (plan.layers[li].point.bits - 1) - 1
        flat[idx] = -qmax if flat[idx] == qmax else qmax
    return tuple((jnp.asarray(r), lp.w_scale, lp.bias)
                 for r, lp in zip(rhss, plan.layers))


def evict(plan: ModelPlan) -> None:
    """Drop a plan's compiled pipelines (the registry's LRU eviction hook —
    without it the pipeline cache would pin evicted imprints forever)."""
    with _LOCK:
        _PIPELINES.pop(id(plan), None)


def pipeline_cache_info() -> Dict[str, int]:
    return dict(_STATS, size=len(_PIPELINES))


def pipeline_dispatch_counts() -> Dict[Tuple[str, int], int]:
    """Served dispatches per (plan name, batch bucket)."""
    with _LOCK:
        return dict(_DISPATCH_COUNTS)


def pipeline_cache_clear() -> None:
    _PIPELINES.clear()
    _DISPATCH_COUNTS.clear()
    for k in _STATS:
        _STATS[k] = 0
