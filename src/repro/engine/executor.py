"""Forward execution of pre-packed weight-stationary plans.

The per-call work is exactly what the hardware pays per frame: quantize the
activations (the input DACs) and stream their DIV patches against the
resident DKV state.  Weight-side padding/packing happened once at plan
compile time; the dequant-scale + bias + activation epilogue is fused into
the Pallas kernels, so the int32 accumulators never round-trip HBM.

Two execution paths, one numerics contract:

* **Implicit-GEMM (default, the serving hot path).**  ``forward`` /
  ``forward_layer`` route SC/PC conv layers to the implicit-GEMM Pallas
  kernels (kernels/vdpe_conv.py): the quantized NHWC activation goes to
  the kernel at its natural (B, Hp, Wp, D) size and the K*K patch taps are
  gathered *inside* the kernel — the (B, P, K*K*D) im2col DIV matrix never
  exists in HBM (a K^2x peak-activation saving for K>1).  Depthwise layers
  run the same windowed gather as a per-channel VPU contraction in plain
  jnp; FC layers have no spatial structure and fall through to the GEMM
  path.  ``layer_route`` reports the routing per layer.

* **im2col -> GEMM (the bitwise oracle).**  ``forward_im2col`` /
  ``forward_layer_im2col`` keep the historical materialized-DIV path next
  to kernels/ref.py's oracles; tests/test_implicit_conv.py asserts the two
  paths are bit-identical across all layer kinds, strides, paddings and
  batch shapes, and benchmarks/kernel_bench.py tracks their wall-clock and
  peak-HBM gap.

Bitwise identity holds because every step matches elementwise: the
per-image quantization scale is the max |activation| over exactly the
patch-covered window set (computed windowed here, equal to the im2col
matrix max — SAME-padding zeros never raise a max), integer tap-sum
accumulation is associative, and both fused epilogues apply the identical
``act(acc * scale + bias)`` expression (kernels/common.apply_act).

Batching (the serving runtime's path): both paths accept a single image
(H, W, D) or an NHWC batch (B, H, W, D).  Quantization stays *per image*
(each frame gets its own input-DAC swing); the implicit-conv kernels take
the per-image scales through a grid-indexed SMEM epilogue, the GEMM path
through per-row scale columns (kernels/vdpe_gemm.py).  For the whole-model
jitted pipeline that chases the per-layer Python dispatch out of this
loop, see engine/pipeline.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind
from ..core import vdp
from ..kernels import ops, ref
from ..kernels import vdpe_conv as kconv
from ..kernels import vdpe_gemm as kern
from ..kernels.common import round_up as _round_up
from .plan import (LayerPlan, MODE_DENSE, MODE_DEPTHWISE, MODE_PACKED,
                   ModelPlan)

#: layer_route values, in routing-priority order.
ROUTE_FC_GEMM = "fc_gemm"
ROUTE_DEPTHWISE = "depthwise_vpu"
ROUTE_CONV_ZS = "conv_implicit_mode2_zs"
ROUTE_CONV_M1 = "conv_implicit_mode1"


def layer_route(lp: LayerPlan) -> str:
    """Which execution path ``forward_layer`` takes for this layer."""
    if lp.kind is ConvKind.FC:
        return ROUTE_FC_GEMM
    if lp.mode == MODE_DEPTHWISE:
        return ROUTE_DEPTHWISE
    return ROUTE_CONV_ZS if lp.mode == MODE_PACKED else ROUTE_CONV_M1


# ---------------------------------------------------------------------------
# Shared activation-side helpers
# ---------------------------------------------------------------------------

def _stable_scale(x: jax.Array) -> jax.Array:
    """Pin a DAC scale against XLA algebraic reassociation.

    The per-image scale is ``absmax * (1/qmax)`` with 1/qmax a compile-time
    constant; under the whole-model jit XLA's simplifier reassociates its
    later multiply by the weight scale — ``(m * c) * w -> m * (c * w)`` —
    which shifts the epilogue scale by 1 ulp and lets the next layer's
    quantizer round() amplify that into integer flips.  Eager execution
    never reassociates, so the two regimes would disagree bitwise.  An
    optimization barrier freezes the association on both sides.
    """
    return jax.lax.optimization_barrier(x)


def _pad_spatial(x4: jax.Array, k: int, stride: int,
                 padding: str) -> jax.Array:
    """SAME/VALID spatial zero-padding, split exactly as vdp.im2col does."""
    if padding != "SAME":
        return x4
    _, h, w, _ = x4.shape
    ho, wo = vdp.out_hw(h, w, k, stride, padding)
    pad_h = max((ho - 1) * stride + k - h, 0)
    pad_w = max((wo - 1) * stride + k - w, 0)
    return jnp.pad(x4, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))


def _window_absmax(x4p: jax.Array, k: int, stride: int, ho: int, wo: int,
                   per_channel: bool) -> jax.Array:
    """max |x| over the patch-covered pixel set, per image (and channel).

    Identical to the im2col-matrix max: the taps enumerate exactly the
    pixels the DIV matrix replicates (a strided layer can leave border
    pixels uncovered, so the whole-image max would be *wrong* — the
    covered-set max is what keeps this path bitwise-equal to the oracle).
    """
    axes = (1, 2) if per_channel else (1, 2, 3)
    m = None
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = jnp.abs(kconv.tap_window(x4p, di, dj, stride, ho, wo))
        wm = jnp.max(win, axis=axes)
        m = wm if m is None else jnp.maximum(m, wm)
    return m                      # (B,) or (B, D)


def _im2col_batch(x4: jax.Array, k: int, stride: int,
                  padding: str) -> jax.Array:
    """(B, H, W, D) -> (B, P, K*K*D): per-image DIV streams, stacked."""
    return jax.vmap(lambda im: vdp.im2col(im, k, stride, padding))(x4)


def _quantize_per_image(divs: jax.Array, bits: int,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-image symmetric quantization of (B, P, S) DIV streams.

    Each image keeps its own input-DAC swing — identical to running
    vdp.quantize_symmetric on every image separately (max is exact, the
    divide/round/clip are elementwise), which is what makes the folded
    batch bit-identical to the per-image loop.
    """
    qmax = 2 ** (bits - 1) - 1
    scale = _stable_scale(jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)),
                                      1e-12) * vdp.inv_qmax(bits))
    q = jnp.clip(jnp.round(divs / scale[:, None, None]),
                 -qmax, qmax).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Implicit-GEMM conv path (no materialized im2col)
# ---------------------------------------------------------------------------

def _forward_conv_implicit(lp: LayerPlan, x4: jax.Array, point,
                           interpret: bool) -> jax.Array:
    """SC/PC layer through the implicit-GEMM kernels (Mode 1 or 2)."""
    b, h, w, din = x4.shape
    k = lp.k
    d = lp.s // (k * k)
    if d != din:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {k * k * din}")
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    qmax = 2 ** (point.bits - 1) - 1
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=False),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B,)
    x_q = jnp.clip(jnp.round(x4p / a_scale[:, None, None, None]),
                   -qmax, qmax).astype(jnp.int8)
    scale = a_scale * lp.w_scale
    # one image rides the scalar-SMEM epilogue; a batch carries per-image
    # scales through the grid-indexed SMEM variant
    scale_arg = scale[0] if b == 1 else scale
    if lp.mode == MODE_PACKED:
        out = kconv.vdpe_pack_conv_zs(
            x_q, lp.rhs, k, lp.stride, ho, wo, x=point.x,
            block_o=point.block_o, interpret=interpret,
            scale=scale_arg, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        out = kconv.vdpe_conv(
            x_q, lp.rhs, k, lp.stride, ho, wo, block_o=point.block_o,
            interpret=interpret, scale=scale_arg, bias=lp.bias, act=lp.act)
    return out[:, :, :lp.f].reshape(b, ho, wo, lp.f)


def _forward_depthwise(lp: LayerPlan, x4: jax.Array, point) -> jax.Array:
    """Per-channel VPU path, windowed — no materialized (B, P, K*K, D).

    Depthwise kernels pair channel c's patches with channel c's single DKV
    row, so the contraction degenerates to K*K tap-wise multiply-adds over
    the strided windows.  Quantization is per image AND per channel (each
    channel of each frame is an independent VDP), matching
    core/vdp.depthwise_conv2d_vdp bit-for-bit: same covered-set max, and
    the integer tap sum equals the einsum's contraction exactly.
    """
    b, h, w, d = x4.shape
    k = lp.k
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    qmax = 2 ** (point.bits - 1) - 1
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=True),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B, D)
    x_q = jnp.clip(jnp.round(x4p / a_scale[:, None, None, :]),
                   -qmax, qmax).astype(jnp.int32)
    acc = jnp.zeros((b, ho, wo, d), jnp.int32)
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = kconv.tap_window(x_q, di, dj, lp.stride, ho, wo)
        acc = acc + win * lp.rhs[:, kk].astype(jnp.int32)[None, None, None]
    return ref.epilogue_ref(
        acc, (a_scale * lp.w_scale[None, :])[:, None, None, :],
        None if lp.bias is None else lp.bias[None, None, None, :],
        lp.act)


def forward_layer(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """One layer through its pre-packed kernel with the fused epilogue.

    x: (H, W, D) or batched (B, H, W, D) for conv layers; a flat feature
    vector, (H, W, D) map, batched rows (B, S) or batched maps for FC.
    Conv layers run the implicit-GEMM path (module docstring); FC falls
    through to the GEMM path.  Batched outputs are bit-identical to the
    per-image loop AND to forward_layer_im2col.

    Each layer executes at its *own* operating point (``lp.point``):
    planner-compiled plans carry heterogeneous per-layer packing geometry
    while fixed-point plans repeat the model point.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = lp.point
    if lp.kind is not ConvKind.FC:
        batched = x.ndim == 4
        x4 = x if batched else x[None]
        if lp.mode == MODE_DEPTHWISE:
            out = _forward_depthwise(lp, x4, point)
        else:
            out = _forward_conv_implicit(lp, x4, point, interpret)
        return out if batched else out[0]
    return _forward_fc(plan, lp, x, interpret)


def _forward_fc(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                interpret: bool) -> jax.Array:
    """FC layer: flatten to (B, S) rows and run the GEMM path."""
    point = lp.point
    if x.ndim == 4:                       # batched feature maps
        flat = x.reshape(x.shape[0], -1)
    elif x.ndim == 2:                     # rows are already the batch
        flat = x
    else:                                 # single map / vector -> (1, S)
        flat = x.reshape(1, -1)
    if flat.shape[1] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {flat.shape[1]}")
    divs_q, a_scale = _quantize_per_image(flat[:, None, :], point.bits)
    b = flat.shape[0]
    lhs = divs_q.reshape(b, lp.s)
    bp = _round_up(b, point.block_b)
    scale = a_scale * lp.w_scale
    if b == 1:
        scale_rows = scale[0]
    else:
        scale_rows = jnp.pad(scale, (0, bp - b))
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(lhs, ((0, bp - b), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale_rows, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(lhs, ((0, bp - b), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale_rows, bias=lp.bias, act=lp.act)
    return out[:b, :lp.f]                 # FC single image stays (1, F)


def forward(plan: ModelPlan, x: jax.Array,
            interpret: bool | None = None) -> jax.Array:
    """Run activations through every layer of a compiled plan (eager loop).

    Accepts one image (H, W, D) or an NHWC batch (B, H, W, D); batched
    outputs are bit-identical to looping `forward` over the images.  This
    is one Python dispatch per layer — the serving hot path uses the
    whole-model jitted pipeline instead (engine.forward_jit).
    """
    for lp in plan.layers:
        x = forward_layer(plan, lp, x, interpret=interpret)
    return x


# ---------------------------------------------------------------------------
# im2col -> GEMM path: the historical bitwise oracle
# ---------------------------------------------------------------------------

def _forward_depthwise_im2col(lp: LayerPlan, x4: jax.Array,
                              point) -> jax.Array:
    """Depthwise oracle: materialized (B, P, K*K, D) + einsum contraction."""
    b, h, w, d = x4.shape
    k = lp.k
    qmax = 2 ** (point.bits - 1) - 1
    divs = _im2col_batch(x4, k, lp.stride, lp.padding)    # (B, P, K*K*D)
    p = divs.shape[1]
    divs = divs.reshape(b, p, k * k, d)
    a_scale = _stable_scale(jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)),
                                        1e-12)
                            * vdp.inv_qmax(point.bits))      # (B, D)
    divs_q = jnp.clip(jnp.round(divs / a_scale[:, None, None, :]),
                      -qmax, qmax).astype(jnp.int8)
    acc = jnp.einsum("bpkc,ck->bpc", divs_q.astype(jnp.int32),
                     lp.rhs.astype(jnp.int32))
    r = ref.epilogue_ref(acc, (a_scale * lp.w_scale[None, :])[:, None, :],
                         None if lp.bias is None else lp.bias[None, None, :],
                         lp.act)
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    return r.reshape(b, ho, wo, d)


def forward_layer_im2col(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                         interpret: bool | None = None) -> jax.Array:
    """One layer through the materialized im2col -> GEMM path.

    The pre-implicit-GEMM execution path, kept verbatim as the bitwise
    oracle (and kernel_bench baseline) for forward_layer: it builds the
    full (B, P, K*K*D) DIV matrix in HBM and folds the batch into one GEMM
    position stream with per-row dequant scales.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = lp.point

    if lp.kind is ConvKind.FC:
        return _forward_fc(plan, lp, x, interpret)
    batched = x.ndim == 4
    x4 = x if batched else x[None]
    if lp.mode == MODE_DEPTHWISE:
        out = _forward_depthwise_im2col(lp, x4, point)
        return out if batched else out[0]
    divs = _im2col_batch(x4, lp.k, lp.stride, lp.padding)  # (B, P, S)
    spatial = vdp.out_hw(x4.shape[1], x4.shape[2], lp.k, lp.stride,
                         lp.padding)
    if divs.shape[2] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {divs.shape[2]}")
    b, p, _ = divs.shape
    divs_q, a_scale = _quantize_per_image(divs, point.bits)
    lhs = divs_q.reshape(b * p, lp.s)
    bp = b * p
    pp = _round_up(bp, point.block_b)
    # fold the batch into the position stream; each image's rows carry its
    # own dequant scale into the fused epilogue.  One image has one scale,
    # so it rides the cheaper scalar-SMEM epilogue path.
    scale = a_scale * lp.w_scale
    if b == 1:
        scale_rows = scale[0]
    else:
        scale_rows = jnp.pad(jnp.repeat(scale, p), (0, pp - bp))
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale_rows, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale_rows, bias=lp.bias, act=lp.act)
    out = out[:bp, :lp.f].reshape(b, *spatial, lp.f)
    return out if batched else out[0]


def forward_im2col(plan: ModelPlan, x: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """Whole-model oracle loop over forward_layer_im2col."""
    for lp in plan.layers:
        x = forward_layer_im2col(plan, lp, x, interpret=interpret)
    return x
