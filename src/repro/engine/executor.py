"""Forward execution of pre-packed weight-stationary plans.

The per-call work is exactly what the hardware pays per frame: im2col the
activations (the DIV stream), quantize them (the input DACs), and stream
them against the resident DKV state.  Weight-side padding/packing happened
once at plan compile time; the dequant-scale + bias + activation epilogue
is fused into the Pallas kernels, so the int32 accumulators never
round-trip HBM.

Batching (the serving runtime's path): `forward`/`forward_layer` accept a
single image (H, W, D) or an NHWC batch (B, H, W, D).  A batch folds the
per-image position streams into ONE GEMM — im2col over the batch
concatenates DIV streams, which is precisely how a weight-stationary
accelerator amortizes a resident DKV imprint over many frames (paper
Section VI-A).  No new kernels: the position axis simply grows B-fold.
Quantization stays *per image* (each frame gets its own input-DAC swing,
as in the per-image loop), so the fused epilogue takes a per-row dequant
scale for B > 1 (kernels/vdpe_gemm.py); a batch of one keeps the scalar
SMEM epilogue.  Batched outputs are bit-identical to the per-image loop:
the int32 accumulators are exact regardless of the fold, and both
epilogue variants apply the identical elementwise f32 ops to identical
inputs (asserted bitwise across all layer kinds and both GEMM modes in
tests/test_engine.py).

Numerics: the integer accumulation is bit-identical to the eager oracle
(quantize -> direct int32 GEMM) — the same invariant core/vdp.py
establishes for the sliced VDP path — and the fused f32 epilogue matches
the unfused reference exactly for bias-free layers, to one ulp otherwise
(XLA contracts acc*scale + bias into an FMA inside the kernel).
tests/test_engine.py checks this across the paper CNNs' layer shapes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind
from ..core import vdp
from ..kernels import ops, ref
from ..kernels import vdpe_gemm as kern
from .plan import (LayerPlan, MODE_DENSE, MODE_DEPTHWISE, MODE_PACKED,
                   ModelPlan)


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def _im2col_batch(x4: jax.Array, k: int, stride: int,
                  padding: str) -> jax.Array:
    """(B, H, W, D) -> (B, P, K*K*D): per-image DIV streams, stacked."""
    return jax.vmap(lambda im: vdp.im2col(im, k, stride, padding))(x4)


def _quantize_per_image(divs: jax.Array, bits: int,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-image symmetric quantization of (B, P, S) DIV streams.

    Each image keeps its own input-DAC swing — identical to running
    vdp.quantize_symmetric on every image separately (max is exact, the
    divide/round/clip are elementwise), which is what makes the folded
    batch bit-identical to the per-image loop.
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)), 1e-12) / qmax
    q = jnp.clip(jnp.round(divs / scale[:, None, None]),
                 -qmax, qmax).astype(jnp.int8)
    return q, scale


def _forward_depthwise(lp: LayerPlan, x4: jax.Array, point) -> jax.Array:
    """Per-channel S=K*K contractions as ONE batched integer contraction.

    Depthwise kernels pair channel c's patches with channel c's single DKV
    row, so the GEMM degenerates to a (B, P, KK, D) x (D, KK) -> (B, P, D)
    batched dot — the VPU path.  Quantization is per image AND per channel
    on the activation side (each channel of each frame is an independent
    VDP), matching core/vdp.depthwise_conv2d_vdp bit-for-bit.
    """
    b, h, w, d = x4.shape
    k = lp.k
    qmax = 2 ** (point.bits - 1) - 1
    divs = _im2col_batch(x4, k, lp.stride, lp.padding)    # (B, P, K*K*D)
    p = divs.shape[1]
    divs = divs.reshape(b, p, k * k, d)
    a_scale = jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)),
                          1e-12) / qmax                    # (B, D)
    divs_q = jnp.clip(jnp.round(divs / a_scale[:, None, None, :]),
                      -qmax, qmax).astype(jnp.int8)
    acc = jnp.einsum("bpkc,ck->bpc", divs_q.astype(jnp.int32),
                     lp.rhs.astype(jnp.int32))
    r = ref.epilogue_ref(acc, (a_scale * lp.w_scale[None, :])[:, None, :],
                         None if lp.bias is None else lp.bias[None, None, :],
                         lp.act)
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    return r.reshape(b, ho, wo, d)


def forward_layer(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """One layer through its pre-packed kernel with the fused epilogue.

    x: (H, W, D) or batched (B, H, W, D) for conv layers; a flat feature
    vector, (H, W, D) map, batched rows (B, S) or batched maps for FC.
    Batched inputs return batched outputs; the computation is the folded
    position stream described in the module docstring.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = plan.point

    if lp.kind is ConvKind.FC:
        if x.ndim == 4:                       # batched feature maps
            flat = x.reshape(x.shape[0], -1)
        elif x.ndim == 2:                     # rows are already the batch
            flat = x
        else:                                 # single map / vector -> (1, S)
            flat = x.reshape(1, -1)
        divs = flat[:, None, :]               # (B, 1, S)
        spatial = None                        # FC output is (B, F) either way
    else:
        batched = x.ndim == 4
        x4 = x if batched else x[None]
        if lp.mode == MODE_DEPTHWISE:
            out = _forward_depthwise(lp, x4, point)
            return out if batched else out[0]
        divs = _im2col_batch(x4, lp.k, lp.stride, lp.padding)  # (B, P, S)
        spatial = vdp.out_hw(x4.shape[1], x4.shape[2], lp.k, lp.stride,
                             lp.padding)
    if divs.shape[2] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {divs.shape[2]}")
    b, p, _ = divs.shape
    divs_q, a_scale = _quantize_per_image(divs, point.bits)
    lhs = divs_q.reshape(b * p, lp.s)
    bp = b * p
    pp = _round_up(bp, point.block_b)
    # fold the batch into the position stream; each image's rows carry its
    # own dequant scale into the fused epilogue.  One image has one scale,
    # so it rides the cheaper scalar-SMEM epilogue path.
    scale = a_scale * lp.w_scale
    if b == 1:
        scale_rows = scale[0]
    else:
        scale_rows = jnp.pad(jnp.repeat(scale, p), (0, pp - bp))
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale_rows, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale_rows, bias=lp.bias, act=lp.act)
    out = out[:bp, :lp.f]
    if spatial is not None:
        out = out.reshape(b, *spatial, lp.f)
        return out if batched else out[0]
    out = out.reshape(b, lp.f)
    return out                                # FC single image stays (1, F)


def forward(plan: ModelPlan, x: jax.Array,
            interpret: bool | None = None) -> jax.Array:
    """Run activations through every layer of a compiled plan.

    Accepts one image (H, W, D) or an NHWC batch (B, H, W, D); batched
    outputs are bit-identical to looping `forward` over the images.
    """
    for lp in plan.layers:
        x = forward_layer(plan, lp, x, interpret=interpret)
    return x
