"""Forward execution of pre-packed weight-stationary plans.

The per-call work is exactly what the hardware pays per frame: im2col the
activations (the DIV stream), quantize them (the input DACs), and stream
them against the resident DKV state.  Weight-side padding/packing happened
once at plan compile time; the dequant-scale + bias + activation epilogue
is fused into the Pallas kernels, so the int32 accumulators never
round-trip HBM.

Numerics: the integer accumulation is bit-identical to the eager oracle
(quantize -> direct int32 GEMM) — the same invariant core/vdp.py
establishes for the sliced VDP path — and the fused f32 epilogue matches
the unfused reference exactly for bias-free layers, to one ulp otherwise
(XLA contracts acc*scale + bias into an FMA inside the kernel).
tests/test_engine.py checks this across the paper CNNs' layer shapes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind
from ..core import vdp
from ..kernels import ops, ref
from ..kernels import vdpe_gemm as kern
from .plan import (LayerPlan, MODE_DENSE, MODE_DEPTHWISE, MODE_PACKED,
                   ModelPlan)


def _round_up(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def _quantize_acts(x: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    return vdp.quantize_symmetric(x, bits)


def _forward_depthwise(lp: LayerPlan, x: jax.Array, point,
                       interpret: bool) -> jax.Array:
    """Per-channel S=K*K contractions as ONE batched integer contraction.

    Depthwise kernels pair channel c's patches with channel c's single DKV
    row, so the GEMM degenerates to a (P, KK, D) x (D, KK) -> (P, D)
    batched dot — the VPU path.  Quantization is per channel on both sides
    (each channel is an independent VDP), matching
    core/vdp.depthwise_conv2d_vdp bit-for-bit.
    """
    del interpret
    h, w, d = x.shape
    k = lp.k
    qmax = 2 ** (point.bits - 1) - 1
    divs = vdp.im2col(x, k, lp.stride, lp.padding)        # (P, K*K*D)
    p = divs.shape[0]
    divs = divs.reshape(p, k * k, d)
    a_scale = jnp.maximum(jnp.max(jnp.abs(divs), axis=(0, 1)), 1e-12) / qmax
    divs_q = jnp.clip(jnp.round(divs / a_scale[None, None, :]),
                      -qmax, qmax).astype(jnp.int8)
    acc = jnp.einsum("pkc,ck->pc", divs_q.astype(jnp.int32),
                     lp.rhs.astype(jnp.int32))
    r = ref.epilogue_ref(acc, (a_scale * lp.w_scale)[None, :],
                         None if lp.bias is None else lp.bias[None, :],
                         lp.act)
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    return r.reshape(ho, wo, d)


def forward_layer(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """One layer through its pre-packed kernel with the fused epilogue."""
    if interpret is None:
        interpret = ops.default_interpret()
    point = plan.point
    if lp.mode == MODE_DEPTHWISE:
        return _forward_depthwise(lp, x, point, interpret)

    if lp.kind is ConvKind.FC:
        divs = x.reshape(1, -1) if x.ndim != 2 else x
        spatial = None
    else:
        divs = vdp.im2col(x, lp.k, lp.stride, lp.padding)
        spatial = vdp.out_hw(x.shape[0], x.shape[1], lp.k, lp.stride,
                             lp.padding)
    assert divs.shape[1] == lp.s, (divs.shape, lp.s)
    divs_q, a_scale = _quantize_acts(divs, point.bits)
    scale = a_scale * lp.w_scale
    p = divs_q.shape[0]
    pp = _round_up(p, point.block_b)
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(divs_q, ((0, pp - p), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(divs_q, ((0, pp - p), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale, bias=lp.bias, act=lp.act)
    out = out[:p, :lp.f]
    if spatial is not None:
        out = out.reshape(*spatial, lp.f)
    return out


def forward(plan: ModelPlan, x: jax.Array,
            interpret: bool | None = None) -> jax.Array:
    """Run activations through every layer of a compiled plan."""
    for lp in plan.layers:
        x = forward_layer(plan, lp, x, interpret=interpret)
    return x
