"""Forward execution of pre-packed weight-stationary plans.

The per-call work is exactly what the hardware pays per frame: quantize the
activations (the input DACs) and stream their DIV patches against the
resident DKV state.  Weight-side padding/packing happened once at plan
compile time; the whole quantize prologue AND the dequant-scale + bias +
activation epilogue are fused into the Pallas kernels, so neither the int8
activation stream nor the int32 accumulators ever round-trip HBM.

Three execution paths, one numerics contract:

* **Quantized-domain implicit-GEMM (default, the serving hot path).**
  ``forward`` / ``forward_layer`` route SC/PC conv layers to the
  fused-quantize implicit-GEMM kernels (kernels/vdpe_conv.py): the *raw
  f32* NHWC activation goes to the kernel at its natural (B, Hp, Wp, D)
  size and the entire input-DAC stage — covered-window absmax, DAC scale,
  int8 quantize — runs in the kernel prologue off the VMEM tile, so the
  separate XLA absmax/round/clip passes (two extra f32 reads plus an int8
  round-trip of the activation through HBM) disappear.  The K*K patch
  taps are gathered *inside* the kernel — the (B, P, K*K*D) im2col DIV
  matrix never exists in HBM.  Depthwise layers run the same windowed
  gather as a per-channel integer VPU contraction in plain jnp; FC layers
  quantize in the GEMM kernels' prologues (their row absmax is a cheap
  XLA reduction, the quantize itself is fused) and stream K through the
  explicitly double-buffered q8 GEMMs.  ``layer_route`` reports the
  routing per layer.

* **Quantize-then-float (the float oracle).**  ``forward_f32`` /
  ``forward_layer_f32`` keep the pre-fusion structure: activations are
  quantized by separate XLA passes, and the *quantized lattice values are
  streamed as f32* through the same implicit-GEMM kernels with f32
  accumulation.  Because int8-lattice products summed to any paper-CNN
  depth stay far below 2^24, f32 accumulation is exact and the path is
  bit-identical to the int8 path while moving 4x the operand bytes —
  it is both the bitwise oracle for the quantized-domain path and the
  float side of benchmarks/kernel_bench.py's int8-vs-float sweep.

* **im2col -> GEMM (the historical oracle).**  ``forward_im2col`` /
  ``forward_layer_im2col`` keep the materialized-DIV path next to
  kernels/ref.py's oracles; tests/test_implicit_conv.py and
  tests/test_quantized.py assert all paths are bit-identical across all
  layer kinds, strides, paddings and batch shapes.

Bitwise identity holds because every step matches elementwise: the
per-image quantization scale is the max |activation| over exactly the
patch-covered window set (the in-kernel prologue and the XLA pass both
enumerate it through kconv.tap_window; SAME-padding zeros never raise a
max), the quantizer rounds onto the same integer lattice through
kernels/common.quantize_tile, integer tap-sum accumulation is associative
(and exact in f32), and every fused epilogue applies the identical
``act(acc * scale + bias)`` expression (kernels/common.dequant_epilogue).

Batching (the serving runtime's path): all paths accept a single image
(H, W, D) or an NHWC batch (B, H, W, D).  Quantization stays *per image*
(each frame gets its own input-DAC swing); the conv kernels derive the
per-image scales per grid instance, the GEMM paths carry per-row scale
columns (kernels/vdpe_gemm.py).  For the whole-model jitted pipeline that
chases the per-layer Python dispatch out of this loop, see
engine/pipeline.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind
from ..core import vdp
from ..kernels import ops, ref
from ..kernels import vdpe_conv as kconv
from ..kernels import vdpe_gemm as kern
from ..kernels.common import (qmax_for, quantize_tile,
                              round_up as _round_up, stable_scale)
from .plan import (LayerPlan, MODE_DENSE, MODE_DEPTHWISE, MODE_PACKED,
                   ModelPlan)

#: layer_route values, in routing-priority order.
ROUTE_FC_GEMM = "fc_gemm"
ROUTE_DEPTHWISE = "depthwise_vpu"
ROUTE_CONV_ZS = "conv_implicit_mode2_zs"
ROUTE_CONV_M1 = "conv_implicit_mode1"


def layer_route(lp: LayerPlan) -> str:
    """Which execution path ``forward_layer`` takes for this layer."""
    if lp.kind is ConvKind.FC:
        return ROUTE_FC_GEMM
    if lp.mode == MODE_DEPTHWISE:
        return ROUTE_DEPTHWISE
    return ROUTE_CONV_ZS if lp.mode == MODE_PACKED else ROUTE_CONV_M1


# ---------------------------------------------------------------------------
# Shared activation-side helpers
# ---------------------------------------------------------------------------

#: Pin a DAC scale against XLA algebraic reassociation (the PR-3
#: reciprocal/optimization_barrier lesson) — now shared with the in-kernel
#: quantize prologues through kernels/common.stable_scale.
_stable_scale = stable_scale


def _pad_spatial(x4: jax.Array, k: int, stride: int,
                 padding: str) -> jax.Array:
    """SAME/VALID spatial zero-padding, split exactly as vdp.im2col does."""
    if padding != "SAME":
        return x4
    _, h, w, _ = x4.shape
    ho, wo = vdp.out_hw(h, w, k, stride, padding)
    pad_h = max((ho - 1) * stride + k - h, 0)
    pad_w = max((wo - 1) * stride + k - w, 0)
    return jnp.pad(x4, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))


def _window_absmax(x4p: jax.Array, k: int, stride: int, ho: int, wo: int,
                   per_channel: bool) -> jax.Array:
    """max |x| over the patch-covered pixel set, per image (and channel).

    Identical to the im2col-matrix max: the taps enumerate exactly the
    pixels the DIV matrix replicates (a strided layer can leave border
    pixels uncovered, so the whole-image max would be *wrong* — the
    covered-set max is what keeps this path bitwise-equal to the oracle).
    The q8 conv kernels run this same tap walk in their prologues.
    """
    axes = (1, 2) if per_channel else (1, 2, 3)
    m = None
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = jnp.abs(kconv.tap_window(x4p, di, dj, stride, ho, wo))
        wm = jnp.max(win, axis=axes)
        m = wm if m is None else jnp.maximum(m, wm)
    return m                      # (B,) or (B, D)


def _im2col_batch(x4: jax.Array, k: int, stride: int,
                  padding: str) -> jax.Array:
    """(B, H, W, D) -> (B, P, K*K*D): per-image DIV streams, stacked."""
    return jax.vmap(lambda im: vdp.im2col(im, k, stride, padding))(x4)


def _quantize_per_image(divs: jax.Array, bits: int,
                        ) -> Tuple[jax.Array, jax.Array]:
    """Per-image symmetric quantization of (B, P, S) DIV streams.

    Each image keeps its own input-DAC swing — identical to running
    vdp.quantize_symmetric on every image separately (max is exact, the
    divide/round/clip are elementwise), which is what makes the folded
    batch bit-identical to the per-image loop.  The oracle paths' XLA-side
    twin of the q8 kernels' fused prologue.
    """
    scale = _stable_scale(jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)),
                                      1e-12) * vdp.inv_qmax(bits))
    return quantize_tile(divs, scale[:, None, None], bits), scale


def _row_dac_scales(flat: jax.Array, bits: int) -> jax.Array:
    """Per-row DAC scales of a (B, S) stream (the q8 GEMM prologue input)."""
    return _stable_scale(jnp.maximum(jnp.max(jnp.abs(flat), axis=1),
                                     1e-12) * vdp.inv_qmax(bits))


# ---------------------------------------------------------------------------
# Quantized-domain implicit-GEMM conv path (the serving hot path)
# ---------------------------------------------------------------------------

def _forward_conv_implicit(lp: LayerPlan, x4: jax.Array, point,
                           interpret: bool) -> jax.Array:
    """SC/PC layer through the fused-quantize implicit-GEMM kernels.

    The raw f32 activation goes straight to the kernel; absmax, DAC scale
    and int8 quantize all happen in the kernel prologue (no XLA passes,
    no int8 round-trip of the activation through HBM).
    """
    b, h, w, din = x4.shape
    k = lp.k
    d = lp.s // (k * k)
    if d != din:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {k * k * din}")
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    if lp.mode == MODE_PACKED:
        out = kconv.vdpe_pack_conv_zs_q8(
            x4p, lp.rhs, lp.w_scale, k, lp.stride, ho, wo, x=point.x,
            bits=point.bits, block_o=point.block_o, interpret=interpret,
            bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        out = kconv.vdpe_conv_q8(
            x4p, lp.rhs, lp.w_scale, k, lp.stride, ho, wo,
            bits=point.bits, block_o=point.block_o, interpret=interpret,
            bias=lp.bias, act=lp.act)
    return out[:, :, :lp.f].reshape(b, ho, wo, lp.f)


def _forward_depthwise(lp: LayerPlan, x4: jax.Array, point) -> jax.Array:
    """Per-channel VPU path, windowed — no materialized (B, P, K*K, D).

    Depthwise kernels pair channel c's patches with channel c's single DKV
    row, so the contraction degenerates to K*K tap-wise multiply-adds over
    the strided windows.  Quantization is per image AND per channel (each
    channel of each frame is an independent VDP), matching
    core/vdp.depthwise_conv2d_vdp bit-for-bit: same covered-set max, and
    the integer tap sum equals the einsum's contraction exactly.
    """
    b, h, w, d = x4.shape
    k = lp.k
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=True),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B, D)
    x_q = quantize_tile(x4p, a_scale[:, None, None, :],
                        point.bits).astype(jnp.int32)
    acc = jnp.zeros((b, ho, wo, d), jnp.int32)
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = kconv.tap_window(x_q, di, dj, lp.stride, ho, wo)
        acc = acc + win * lp.rhs[:, kk].astype(jnp.int32)[None, None, None]
    return ref.epilogue_ref(
        acc, (a_scale * lp.w_scale[None, :])[:, None, None, :],
        None if lp.bias is None else lp.bias[None, None, None, :],
        lp.act)


def forward_layer(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                  interpret: bool | None = None) -> jax.Array:
    """One layer through its pre-packed kernel with the fused quantize
    prologue and dequant epilogue.

    x: (H, W, D) or batched (B, H, W, D) for conv layers; a flat feature
    vector, (H, W, D) map, batched rows (B, S) or batched maps for FC.
    Conv layers run the quantized-domain implicit-GEMM path (module
    docstring); FC falls through to the q8 GEMM path.  Batched outputs
    are bit-identical to the per-image loop AND to forward_layer_f32 /
    forward_layer_im2col.

    Each layer executes at its *own* operating point (``lp.point``):
    planner-compiled plans carry heterogeneous per-layer packing geometry
    while fixed-point plans repeat the model point.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = lp.point
    if lp.kind is not ConvKind.FC:
        batched = x.ndim == 4
        x4 = x if batched else x[None]
        if lp.mode == MODE_DEPTHWISE:
            out = _forward_depthwise(lp, x4, point)
        else:
            out = _forward_conv_implicit(lp, x4, point, interpret)
        return out if batched else out[0]
    return _forward_fc(plan, lp, x, interpret)


def _fc_flatten(lp: LayerPlan, x: jax.Array) -> jax.Array:
    """FC input: flatten maps/vectors to (B, S) rows."""
    if x.ndim == 4:                       # batched feature maps
        flat = x.reshape(x.shape[0], -1)
    elif x.ndim == 2:                     # rows are already the batch
        flat = x
    else:                                 # single map / vector -> (1, S)
        flat = x.reshape(1, -1)
    if flat.shape[1] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {flat.shape[1]}")
    return flat


def _forward_fc(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                interpret: bool) -> jax.Array:
    """FC layer: (B, S) rows through the fused-quantize q8 GEMMs.

    The per-row DAC scales (a cheap XLA row reduction — a K-blocked GEMM
    tile cannot see its whole row) go in as data; the divide/round/clip
    quantize itself runs in the kernel prologue and the K axis streams
    through explicitly double-buffered VMEM slots.  Pad rows carry scale
    1 so the prologue quantizes their zeros to zero.
    """
    point = lp.point
    flat = _fc_flatten(lp, x)
    b = flat.shape[0]
    a_scale = _row_dac_scales(flat, point.bits)
    bp = _round_up(b, point.block_b)
    a_rows = jnp.pad(a_scale, (0, bp - b), constant_values=1.0)
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(flat, ((0, bp - b), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs_q8(
            lhs, lp.rhs, a_rows, lp.w_scale, bits=point.bits,
            block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(flat, ((0, bp - b), (0, ss - lp.s)))
        out = kern.vdpe_gemm_q8(
            lhs, lp.rhs, a_rows, lp.w_scale, bits=point.bits,
            block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            bias=lp.bias, act=lp.act)
    return out[:b, :lp.f]                 # FC single image stays (1, F)


def forward(plan: ModelPlan, x: jax.Array,
            interpret: bool | None = None) -> jax.Array:
    """Run activations through every layer of a compiled plan (eager loop).

    Accepts one image (H, W, D) or an NHWC batch (B, H, W, D); batched
    outputs are bit-identical to looping `forward` over the images.  This
    is one Python dispatch per layer — the serving hot path uses the
    whole-model jitted pipeline instead (engine.forward_jit).
    """
    for lp in plan.layers:
        x = forward_layer(plan, lp, x, interpret=interpret)
    return x


# ---------------------------------------------------------------------------
# Quantize-then-float path: the float oracle (and the bench's float side)
# ---------------------------------------------------------------------------

def _forward_conv_implicit_f32(lp: LayerPlan, x4: jax.Array, point,
                               interpret: bool) -> jax.Array:
    """SC/PC float oracle: XLA quantize passes + f32 operand streams.

    The pre-fusion structure kept verbatim: covered-window absmax and
    round/clip run as separate XLA passes, then the *lattice values* are
    streamed as f32 (4x the bytes of the int8 stream) through the same
    implicit-GEMM kernels with exact f32 accumulation.
    """
    b, h, w, din = x4.shape
    k = lp.k
    d = lp.s // (k * k)
    if d != din:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {k * k * din}")
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=False),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B,)
    x_q = quantize_tile(x4p, a_scale[:, None, None, None],
                        point.bits).astype(jnp.float32)
    rhs_f = lp.rhs.astype(jnp.float32)
    scale = a_scale * lp.w_scale
    # one image rides the scalar-SMEM epilogue; a batch carries per-image
    # scales through the grid-indexed SMEM variant
    scale_arg = scale[0] if b == 1 else scale
    if lp.mode == MODE_PACKED:
        out = kconv.vdpe_pack_conv_zs(
            x_q, rhs_f, k, lp.stride, ho, wo, x=point.x,
            block_o=point.block_o, interpret=interpret,
            scale=scale_arg, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        out = kconv.vdpe_conv(
            x_q, rhs_f, k, lp.stride, ho, wo, block_o=point.block_o,
            interpret=interpret, scale=scale_arg, bias=lp.bias, act=lp.act)
    return out[:, :, :lp.f].reshape(b, ho, wo, lp.f)


def _forward_depthwise_f32(lp: LayerPlan, x4: jax.Array, point) -> jax.Array:
    """Depthwise float oracle: lattice values accumulated exactly in f32."""
    b, h, w, d = x4.shape
    k = lp.k
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=True),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B, D)
    x_q = quantize_tile(x4p, a_scale[:, None, None, :],
                        point.bits).astype(jnp.float32)
    acc = jnp.zeros((b, ho, wo, d), jnp.float32)
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = kconv.tap_window(x_q, di, dj, lp.stride, ho, wo)
        acc = acc + win * lp.rhs[:, kk].astype(jnp.float32)[None, None, None]
    return ref.epilogue_ref(
        acc, (a_scale * lp.w_scale[None, :])[:, None, None, :],
        None if lp.bias is None else lp.bias[None, None, None, :],
        lp.act)


def _forward_fc_prequantized(lp: LayerPlan, x: jax.Array, interpret: bool,
                             lattice_f32: bool) -> jax.Array:
    """Shared FC oracle body: XLA quantize, pre-quantized GEMM kernels.

    ``lattice_f32`` picks the operand domain — int8 (the historical
    im2col-era path) or the same lattice streamed as f32 (the float
    oracle); everything else (padding, per-row dequant scales, mode
    routing) is identical, which is the point: the oracles cannot drift
    apart structurally.
    """
    point = lp.point
    flat = _fc_flatten(lp, x)
    divs_q, a_scale = _quantize_per_image(flat[:, None, :], point.bits)
    b = flat.shape[0]
    lhs = divs_q.reshape(b, lp.s)
    rhs = lp.rhs
    if lattice_f32:
        lhs = lhs.astype(jnp.float32)
        rhs = rhs.astype(jnp.float32)
    bp = _round_up(b, point.block_b)
    scale = a_scale * lp.w_scale
    if b == 1:
        scale_rows = scale[0]
    else:
        scale_rows = jnp.pad(scale, (0, bp - b))
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(lhs, ((0, bp - b), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale_rows, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(lhs, ((0, bp - b), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale_rows, bias=lp.bias, act=lp.act)
    return out[:b, :lp.f]


def _forward_fc_f32(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                    interpret: bool) -> jax.Array:
    """FC float oracle: the shared body with f32 lattice streams."""
    return _forward_fc_prequantized(lp, x, interpret, lattice_f32=True)


def forward_layer_f32(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                      interpret: bool | None = None) -> jax.Array:
    """One layer through the quantize-then-float path (module docstring).

    Bit-identical to ``forward_layer`` while streaming f32 operands —
    the float side of the int8-vs-float kernel bench and the oracle the
    quantized-domain tests hold the int8 path against.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = lp.point
    if lp.kind is ConvKind.FC:
        return _forward_fc_f32(plan, lp, x, interpret)
    batched = x.ndim == 4
    x4 = x if batched else x[None]
    if lp.mode == MODE_DEPTHWISE:
        out = _forward_depthwise_f32(lp, x4, point)
    else:
        out = _forward_conv_implicit_f32(lp, x4, point, interpret)
    return out if batched else out[0]


def forward_f32(plan: ModelPlan, x: jax.Array,
                interpret: bool | None = None) -> jax.Array:
    """Whole-model quantize-then-float oracle loop."""
    for lp in plan.layers:
        x = forward_layer_f32(plan, lp, x, interpret=interpret)
    return x


# ---------------------------------------------------------------------------
# im2col -> GEMM path: the historical bitwise oracle
# ---------------------------------------------------------------------------

def _forward_depthwise_im2col(lp: LayerPlan, x4: jax.Array,
                              point) -> jax.Array:
    """Depthwise oracle: materialized (B, P, K*K, D) + einsum contraction."""
    b, h, w, d = x4.shape
    k = lp.k
    divs = _im2col_batch(x4, k, lp.stride, lp.padding)    # (B, P, K*K*D)
    p = divs.shape[1]
    divs = divs.reshape(b, p, k * k, d)
    a_scale = _stable_scale(jnp.maximum(jnp.max(jnp.abs(divs), axis=(1, 2)),
                                        1e-12)
                            * vdp.inv_qmax(point.bits))      # (B, D)
    divs_q = quantize_tile(divs, a_scale[:, None, None, :], point.bits)
    acc = jnp.einsum("bpkc,ck->bpc", divs_q.astype(jnp.int32),
                     lp.rhs.astype(jnp.int32))
    r = ref.epilogue_ref(acc, (a_scale * lp.w_scale[None, :])[:, None, :],
                         None if lp.bias is None else lp.bias[None, None, :],
                         lp.act)
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    return r.reshape(b, ho, wo, d)


def forward_layer_im2col(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                         interpret: bool | None = None) -> jax.Array:
    """One layer through the materialized im2col -> GEMM path.

    The pre-implicit-GEMM execution path, kept verbatim as the bitwise
    oracle (and kernel_bench baseline) for forward_layer: it builds the
    full (B, P, K*K*D) DIV matrix in HBM and folds the batch into one GEMM
    position stream with per-row dequant scales.
    """
    if interpret is None:
        interpret = ops.default_interpret()
    point = lp.point

    if lp.kind is ConvKind.FC:
        return _forward_fc_im2col(plan, lp, x, interpret)
    batched = x.ndim == 4
    x4 = x if batched else x[None]
    if lp.mode == MODE_DEPTHWISE:
        out = _forward_depthwise_im2col(lp, x4, point)
        return out if batched else out[0]
    divs = _im2col_batch(x4, lp.k, lp.stride, lp.padding)  # (B, P, S)
    spatial = vdp.out_hw(x4.shape[1], x4.shape[2], lp.k, lp.stride,
                         lp.padding)
    if divs.shape[2] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {divs.shape[2]}")
    b, p, _ = divs.shape
    divs_q, a_scale = _quantize_per_image(divs, point.bits)
    lhs = divs_q.reshape(b * p, lp.s)
    bp = b * p
    pp = _round_up(bp, point.block_b)
    # fold the batch into the position stream; each image's rows carry its
    # own dequant scale into the fused epilogue.  One image has one scale,
    # so it rides the cheaper scalar-SMEM epilogue path.
    scale = a_scale * lp.w_scale
    if b == 1:
        scale_rows = scale[0]
    else:
        scale_rows = jnp.pad(jnp.repeat(scale, p), (0, pp - bp))
    if lp.mode == MODE_PACKED:
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, point.x - lp.s)))
        out = kern.vdpe_pack_gemm_zs(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            interpret=interpret, scale=scale_rows, bias=lp.bias, act=lp.act)
    else:
        assert lp.mode == MODE_DENSE
        ss = lp.rhs.shape[0]
        lhs = jnp.pad(lhs, ((0, pp - bp), (0, ss - lp.s)))
        out = kern.vdpe_gemm(
            lhs, lp.rhs, block_b=point.block_b, block_o=point.block_o,
            block_k=point.block_k, interpret=interpret,
            scale=scale_rows, bias=lp.bias, act=lp.act)
    out = out[:bp, :lp.f].reshape(b, *spatial, lp.f)
    return out if batched else out[0]


def _forward_fc_im2col(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                       interpret: bool) -> jax.Array:
    """FC oracle: the shared body with int8 operand streams."""
    return _forward_fc_prequantized(lp, x, interpret, lattice_f32=False)


def forward_im2col(plan: ModelPlan, x: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """Whole-model oracle loop over forward_layer_im2col."""
    for lp in plan.layers:
        x = forward_layer_im2col(plan, lp, x, interpret=interpret)
    return x


# ---------------------------------------------------------------------------
# Guarded execution path: value-corruption hook + ABFT/guard detection (SDC)
# ---------------------------------------------------------------------------
#
# The serving hot path fuses the int32 accumulators inside the Pallas
# kernels — they never exist as host-visible arrays, so there is nowhere to
# corrupt them or checksum them.  The guarded path is a fourth execution
# path with the SAME numerics contract as the three above: the im2col
# quantize prologue (shared helpers), an *explicit* XLA int32 GEMM whose
# accumulators are materialized, and the identical fused-epilogue
# expression (ref.epilogue_ref).  Integer accumulation is order-invariant
# (int32 addition is associative and commutative, wraparound included), so
# the guarded path is bit-identical to `forward` / `forward_jit` when the
# corruption arguments are null — which is what lets the dispatcher serve
# real traffic through it and lets recovery claim *bitwise* equality with
# the fault-free run.
#
# Between GEMM and epilogue the path (a) applies the fault injector's
# value corruption to the accumulators (deterministic under the dispatch
# seed; exactly zero effect when the corruption args are null) and (b)
# verifies the accumulators with Huang-Abraham-style ABFT checksums, a
# B-bit accumulation range guard, and a weight-imprint checksum, returning
# a per-layer detector bitmask alongside the activations.
#
# Detector algebra (all exact in the ring Z/2^32 — int32 wraparound is
# deterministic two's-complement, and GEMM is linear mod 2^32):
#   column check:  (sum_r lhs[r, :]) @ rhs == sum_r acc[r, :]
#   row check:     lhs @ (sum_f rhs[:, f]) == sum_f acc[:, f]
# A single corrupted element acc[i, j] += d (d != 0 mod 2^32) shifts
# column-sum j and row-sum i by exactly d, so it is ALWAYS detected by
# both checks — no false negatives for single-element corruption, and no
# false positives ever (the checks are identities, not tolerances).  Note
# the checks verify acc *against the rhs as loaded*: a corrupted weight
# imprint yields a GEMM that is self-consistent with the wrong weights,
# which is exactly why the weight-imprint checksum (vs a trace-time golden
# of the pristine rhs) exists as a separate detector.

#: detector bitmask bits (per-layer flags word)
DET_ABFT_COL = 1     # column-checksum mismatch
DET_ABFT_ROW = 2     # row-checksum mismatch
DET_RANGE = 4        # accumulator outside the B-bit accumulation bound
DET_WEIGHT = 8       # resident weight imprint differs from golden

_DETECTOR_NAMES = {DET_ABFT_COL: "abft_col", DET_ABFT_ROW: "abft_row",
                   DET_RANGE: "range_guard", DET_WEIGHT: "weight_checksum"}


def detector_names(mask: int) -> Tuple[str, ...]:
    """Human-readable detector names for a flags bitmask."""
    return tuple(name for bit, name in sorted(_DETECTOR_NAMES.items())
                 if mask & bit)


@dataclasses.dataclass(frozen=True)
class IntegrityPolicy:
    """Which detectors run, and how often (hashable: keys jit caches).

    ``check_every=k`` checksums layers 0, k, 2k, ... (cadence trades
    detection latency against overhead); ``check_every=0`` disables all
    verification (the silent-corruption baseline).  The ABFT identity
    catches any single corrupted accumulator element exactly; the range
    guard bounds |acc| by qmax^2 * depth (a cheap always-on sanity net);
    the weight checksum compares the resident imprint against a trace-time
    golden (the only detector that can see STUCK_MRR weight corruption —
    ABFT verifies the GEMM against the weights *as loaded*).
    """
    abft: bool = True
    range_guard: bool = True
    weight_checksum: bool = True
    check_every: int = 1

    def __post_init__(self) -> None:
        if self.check_every < 0:
            raise ValueError(
                f"check_every must be >= 0, got {self.check_every}")


DEFAULT_POLICY = IntegrityPolicy()
DISABLED_POLICY = IntegrityPolicy(abft=False, range_guard=False,
                                  weight_checksum=False, check_every=0)


class CorruptionArgs(NamedTuple):
    """Traced corruption parameters (jit *arguments*, not constants: one
    guarded executable serves both clean and corrupted dispatches)."""
    key: jax.Array        # PRNG key; folded with the layer index
    sigma_lsb: jax.Array  # ANALOG_NOISE: Gaussian sigma in LSBs
    gain: jax.Array       # THERMAL_DETUNE: multiplicative drift
    bias_lsb: jax.Array   # THERMAL_DETUNE: additive drift in LSBs
    flip_prob: jax.Array  # ADC_BITFLIP: per-element flip probability


def corruption_args(seed: int = 0, sigma_lsb: float = 0.0, gain: float = 1.0,
                    bias_lsb: float = 0.0, flip_prob: float = 0.0,
                    ) -> CorruptionArgs:
    return CorruptionArgs(
        key=jax.random.PRNGKey(seed),
        sigma_lsb=jnp.float32(sigma_lsb), gain=jnp.float32(gain),
        bias_lsb=jnp.float32(bias_lsb), flip_prob=jnp.float32(flip_prob))


def null_corruption_args() -> CorruptionArgs:
    """The identity corruption (a clean dispatch)."""
    return corruption_args()


def corrupt_accumulators(acc: jax.Array, cargs: CorruptionArgs,
                         salt: int) -> jax.Array:
    """Apply the analog fault model to materialized int32 accumulators.

    Three physically-motivated corruptions, each an *exact identity* when
    its parameter is at rest (so a null CorruptionArgs returns ``acc``
    unchanged, bit for bit):

    * ANALOG_NOISE:   acc += round(N(0, sigma_lsb))       per element
    * THERMAL_DETUNE: acc += round(acc*(gain-1) + bias)   (gain/offset)
    * ADC_BITFLIP:    acc ^= (1 << low_bit)               w.p. flip_prob

    All RNG derives from fold_in(cargs.key, salt) — the layer index salts
    the per-dispatch key, so replaying a dispatch corrupts identically.
    The whole block sits under a lax.cond on the traced activity
    predicate: clean dispatches skip the RNG entirely.
    """
    def _apply(a: jax.Array) -> jax.Array:
        key = jax.random.fold_in(cargs.key, salt)
        k_noise, k_flip, k_bit = jax.random.split(key, 3)
        noise = jnp.round(jax.random.normal(k_noise, a.shape)
                          * cargs.sigma_lsb).astype(jnp.int32)
        detune = jnp.round(a.astype(jnp.float32) * (cargs.gain - 1.0)
                           + cargs.bias_lsb).astype(jnp.int32)
        flips = jax.random.uniform(k_flip, a.shape) < cargs.flip_prob
        bit = jax.random.randint(k_bit, a.shape, 0, 12)
        mask = jnp.where(flips, jnp.int32(1) << bit, jnp.int32(0))
        return jax.lax.bitwise_xor(a + noise + detune, mask)

    active = ((cargs.sigma_lsb > 0) | (cargs.gain != 1.0)
              | (cargs.bias_lsb != 0) | (cargs.flip_prob > 0))
    return jax.lax.cond(active, _apply, lambda a: a, acc)


def abft_flags(lhs: jax.Array, rhs: jax.Array, acc: jax.Array) -> jax.Array:
    """ABFT row/column checksum verification of ``acc == lhs @ rhs``.

    Exact identities in Z/2^32 (module comment); cost is two rank-1
    checks, O(BF + BS + SF) vs the GEMM's O(BSF).  Returns an int32
    DET_ABFT_* bitmask (0 when both checks pass).
    """
    li = lhs.astype(jnp.int32)
    ri = rhs.astype(jnp.int32)
    col_ok = jnp.all(jnp.matmul(jnp.sum(li, axis=0), ri)
                     == jnp.sum(acc, axis=0))
    row_ok = jnp.all(jnp.matmul(li, jnp.sum(ri, axis=1))
                     == jnp.sum(acc, axis=1))
    return (jnp.where(col_ok, 0, DET_ABFT_COL)
            | jnp.where(row_ok, 0, DET_ABFT_ROW)).astype(jnp.int32)


def range_guard_flag(acc: jax.Array, bound: int) -> jax.Array:
    """DET_RANGE iff any |acc| exceeds the B-bit accumulation bound.

    A depth-S contraction of qmax-bounded integers satisfies
    |acc| <= qmax^2 * S exactly (equality reachable, so the guard is
    strict).  Two comparisons, not abs(): |INT32_MIN| wraps negative.
    """
    b = jnp.int32(bound)
    exceeds = jnp.any((acc > b) | (acc < -b))
    return jnp.where(exceeds, DET_RANGE, 0).astype(jnp.int32)


def weight_imprint_checksum(rhs: jax.Array) -> jax.Array:
    """Position-weighted int32 checksum of a resident weight imprint.

    The (i mod 97)+1 weights make the sum sensitive to *where* an element
    changed, not just its value (a plain sum misses compensating swaps).
    Compared against a golden computed from the pristine rhs at guarded-
    pipeline build time — the one detector that catches STUCK_MRR faults,
    since ABFT verifies the GEMM against the weights as loaded.
    """
    flat = rhs.astype(jnp.int32).ravel()
    pos = (jnp.arange(flat.shape[0], dtype=jnp.int32) % 97) + 1
    return jnp.sum(flat * pos)


def _integrity_flags(lhs: jax.Array, rhs: jax.Array, acc: jax.Array,
                     bound: int, policy: IntegrityPolicy,
                     golden: Optional[int]) -> jax.Array:
    flags = jnp.int32(0)
    if policy.abft:
        flags = flags | abft_flags(lhs, rhs, acc)
    if policy.range_guard:
        flags = flags | range_guard_flag(acc, bound)
    if policy.weight_checksum and golden is not None:
        ok = weight_imprint_checksum(rhs) == jnp.int32(golden)
        flags = flags | jnp.where(ok, 0, DET_WEIGHT).astype(jnp.int32)
    return flags


def _guarded_conv(lp: LayerPlan, x4: jax.Array, cargs: CorruptionArgs,
                  salt: int, check: bool, policy: IntegrityPolicy,
                  golden: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """SC/PC conv: the im2col structure with a materialized int32 GEMM.

    Bitwise-identical to the kernel paths: shared quantize helpers, exact
    integer GEMM (order-invariant), identical epilogue expression.  The
    packed Mode-2 rhs (ops.pack_mode2_segments) is a dense (x, F) matrix
    with each column's weights at natural offset, so the same plain GEMM
    covers MODE_PACKED and MODE_DENSE.
    """
    point = lp.point
    divs = _im2col_batch(x4, lp.k, lp.stride, lp.padding)   # (B, P, S)
    spatial = vdp.out_hw(x4.shape[1], x4.shape[2], lp.k, lp.stride,
                         lp.padding)
    if divs.shape[2] != lp.s:
        raise ValueError(f"layer {lp.name!r} expects contraction {lp.s}, "
                         f"got input stream of width {divs.shape[2]}")
    b, p, _ = divs.shape
    divs_q, a_scale = _quantize_per_image(divs, point.bits)
    ss = lp.rhs.shape[0]                       # x (packed) or S_pad (dense)
    lhs = jnp.pad(divs_q.reshape(b * p, lp.s),
                  ((0, 0), (0, ss - lp.s))).astype(jnp.int32)
    rhs = lp.rhs.astype(jnp.int32)
    acc = jnp.matmul(lhs, rhs)                 # (B*P, F_pad) int32
    acc = corrupt_accumulators(acc, cargs, salt)
    qmax = qmax_for(point.bits)
    flags = (_integrity_flags(lhs, rhs, acc, qmax * qmax * lp.s,
                              policy, golden)
             if check else jnp.int32(0))
    acc3 = acc[:, :lp.f].reshape(b, p, lp.f)
    out = ref.epilogue_ref(
        acc3, (a_scale * lp.w_scale)[:, None, None],
        None if lp.bias is None else lp.bias[0][None, None, :lp.f],
        lp.act)
    return out.reshape(b, *spatial, lp.f), flags


def _guarded_depthwise(lp: LayerPlan, x4: jax.Array, cargs: CorruptionArgs,
                       salt: int, check: bool, policy: IntegrityPolicy,
                       golden: Optional[int],
                       ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise: the windowed VPU path with materialized tap windows.

    The ABFT analogue checksums the position axis: summing the tap-sum
    identity over all spatial positions gives
        sum_p acc[b, p, c] == sum_kk (sum_p win_kk[b, p, c]) * rhs[c, kk]
    — linear mod 2^32, so any single corrupted accumulator shifts its
    (b, c) checksum by its nonzero delta and is always detected.
    """
    point = lp.point
    b, h, w, d = x4.shape
    k = lp.k
    ho, wo = vdp.out_hw(h, w, k, lp.stride, lp.padding)
    x4p = _pad_spatial(x4, k, lp.stride, lp.padding)
    a_scale = _stable_scale(
        jnp.maximum(_window_absmax(x4p, k, lp.stride, ho, wo,
                                   per_channel=True),
                    1e-12) * vdp.inv_qmax(point.bits))           # (B, D)
    x_q = quantize_tile(x4p, a_scale[:, None, None, :],
                        point.bits).astype(jnp.int32)
    rhs = lp.rhs.astype(jnp.int32)
    wins = []
    acc = jnp.zeros((b, ho, wo, d), jnp.int32)
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        win = kconv.tap_window(x_q, di, dj, lp.stride, ho, wo)
        wins.append(win)
        acc = acc + win * rhs[:, kk][None, None, None]
    acc = corrupt_accumulators(acc, cargs, salt)
    if check:
        flags = jnp.int32(0)
        if policy.abft:
            expect = sum(wins[kk].sum(axis=(1, 2)) * rhs[:, kk][None]
                         for kk in range(k * k))
            ok = jnp.all(expect == acc.sum(axis=(1, 2)))
            flags = flags | jnp.where(ok, 0, DET_ABFT_COL).astype(jnp.int32)
        if policy.range_guard:
            qmax = qmax_for(point.bits)
            flags = flags | range_guard_flag(acc, qmax * qmax * k * k)
        if policy.weight_checksum and golden is not None:
            ok = weight_imprint_checksum(rhs) == jnp.int32(golden)
            flags = flags | jnp.where(ok, 0, DET_WEIGHT).astype(jnp.int32)
    else:
        flags = jnp.int32(0)
    out = ref.epilogue_ref(
        acc, (a_scale * lp.w_scale[None, :])[:, None, None, :],
        None if lp.bias is None else lp.bias[None, None, None, :],
        lp.act)
    return out, flags


def _guarded_fc(lp: LayerPlan, x: jax.Array, cargs: CorruptionArgs,
                salt: int, check: bool, policy: IntegrityPolicy,
                golden: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """FC: the pre-quantized GEMM structure with materialized accumulators."""
    point = lp.point
    flat = _fc_flatten(lp, x)
    divs_q, a_scale = _quantize_per_image(flat[:, None, :], point.bits)
    b = flat.shape[0]
    ss = lp.rhs.shape[0]                       # x (packed) or S_pad (dense)
    lhs = jnp.pad(divs_q.reshape(b, lp.s),
                  ((0, 0), (0, ss - lp.s))).astype(jnp.int32)
    rhs = lp.rhs.astype(jnp.int32)
    acc = jnp.matmul(lhs, rhs)                 # (B, F_pad) int32
    acc = corrupt_accumulators(acc, cargs, salt)
    qmax = qmax_for(point.bits)
    flags = (_integrity_flags(lhs, rhs, acc, qmax * qmax * lp.s,
                              policy, golden)
             if check else jnp.int32(0))
    out = ref.epilogue_ref(
        acc[:, :lp.f], (a_scale * lp.w_scale)[:, None],
        None if lp.bias is None else lp.bias[:, :lp.f], lp.act)
    return out, flags


def forward_layer_guarded(plan: ModelPlan, lp: LayerPlan, x: jax.Array,
                          cargs: CorruptionArgs, salt: int = 0,
                          check: bool = True,
                          policy: IntegrityPolicy = DEFAULT_POLICY,
                          golden: Optional[int] = None,
                          ) -> Tuple[jax.Array, jax.Array]:
    """One layer through the guarded path: (activations, detector flags).

    Bit-identical to ``forward_layer`` when ``cargs`` is null (the module
    comment's argument); with active corruption the int32 accumulators are
    corrupted *before* the epilogue — exactly where the analog faults land
    in hardware — and the detectors (when ``check``) verify them.  ``salt``
    (normally the layer index) decorrelates per-layer corruption under one
    dispatch key; ``golden`` is the trace-time weight-imprint checksum.
    ``check``/``policy``/``golden``/``salt`` are static: the flags math
    traces away entirely for unchecked layers.
    """
    if lp.kind is ConvKind.FC:
        return _guarded_fc(lp, x, cargs, salt, check, policy, golden)
    batched = x.ndim == 4
    x4 = x if batched else x[None]
    if lp.mode == MODE_DEPTHWISE:
        out, flags = _guarded_depthwise(lp, x4, cargs, salt, check, policy,
                                        golden)
    else:
        out, flags = _guarded_conv(lp, x4, cargs, salt, check, policy,
                                   golden)
    return (out if batched else out[0]), flags
