"""Weight-stationary execution engine (the paper's pack-once DKV imprint).

compile once (plan.py) -> run forever (executor.py), with the dequant/bias/
activation epilogue fused into the Pallas kernels (kernels/vdpe_gemm.py;
eager oracle: kernels/ref.epilogue_ref).
"""
from .executor import forward, forward_layer  # noqa: F401
from .plan import (DEFAULT_POINT, EnginePoint, LayerDef, LayerPlan,  # noqa: F401
                   MODE_DENSE, MODE_DEPTHWISE, MODE_PACKED, ModelPlan,
                   compile_layer, compile_model, get_plan,
                   plan_cache_clear, plan_cache_info)
