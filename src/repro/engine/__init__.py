"""Weight-stationary execution engine (the paper's pack-once DKV imprint).

compile once (plan.py) -> run forever (executor.py), with the dequant/bias/
activation epilogue fused into the Pallas kernels (kernels/vdpe_gemm.py,
kernels/vdpe_conv.py; eager oracle: kernels/ref.epilogue_ref).  Conv layers
run implicit-GEMM kernels (no materialized im2col); the serving hot path
serves whole batches through one jitted dispatch (pipeline.forward_jit).
The guarded twin (pipeline.forward_jit_guarded) materializes the int32
accumulators for value-corruption injection and ABFT/guard verification —
bit-identical to forward_jit on clean dispatches.
"""
from .executor import (CorruptionArgs, DEFAULT_POLICY,  # noqa: F401
                       DET_ABFT_COL, DET_ABFT_ROW, DET_RANGE, DET_WEIGHT,
                       DISABLED_POLICY, IntegrityPolicy, abft_flags,
                       corrupt_accumulators, corruption_args,
                       detector_names, forward, forward_f32,
                       forward_im2col, forward_layer, forward_layer_f32,
                       forward_layer_guarded, forward_layer_im2col,
                       layer_route, null_corruption_args,
                       weight_imprint_checksum)
from .pipeline import (batch_bucket, corrupted_layer_params,  # noqa: F401
                       forward_jit, forward_jit_guarded,
                       get_guarded_pipeline, get_pipeline,
                       pipeline_cache_clear, pipeline_cache_info,
                       pipeline_dispatch_counts)
from .pipeline import evict as pipeline_evict  # noqa: F401
from .plan import (DEFAULT_POINT, EnginePoint, LayerChoice,  # noqa: F401
                   LayerDef, LayerPlan, MODE_DENSE, MODE_DEPTHWISE,
                   MODE_PACKED, ModelPlan, OBJECTIVES, PlannerReport,
                   compile_layer, compile_model, defs_to_specs, get_plan,
                   plan_cache_clear, plan_cache_info, plan_model,
                   search_cache_evict, search_points, snr_feasible_options)
