"""Weight-stationary execution plans: compile a model ONCE, run it forever.

The paper's core economics are weight-stationary: DKVs are imprinted onto
the MRRs once and amortized over an entire position stream (Section VI-A).
The eager kernel wrappers (kernels/ops.py) betray that — every
`mixed_size_gemm` call re-pads the DKV matrix to MXU tiles or re-packs the
Mode-2 operand from scratch.  This module is the one-time DKV imprint:

    compile_model(name, layer_defs)  ->  ModelPlan

quantizes each layer's weights, routes it to Mode 1 / Mode 2 / the
depthwise VPU path, and materializes the *exact* operand the kernel wants
(Mode-1 tiles padded to MXU blocks, Mode-2 segment-sum packs, padded f32
bias rows).  Forward calls (engine/executor.py) never touch `jnp.pad` or
`pack_mode2_weights` on the weight side again.

Plans are memoized by (model key, operating point) in `get_plan`, mirroring
how a deployed TPC keeps a model's DKVs resident across requests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind
from ..core import vdp
from ..kernels import ops
from ..kernels import vdpe_gemm as kern
from ..kernels.common import ACTIVATIONS, round_up as _round_up


@dataclasses.dataclass(frozen=True)
class EnginePoint:
    """The TPU operating point a plan is compiled for (the paper's (N, x))."""
    n: int = ops.N_TPU            # MXU contraction-lane budget
    x: int = ops.X_TPU            # Mode-2 re-aggregation segment width
    block_b: int = kern.BLOCK_B
    block_o: int = kern.BLOCK_O
    block_k: int = kern.BLOCK_K
    bits: int = 4                 # paper Section III-B quantization


DEFAULT_POINT = EnginePoint()


@dataclasses.dataclass(frozen=True)
class LayerDef:
    """One layer's weights + epilogue, the compiler's input.

    weights: SC/PC (F, K, K, D) — K=1 for PC; FC (F, D); DC (D, K, K).
    """
    name: str
    kind: ConvKind
    weights: jax.Array
    bias: Optional[jax.Array] = None
    act: str = "none"
    stride: int = 1
    padding: str = "SAME"

    def __post_init__(self) -> None:
        assert self.act in ACTIVATIONS, self.act


#: LayerPlan.mode values: paper Mode 1 / Mode 2, plus the depthwise VPU path
#: (per-channel S=K*K contractions — one kernel row per channel, executed as
#: a single batched integer contraction rather than F separate GEMMs).
MODE_DENSE, MODE_PACKED, MODE_DEPTHWISE = 1, 2, 0


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer, pre-packed for its kernel — the imprinted DKV state."""
    name: str
    kind: ConvKind
    mode: int                 # MODE_DENSE | MODE_PACKED | MODE_DEPTHWISE
    k: int                    # spatial kernel size (1 for PC/FC)
    stride: int
    padding: str
    s: int                    # true contraction length S = K*K*D
    f: int                    # true output channels/units
    rhs: jax.Array            # packed int8 weights: mode1 (S_pad, F_pad),
                              # mode2 (x, F_pad), depthwise (D, K*K)
    w_scale: jax.Array        # () dequant scale; (D,) for depthwise
    bias: Optional[jax.Array]  # (1, F_pad) f32; (D,) for depthwise
    act: str


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    name: str
    point: EnginePoint
    layers: Tuple[LayerPlan, ...]

    @property
    def mode_census(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for l in self.layers:
            out[l.mode] = out.get(l.mode, 0) + 1
        return out


def _quantize_rows(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization (depthwise: one scale per channel).

    Scales use the same explicit reciprocal multiply as
    vdp.quantize_symmetric (see vdp.inv_qmax) so plan-side weight scales
    stay bit-identical to the eager oracle's.
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=1),
                        1e-12) * vdp.inv_qmax(bits)
    q = jnp.clip(jnp.round(w / scale[:, None]), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _quantize_tensor(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) * vdp.inv_qmax(bits)
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compile_layer(ld: LayerDef, point: EnginePoint = DEFAULT_POINT,
                  ) -> LayerPlan:
    """Quantize + route + pack one layer (the per-layer DKV imprint)."""
    if ld.kind is ConvKind.DC:
        d, k, _ = ld.weights.shape
        dkvs = ld.weights.reshape(d, k * k)
        # per-channel scales: each channel is its own VDP with its own DAC
        # swing, matching core/vdp.depthwise_conv2d_vdp bit-for-bit
        dkvs_q, w_scale = _quantize_rows(dkvs, point.bits)
        bias = None
        if ld.bias is not None:
            bias = jnp.asarray(ld.bias, jnp.float32).reshape(d)
        return LayerPlan(name=ld.name, kind=ld.kind, mode=MODE_DEPTHWISE,
                         k=k, stride=ld.stride, padding=ld.padding,
                         s=k * k, f=d, rhs=dkvs_q, w_scale=w_scale,
                         bias=bias, act=ld.act)

    if ld.kind is ConvKind.FC:
        f, s = ld.weights.shape
        dkvs = ld.weights
        k = 1
    else:                                   # SC / PC: (F, K, K, D)
        f = ld.weights.shape[0]
        k = ld.weights.shape[1]
        dkvs = ld.weights.reshape(f, -1)
        s = dkvs.shape[1]
    dkvs_q, w_scale = _quantize_tensor(dkvs, point.bits)
    ff = _round_up(f, point.block_o)
    bias = None
    if ld.bias is not None:
        bias = jnp.pad(jnp.asarray(ld.bias, jnp.float32).reshape(1, f),
                       ((0, 0), (0, ff - f)))
    if s <= point.x:
        mode = MODE_PACKED
        rhs = jnp.pad(ops.pack_mode2_segments(dkvs_q, point.x),
                      ((0, 0), (0, ff - f)))
    else:
        mode = MODE_DENSE
        ss = _round_up(s, point.block_k)
        rhs = jnp.pad(dkvs_q.T, ((0, ss - s), (0, ff - f)))
    return LayerPlan(name=ld.name, kind=ld.kind, mode=mode, k=k,
                     stride=ld.stride, padding=ld.padding, s=s, f=f,
                     rhs=rhs, w_scale=w_scale, bias=bias, act=ld.act)


def compile_model(name: str, layer_defs: Sequence[LayerDef],
                  point: EnginePoint = DEFAULT_POINT) -> ModelPlan:
    """Compile a whole model's pack-once plan (no caching — see get_plan)."""
    return ModelPlan(name=name, point=point,
                     layers=tuple(compile_layer(ld, point)
                                  for ld in layer_defs))


# ---------------------------------------------------------------------------
# Plan cache: one imprint per (model, operating point)
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[Tuple[str, EnginePoint], Tuple[tuple, ModelPlan]] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _defs_fingerprint(layer_defs: Sequence[LayerDef]) -> tuple:
    """Cheap structural identity of a model's defs (no weight hashing)."""
    return tuple((ld.name, ld.kind, tuple(ld.weights.shape),
                  ld.bias is not None, ld.act, ld.stride, ld.padding)
                 for ld in layer_defs)


def get_plan(name: str, layer_defs: Sequence[LayerDef],
             point: EnginePoint = DEFAULT_POINT) -> ModelPlan:
    """Memoized compile: same (model key, operating point) -> same plan.

    ``name`` is the cache identity — callers must use distinct keys for
    distinct weight sets, exactly as a serving runtime keys its loaded
    checkpoints.  A structural fingerprint of the defs guards the obvious
    misuse (same key, different architecture) — weight *values* are not
    hashed, so reusing a key for retrained weights of identical shape is
    still on the caller.
    """
    key = (name, point)
    fp = _defs_fingerprint(layer_defs)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        cached_fp, plan = cached
        if cached_fp != fp:
            raise ValueError(
                f"plan cache key {name!r} reused for a structurally "
                f"different model; use a distinct model key per weight set")
        _CACHE_STATS["hits"] += 1
        return plan
    _CACHE_STATS["misses"] += 1
    plan = compile_model(name, layer_defs, point)
    _PLAN_CACHE[key] = (fp, plan)
    return plan


def plan_cache_info() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_PLAN_CACHE))


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0
