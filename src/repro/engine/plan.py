"""Weight-stationary execution plans: compile a model ONCE, run it forever.

The paper's core economics are weight-stationary: DKVs are imprinted onto
the MRRs once and amortized over an entire position stream (Section VI-A).
The eager kernel wrappers (kernels/ops.py) betray that — every
`mixed_size_gemm` call re-pads the DKV matrix to MXU tiles or re-packs the
Mode-2 operand from scratch.  This module is the one-time DKV imprint:

    compile_model(name, layer_defs)  ->  ModelPlan

quantizes each layer's weights, routes it to Mode 1 / Mode 2 / the
depthwise VPU path, and materializes the *exact* operand the kernel wants
(Mode-1 tiles padded to MXU blocks, Mode-2 segment-sum packs, padded f32
bias rows).  Forward calls (engine/executor.py) never touch `jnp.pad` or
`pack_mode2_weights` on the weight side again.

Plans are memoized by (model key, operating point) in `get_plan`, mirroring
how a deployed TPC keeps a model's DKVs resident across requests.

Reconfiguration-aware planning (the paper's RCA headline): `plan_model`
sweeps, per layer, the simulator's reconfigurable comb-switch operating
points (core/mapping.point_options — re-aggregation widths x plus the
fixed Mode-1 geometry), scores each by memoized cycle-true layer time over
MRR utilization, charges a reconfiguration-latency penalty at every point
switch between consecutive layers (Viterbi over the option sequence), and
emits *heterogeneous per-layer* `EnginePoint`s into the `ModelPlan`.  Only
the packing geometry varies — quantization bits never do — so a planned
plan's outputs are bitwise-identical to the fixed-point plan's while its
mode census and point sequence follow the hardware search.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..cnn.layers import ConvKind, LayerSpec, dc, fc, pc, sc
from ..core import mapping, vdp
from ..core import photonics as ph
from ..core import simulator as sim
from ..core.photonics import InfeasiblePrecisionError
from ..core.tpc import (AcceleratorConfig, DIV_DAC_ENERGY_PER_SAMPLE_J,
                        RECONFIG_SWITCH_LATENCY_S, accelerator_at,
                        build_accelerator)
from ..kernels import ops
from ..kernels import vdpe_gemm as kern
from ..kernels.common import ACTIVATIONS, round_up as _round_up


@dataclasses.dataclass(frozen=True)
class EnginePoint:
    """The TPU operating point a plan is compiled for (the paper's (N, x))."""
    n: int = ops.N_TPU            # MXU contraction-lane budget
    x: int = ops.X_TPU            # Mode-2 re-aggregation segment width
    block_b: int = kern.BLOCK_B
    block_o: int = kern.BLOCK_O
    block_k: int = kern.BLOCK_K
    bits: int = 4                 # paper Section III-B quantization


DEFAULT_POINT = EnginePoint()


@dataclasses.dataclass(frozen=True)
class LayerDef:
    """One layer's weights + epilogue, the compiler's input.

    weights: SC/PC (F, K, K, D) — K=1 for PC; FC (F, D); DC (D, K, K).
    """
    name: str
    kind: ConvKind
    weights: jax.Array
    bias: Optional[jax.Array] = None
    act: str = "none"
    stride: int = 1
    padding: str = "SAME"

    def __post_init__(self) -> None:
        assert self.act in ACTIVATIONS, self.act


#: LayerPlan.mode values: paper Mode 1 / Mode 2, plus the depthwise VPU path
#: (per-channel S=K*K contractions — one kernel row per channel, executed as
#: a single batched integer contraction rather than F separate GEMMs).
MODE_DENSE, MODE_PACKED, MODE_DEPTHWISE = 1, 2, 0


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer, pre-packed for its kernel — the imprinted DKV state.

    ``point`` is the layer's *own* operating point: a fixed-point plan
    repeats the model point, a planner-compiled plan carries heterogeneous
    per-layer geometry (executor/pipeline read the packing from here).
    """
    name: str
    kind: ConvKind
    mode: int                 # MODE_DENSE | MODE_PACKED | MODE_DEPTHWISE
    k: int                    # spatial kernel size (1 for PC/FC)
    stride: int
    padding: str
    s: int                    # true contraction length S = K*K*D
    f: int                    # true output channels/units
    rhs: jax.Array            # packed int8 weights: mode1 (S_pad, F_pad),
                              # mode2 (x, F_pad), depthwise (D, K*K)
    w_scale: jax.Array        # () dequant scale; (D,) for depthwise
    bias: Optional[jax.Array]  # (1, F_pad) f32; (D,) for depthwise
    act: str
    point: EnginePoint

    @property
    def weight_bytes(self) -> int:
        """Resident HBM bytes of this layer's imprint: the pre-quantized
        int8 operand plus its f32 scale/bias metadata."""
        n = self.rhs.size * self.rhs.dtype.itemsize
        n += self.w_scale.size * 4
        if self.bias is not None:
            n += self.bias.size * 4
        return n

    @property
    def weight_bytes_f32(self) -> int:
        """What the same imprint would weigh streaming f32 operands."""
        n = self.rhs.size * 4 + self.w_scale.size * 4
        if self.bias is not None:
            n += self.bias.size * 4
        return n


@dataclasses.dataclass(frozen=True)
class ModelPlan:
    name: str
    point: EnginePoint        # base point (per-layer points may differ)
    layers: Tuple[LayerPlan, ...]
    planner: Optional["PlannerReport"] = None   # set by plan_model

    @property
    def mode_census(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for l in self.layers:
            out[l.mode] = out.get(l.mode, 0) + 1
        return out

    @property
    def points(self) -> Tuple[EnginePoint, ...]:
        """The per-layer engine point sequence (the jit-bucket identity)."""
        return tuple(l.point for l in self.layers)

    @property
    def point_labels(self) -> Optional[Tuple[str, ...]]:
        """Chosen hardware operating point per layer (planner plans only)."""
        return None if self.planner is None else self.planner.labels

    @property
    def layer_points(self) -> Dict[str, str]:
        """Operating point by layer name, for per-layer attribution.

        Empty for non-planner plans — every layer sits at the base point,
        so there is nothing layer-specific to report.
        """
        if self.planner is None:
            return {}
        return {c.name: c.option.label for c in self.planner.choices}

    @property
    def reconfig_switches(self) -> int:
        """Operating-point changes the plan pays between consecutive
        layers (0 for fixed-geometry plans)."""
        return 0 if self.planner is None else self.planner.switches

    @property
    def weight_bytes(self) -> int:
        """Resident HBM bytes of the whole imprint (int8 operands + f32
        scale/bias metadata) — what the serving registry reports."""
        return sum(l.weight_bytes for l in self.layers)

    @property
    def weight_bytes_f32(self) -> int:
        """The same imprint's footprint as f32 operand streams."""
        return sum(l.weight_bytes_f32 for l in self.layers)


def _quantize_rows(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization (depthwise: one scale per channel).

    Scales use the same explicit reciprocal multiply as
    vdp.quantize_symmetric (see vdp.inv_qmax) so plan-side weight scales
    stay bit-identical to the eager oracle's.
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=1),
                        1e-12) * vdp.inv_qmax(bits)
    q = jnp.clip(jnp.round(w / scale[:, None]), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _quantize_tensor(w: jax.Array, bits: int) -> Tuple[jax.Array, jax.Array]:
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) * vdp.inv_qmax(bits)
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compile_layer(ld: LayerDef, point: EnginePoint = DEFAULT_POINT,
                  ) -> LayerPlan:
    """Quantize + route + pack one layer (the per-layer DKV imprint)."""
    if ld.kind is ConvKind.DC:
        d, k, _ = ld.weights.shape
        dkvs = ld.weights.reshape(d, k * k)
        # per-channel scales: each channel is its own VDP with its own DAC
        # swing, matching core/vdp.depthwise_conv2d_vdp bit-for-bit
        dkvs_q, w_scale = _quantize_rows(dkvs, point.bits)
        bias = None
        if ld.bias is not None:
            bias = jnp.asarray(ld.bias, jnp.float32).reshape(d)
        return LayerPlan(name=ld.name, kind=ld.kind, mode=MODE_DEPTHWISE,
                         k=k, stride=ld.stride, padding=ld.padding,
                         s=k * k, f=d, rhs=dkvs_q, w_scale=w_scale,
                         bias=bias, act=ld.act, point=point)

    if ld.kind is ConvKind.FC:
        f, s = ld.weights.shape
        dkvs = ld.weights
        k = 1
    else:                                   # SC / PC: (F, K, K, D)
        f = ld.weights.shape[0]
        k = ld.weights.shape[1]
        dkvs = ld.weights.reshape(f, -1)
        s = dkvs.shape[1]
    dkvs_q, w_scale = _quantize_tensor(dkvs, point.bits)
    ff = _round_up(f, point.block_o)
    bias = None
    if ld.bias is not None:
        bias = jnp.pad(jnp.asarray(ld.bias, jnp.float32).reshape(1, f),
                       ((0, 0), (0, ff - f)))
    if 0 < point.x and s <= point.x:
        mode = MODE_PACKED
        rhs = jnp.pad(ops.pack_mode2_segments(dkvs_q, point.x),
                      ((0, 0), (0, ff - f)))
    else:
        mode = MODE_DENSE
        ss = _round_up(s, point.block_k)
        rhs = jnp.pad(dkvs_q.T, ((0, ss - s), (0, ff - f)))
    return LayerPlan(name=ld.name, kind=ld.kind, mode=mode, k=k,
                     stride=ld.stride, padding=ld.padding, s=s, f=f,
                     rhs=rhs, w_scale=w_scale, bias=bias, act=ld.act,
                     point=point)


def compile_model(name: str, layer_defs: Sequence[LayerDef],
                  point: EnginePoint = DEFAULT_POINT) -> ModelPlan:
    """Compile a whole model's pack-once plan (no caching — see get_plan)."""
    return ModelPlan(name=name, point=point,
                     layers=tuple(compile_layer(ld, point)
                                  for ld in layer_defs))


# ---------------------------------------------------------------------------
# Reconfiguration-aware planner: per-layer operating-point search
# ---------------------------------------------------------------------------

def defs_to_specs(layer_defs: Sequence[LayerDef],
                  input_shape: Tuple[int, int, int]) -> Tuple[LayerSpec, ...]:
    """Analytic LayerSpec table of an executable LayerDef chain.

    Walks the chain tracking spatial shape exactly as the executor does
    (vdp.out_hw), so the planner scores precisely the tensor products the
    engine will run (serve.models.specs_for_defs delegates here).
    """
    h, w, _ = input_shape
    specs: List[LayerSpec] = []
    for ld in layer_defs:
        if ld.kind is ConvKind.FC:
            f, s = ld.weights.shape
            specs.append(fc(ld.name, s, f))
            continue
        if ld.kind is ConvKind.DC:
            d, k, _ = ld.weights.shape
            h, w = vdp.out_hw(h, w, k, ld.stride, ld.padding)
            specs.append(dc(ld.name, k, d, h, w))
            continue
        f, k, _, d = ld.weights.shape
        h, w = vdp.out_hw(h, w, k, ld.stride, ld.padding)
        if ld.kind is ConvKind.PC:
            specs.append(pc(ld.name, d, f, h, w))
        else:
            specs.append(sc(ld.name, k, d, f, h, w))
    return tuple(specs)


#: Planner objectives: what the Viterbi search minimizes.
OBJECTIVES = ("latency", "edp", "energy")


@dataclasses.dataclass(frozen=True)
class LayerChoice:
    """The planner's verdict for one layer."""
    name: str
    option: mapping.PointOption
    time_s: float             # memoized simulate_layer time at the point
    utilization: float        # Fig. 6 per-VDPE utilization at the point
    modes: Tuple[int, ...]    # hardware slice modes the mapping selected
    #: modeled joules at the point, from the component ledger: the retuned
    #: accelerator's power_breakdown() sum charged for time_s, plus
    #: DIV-DAC switching per sample
    energy_j: float = 0.0
    #: the retuned accelerator's peak device power at the point (what the
    #: power_cap_w feasibility filter screens)
    point_power_w: float = 0.0

    @property
    def cost(self) -> float:
        """The latency objective: modeled time per utilized MRR fraction."""
        return self.time_s / max(self.utilization, 1e-9)

    def objective_cost(self, objective: str) -> float:
        """Per-layer DP cost under an objective.  ``edp`` uses the layer's
        own energy x time product as its additive proxy (the final plan is
        still selected by true total EDP — see search_points)."""
        if objective == "latency":
            return self.cost
        if objective == "energy":
            return self.energy_j
        return self.energy_j * self.time_s


@dataclasses.dataclass(frozen=True)
class PlannerReport:
    """One model's operating-point search result (attached to its plan)."""
    accelerator: AcceleratorConfig
    options: Tuple[mapping.PointOption, ...]
    choices: Tuple[LayerChoice, ...]
    switch_penalty_s: float
    switches: int             # point changes between consecutive layers
    total_time_s: float       # chosen layer times + switch penalties
    fixed_time_s: float       # every layer at the fixed Mode-1 geometry
    fixed_utilization: float  # time-weighted, at the fixed geometry
    batch: int
    #: option labels excluded by the Eq. 9 SNR feasibility filter (their
    #: comb-switch insertion loss starves the PD below the precision's
    #: minimum received power) — empty when the filter was off or nothing
    #: was dropped
    snr_excluded: Tuple[str, ...] = ()
    #: the objective the plan was selected under (OBJECTIVES)
    objective: str = "latency"
    #: peak-device-power cap the candidate points were screened against
    #: (None = unconstrained)
    power_cap_w: Optional[float] = None
    #: option labels excluded by the power cap (their retuned peak power
    #: exceeds ``power_cap_w``)
    cap_excluded: Tuple[str, ...] = ()
    #: ledger energy of the chosen sequence: per-layer component-ledger
    #: joules plus base static power charged for switch-penalty time
    total_energy_j: float = 0.0
    fixed_energy_j: float = 0.0

    @property
    def fps(self) -> float:
        return self.batch / self.total_time_s

    @property
    def fixed_fps(self) -> float:
        return self.batch / self.fixed_time_s

    @property
    def uplift(self) -> float:
        """Modeled planner-vs-fixed FPS ratio (the paper's RCA headline)."""
        return self.fixed_time_s / self.total_time_s

    @property
    def energy_per_frame_j(self) -> float:
        return self.total_energy_j / self.batch

    @property
    def avg_power_w(self) -> float:
        """Frame-averaged wall power of the chosen sequence."""
        return self.total_energy_j / self.total_time_s

    @property
    def edp(self) -> float:
        """Modeled energy-delay product of the chosen sequence."""
        return self.total_energy_j * self.total_time_s

    @property
    def fixed_edp(self) -> float:
        return self.fixed_energy_j * self.fixed_time_s

    @property
    def max_point_power_w(self) -> float:
        """Largest peak device power across the chosen points (always
        <= ``power_cap_w`` when a cap was set)."""
        return max(c.point_power_w for c in self.choices)

    @property
    def mean_utilization(self) -> float:
        """Time-weighted MRR utilization over the chosen point sequence."""
        return _time_weighted_utilization(self.choices)

    @property
    def labels(self) -> Tuple[str, ...]:
        return tuple(c.option.label for c in self.choices)


def _time_weighted_utilization(choices: Sequence["LayerChoice"]) -> float:
    t = sum(c.time_s for c in choices)
    return sum(c.utilization * c.time_s for c in choices) / max(t, 1e-30)


def _score_layer(acc: AcceleratorConfig, opt: mapping.PointOption,
                 spec: LayerSpec, batch: int) -> LayerChoice:
    acc_o = accelerator_at(acc, opt)
    rep = sim.simulate_layer(acc_o, spec, batch)
    util = mapping.vdpe_utilization_for_s(acc_o.tpc_config, spec.dkv_size)
    # ledger energy at the retuned point: its own static breakdown (the
    # lane-SE share moves with y) for the layer's time + DIV switching
    energy = (acc_o.power_static_w() * rep.time_s
              + rep.div_samples * DIV_DAC_ENERGY_PER_SAMPLE_J)
    return LayerChoice(name=spec.name, option=opt, time_s=rep.time_s,
                       utilization=util,
                       modes=tuple(sorted(rep.mapping.modes)),
                       energy_j=energy, point_power_w=acc_o.power_w())


def snr_feasible_options(acc: AcceleratorConfig,
                         options: Sequence[mapping.PointOption],
                         bits: int,
                         params: Optional[ph.PhotonicParams] = None,
                         ) -> Tuple[Tuple[mapping.PointOption, ...],
                                    Tuple[mapping.PointOption, ...]]:
    """Split operating points by the Eq. 9 SNR budget at ``bits``.

    An operating point is feasible when the laser power minus the link
    loss *at that point's comb-switch count* still delivers at least the
    minimum PD power the precision needs (``pd_power_for_precision``) —
    i.e. the received power closes the Eq. 9 SNR budget for ``bits``-bit
    ENOB at the accelerator's bit rate.  Each reconfigurable point pays
    its own y = n//x comb-switch insertion-loss pairs (the term that
    separates the points; link_loss_db's reconfigurable branch hardcodes
    the paper's x=9, so the CS term is rebuilt per option here).  The
    fixed Mode-1 point pays none, so it is feasible whenever anything is.

    Returns (kept, dropped), both preserving the input order — subsetting
    the candidate list never reorders ties, so Viterbi plans that avoided
    the dropped options are label-identical to unfiltered ones.

    Raises :class:`InfeasiblePrecisionError` when ``bits`` cannot close
    the budget at ANY received power (the RIN ceiling) — no operating
    point of any geometry can help there.
    """
    p = params if params is not None else ph.PhotonicParams()
    br_hz = acc.br_gbps * 1e9
    pd_w = ph.pd_power_for_precision(p, bits, br_hz)
    if pd_w is None:
        raise InfeasiblePrecisionError(
            bits, br_hz, "RIN ceiling exceeded at any received power")
    pd_dbm = ph.watt_to_dbm(pd_w)
    arch = ph.ARCHS[acc.name]
    base_loss = ph.link_loss_db(
        p, dataclasses.replace(arch, reconfigurable=False), acc.n, br_hz)
    kept, dropped = [], []
    for opt in options:
        y = accelerator_at(acc, opt).y
        loss = base_loss + y * arch.il_cs_db
        if p.laser_power_dbm - loss >= pd_dbm:
            kept.append(opt)
        else:
            dropped.append(opt)
    return tuple(kept), tuple(dropped)


def search_points(specs: Sequence[LayerSpec],
                  acc: Optional[AcceleratorConfig] = None,
                  options: Optional[Sequence[mapping.PointOption]] = None,
                  switch_penalty_s: Optional[float] = None,
                  batch: int = 1, bits: int = DEFAULT_POINT.bits,
                  snr_filter: bool = True, objective: str = "latency",
                  power_cap_w: Optional[float] = None) -> PlannerReport:
    """Per-layer operating-point search over a layer table (Viterbi).

    For every layer the candidate comb-switch points are scored by
    memoized cycle-true layer time / MRR utilization
    (``simulate_layer``, ``vdpe_utilization_for_s``); a reconfiguration
    penalty of ``switch_penalty_s`` (default: one EO comb-switch retune,
    ``RECONFIG_SWITCH_LATENCY_S``) is charged whenever two consecutive
    layers run at different points, so a higher switch cost monotonically
    drives the sequence toward fewer switches.  Ties keep the earlier
    option (the canonical geometry leads the candidate list) and prefer
    not switching, which makes the search deterministic in its inputs.

    With ``snr_filter`` (the default) the candidate points are first
    vetted against the Eq. 9 SNR budget at ``bits``
    (``snr_feasible_options``): a point whose comb-switch insertion loss
    starves the photodetector below the precision's minimum received
    power is excluded *before* the search, so emitted plans are
    noise-feasible by construction (dropped labels are recorded in
    ``PlannerReport.snr_excluded``).  Filtering only ever removes options
    — a search whose optimal path avoided them is unchanged — and raises
    :class:`InfeasiblePrecisionError` if no candidate survives.

    Under ``objective="latency"`` (the default) the DP cost is
    ``time_s / utilization`` per layer plus the raw switch penalty in
    seconds: dividing by utilization deliberately biases the search toward
    configurations that keep MRR area busy (the paper's stated selection
    criterion), which weights the penalty lightly against low-utilization
    layers.  Because the *reported* total is pure modeled time, the search
    falls back to the all-fixed sequence whenever its pick would lose in
    pure time — ``uplift >= 1`` always holds for the latency objective.

    ``objective="energy"`` / ``"edp"`` run the same Viterbi over the
    component-ledger joules (x time for EDP) as an additive proxy, then
    select among {objective path, latency path, fixed sequence} by the
    TRUE sequence total (energy, or energy x time) — so the EDP plan's
    EDP never exceeds the latency plan's, and the energy plan's joules
    never exceed either, by construction.  Objectives only reorder the
    operating-point choices; quantization bits never change, so plan
    outputs stay bitwise-identical across objectives.

    ``power_cap_w`` screens candidate points by *peak device power* at
    the retuned geometry (``accelerator_at(...).power_w()``) before the
    search, recording dropped labels in ``cap_excluded`` and raising
    ``ValueError`` when nothing survives.  The fixed Mode-1 point has the
    fewest sharing elements and hence the lowest peak power, so it
    survives any cap that is feasible at all.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"expected one of {OBJECTIVES}")
    if acc is None:
        acc = build_accelerator("RMAM", 1.0)
    opts = (mapping.point_options(acc.n) if options is None
            else tuple(options))
    if not opts:
        raise ValueError("search_points needs at least one PointOption")
    snr_excluded: Tuple[str, ...] = ()
    if snr_filter:
        kept, dropped = snr_feasible_options(acc, opts, bits)
        if not kept:
            raise InfeasiblePrecisionError(
                bits, acc.br_gbps * 1e9,
                "no operating point closes the SNR budget "
                f"(all of {[o.label for o in opts]} excluded)")
        if dropped:
            snr_excluded = tuple(o.label for o in dropped)
            opts = kept
    cap_excluded: Tuple[str, ...] = ()
    if power_cap_w is not None:
        kept_c, dropped_c = [], []
        for opt in opts:
            if accelerator_at(acc, opt).power_w() <= power_cap_w:
                kept_c.append(opt)
            else:
                dropped_c.append(opt)
        if not kept_c:
            raise ValueError(
                f"power_cap_w={power_cap_w} excludes every operating "
                f"point (min peak power "
                f"{min(accelerator_at(acc, o).power_w() for o in opts):.3f}"
                f" W across {[o.label for o in opts]})")
        if dropped_c:
            cap_excluded = tuple(o.label for o in dropped_c)
            opts = tuple(kept_c)
    penalty = (RECONFIG_SWITCH_LATENCY_S if switch_penalty_s is None
               else switch_penalty_s)
    specs = tuple(specs)
    if not specs:
        raise ValueError("search_points needs at least one layer")
    table = [[_score_layer(acc, opt, spec, batch) for opt in opts]
             for spec in specs]
    base_static_w = acc.power_static_w()

    def viterbi(cost_of, switch_cost):
        dp = [cost_of(table[0][j]) for j in range(len(opts))]
        back: List[List[int]] = []
        for i in range(1, len(specs)):
            best_k = 0
            for k in range(1, len(opts)):
                if dp[k] < dp[best_k]:
                    best_k = k
            ndp, nback = [], []
            for j in range(len(opts)):
                stay, switch = dp[j], dp[best_k] + switch_cost
                if stay <= switch:
                    prev, base = j, stay
                else:
                    prev, base = best_k, switch
                ndp.append(base + cost_of(table[i][j]))
                nback.append(prev)
            dp = ndp
            back.append(nback)
        j = 0
        for k in range(1, len(opts)):
            if dp[k] < dp[j]:
                j = k
        path = [j]
        for nback in reversed(back):
            j = nback[j]
            path.append(j)
        path.reverse()
        seq = tuple(table[i][path[i]] for i in range(len(specs)))
        return seq, sum(1 for a, b in zip(path, path[1:]) if a != b)

    def seq_time(seq, sw):
        return sum(c.time_s for c in seq) + sw * penalty

    def seq_energy(seq, sw):
        # switch downtime burns the base accelerator's static ledger power
        return (sum(c.energy_j for c in seq)
                + sw * penalty * base_static_w)

    choices, switches = viterbi(lambda c: c.cost, penalty)
    total = seq_time(choices, switches)
    if mapping.FIXED_POINT_OPTION in opts:
        fixed_j = opts.index(mapping.FIXED_POINT_OPTION)
        fixed = [row[fixed_j] for row in table]
    else:
        fixed = [_score_layer(acc, mapping.FIXED_POINT_OPTION, spec, batch)
                 for spec in specs]
    fixed_t = sum(c.time_s for c in fixed)
    if total > fixed_t:
        # the utilization-weighted objective can, on tables where the
        # fixed geometry is simply fastest, pick a sequence that loses in
        # pure time — never ship a plan worse than the baseline it is
        # measured against
        choices, switches, total = tuple(fixed), 0, fixed_t
    if objective != "latency":
        sw_cost = penalty * base_static_w      # joules per switch
        if objective == "edp":
            sw_cost *= penalty                 # J x s per switch (proxy)
        obj_seq, obj_sw = viterbi(
            lambda c: c.objective_cost(objective), sw_cost)
        # the additive DP cost is only a proxy (per-layer EDP does not sum
        # to sequence EDP) — select among {objective path, latency path,
        # fixed} by the TRUE sequence total, which also makes
        # "edp plan's EDP <= latency plan's" hold by construction
        candidates = [(obj_seq, obj_sw), (choices, switches),
                      (tuple(fixed), 0)]

        def metric(seq, sw):
            e = seq_energy(seq, sw)
            return e if objective == "energy" else e * seq_time(seq, sw)

        choices, switches = min(candidates, key=lambda c: metric(*c))
        total = seq_time(choices, switches)
    return PlannerReport(accelerator=acc, options=opts, choices=choices,
                         switch_penalty_s=penalty, switches=switches,
                         total_time_s=total, fixed_time_s=fixed_t,
                         fixed_utilization=_time_weighted_utilization(fixed),
                         batch=batch, snr_excluded=snr_excluded,
                         objective=objective, power_cap_w=power_cap_w,
                         cap_excluded=cap_excluded,
                         total_energy_j=seq_energy(choices, switches),
                         fixed_energy_j=seq_energy(fixed, 0))


def _engine_point_for(base: EnginePoint, ld: LayerDef, spec: LayerSpec,
                      choice: LayerChoice) -> EnginePoint:
    """Map a chosen hardware point onto the layer's engine geometry.

    The engine analogue of the comb-switch decision: a layer the hardware
    runs entirely in Mode 2 packs its segments (width rounded up to the
    int8 sublane tile so contractions up to the chosen re-aggregation
    reach still pack); a layer with any Mode-1 slice runs the dense path
    with the re-aggregation segments parked (x = 0).  Quantization bits
    are never touched, which is what keeps planned plans bitwise-equal to
    fixed-point plans.
    """
    if ld.kind is ConvKind.DC:
        return base               # depthwise VPU path has no GEMM packing
    if choice.option.reconfigurable and set(choice.modes) == {2}:
        return dataclasses.replace(
            base, x=max(base.x, _round_up(spec.dkv_size, 32)))
    return dataclasses.replace(base, x=0)


# the per-layer point-search memo: (model, acc, options, penalty, batch)
# -> (spec table, report); evicted per model with the registry's LRU
_SEARCH_CACHE: Dict[tuple, Tuple[tuple, PlannerReport]] = {}
_SEARCH_STATS = {"hits": 0, "misses": 0}


def cached_search(name: str, specs: Sequence[LayerSpec],
                  acc: Optional[AcceleratorConfig] = None,
                  options: Optional[Sequence[mapping.PointOption]] = None,
                  switch_penalty_s: Optional[float] = None,
                  batch: int = 1, bits: int = DEFAULT_POINT.bits,
                  snr_filter: bool = True, objective: str = "latency",
                  power_cap_w: Optional[float] = None) -> PlannerReport:
    """Memoized ``search_points``, keyed like ``get_plan`` (model name =
    identity, spec table as the structural guard)."""
    specs = tuple(specs)
    key = (name, acc, None if options is None else tuple(options),
           switch_penalty_s, batch, bits, snr_filter, objective,
           power_cap_w)
    cached = _SEARCH_CACHE.get(key)
    if cached is not None:
        cached_specs, report = cached
        if cached_specs != specs:
            raise ValueError(
                f"planner search cache key {name!r} reused for a "
                f"structurally different model; use a distinct model key "
                f"per weight set")
        _SEARCH_STATS["hits"] += 1
        return report
    _SEARCH_STATS["misses"] += 1
    report = search_points(specs, acc=acc, options=options,
                           switch_penalty_s=switch_penalty_s, batch=batch,
                           bits=bits, snr_filter=snr_filter,
                           objective=objective, power_cap_w=power_cap_w)
    _SEARCH_CACHE[key] = (specs, report)
    return report


def search_cache_evict(name: str) -> int:
    """Drop a model's point-search memo entries (registry eviction hook)."""
    stale = [k for k in _SEARCH_CACHE if k[0] == name]
    for k in stale:
        del _SEARCH_CACHE[k]
    return len(stale)


def plan_model(name: str, layer_defs: Sequence[LayerDef],
               input_shape: Tuple[int, int, int],
               point: EnginePoint = DEFAULT_POINT,
               acc: Optional[AcceleratorConfig] = None,
               options: Optional[Sequence[mapping.PointOption]] = None,
               switch_penalty_s: Optional[float] = None,
               objective: str = "latency",
               power_cap_w: Optional[float] = None) -> ModelPlan:
    """Compile a model with per-layer operating points (the RCA planner).

    Same inputs as ``compile_model`` plus the model's input shape (the
    planner needs the spatial walk to score positions), returning a
    ``ModelPlan`` whose layers carry heterogeneous ``EnginePoint``s and
    whose ``planner`` field records the search.  ``objective`` picks the
    Viterbi metric (OBJECTIVES) and ``power_cap_w`` screens candidate
    points by peak device power — see ``search_points``.  Outputs are
    bitwise-identical to ``compile_model(name, layer_defs, point)``
    under every objective/cap — only packing geometry differs, never
    quantization.
    """
    specs = defs_to_specs(layer_defs, input_shape)
    report = cached_search(name, specs, acc=acc, options=options,
                           switch_penalty_s=switch_penalty_s,
                           bits=point.bits, objective=objective,
                           power_cap_w=power_cap_w)
    layers = tuple(
        compile_layer(ld, _engine_point_for(point, ld, spec, choice))
        for ld, spec, choice in zip(layer_defs, specs, report.choices))
    return ModelPlan(name=name, point=point, layers=layers, planner=report)


# ---------------------------------------------------------------------------
# Plan cache: one imprint per (model, operating point)
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[Tuple[str, EnginePoint], Tuple[tuple, ModelPlan]] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def _defs_fingerprint(layer_defs: Sequence[LayerDef]) -> tuple:
    """Cheap structural identity of a model's defs (no weight hashing)."""
    return tuple((ld.name, ld.kind, tuple(ld.weights.shape),
                  ld.bias is not None, ld.act, ld.stride, ld.padding)
                 for ld in layer_defs)


def get_plan(name: str, layer_defs: Sequence[LayerDef],
             point: EnginePoint = DEFAULT_POINT) -> ModelPlan:
    """Memoized compile: same (model key, operating point) -> same plan.

    ``name`` is the cache identity — callers must use distinct keys for
    distinct weight sets, exactly as a serving runtime keys its loaded
    checkpoints.  A structural fingerprint of the defs guards the obvious
    misuse (same key, different architecture) — weight *values* are not
    hashed, so reusing a key for retrained weights of identical shape is
    still on the caller.
    """
    key = (name, point)
    fp = _defs_fingerprint(layer_defs)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        cached_fp, plan = cached
        if cached_fp != fp:
            raise ValueError(
                f"plan cache key {name!r} reused for a structurally "
                f"different model; use a distinct model key per weight set")
        _CACHE_STATS["hits"] += 1
        return plan
    _CACHE_STATS["misses"] += 1
    plan = compile_model(name, layer_defs, point)
    _PLAN_CACHE[key] = (fp, plan)
    return plan


def plan_cache_info() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_PLAN_CACHE),
                search_hits=_SEARCH_STATS["hits"],
                search_misses=_SEARCH_STATS["misses"],
                search_size=len(_SEARCH_CACHE))


def plan_cache_clear() -> None:
    """Clear the pack cache AND the per-layer point-search memo."""
    _PLAN_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0
    _SEARCH_CACHE.clear()
    _SEARCH_STATS["hits"] = _SEARCH_STATS["misses"] = 0
