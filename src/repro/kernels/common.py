"""Shared kernel-side helpers: MXU alignment, quantize prologue, epilogue.

Single home for the ``round_up``/``pad_to`` alignment arithmetic that was
copy-pasted across kernels/ops.py, engine/plan.py and engine/executor.py,
and for the two numeric expressions every quantized-domain kernel shares:

* ``quantize_tile`` — the symmetric-quantizer expression (divide by the
  DAC scale, round, clip, int8).  The fused in-kernel prologues
  (vdpe_gemm_q8, vdpe_conv_q8) and every XLA-side quantize in the engine
  (executor._quantize_per_image, the depthwise and float-oracle paths)
  must round onto the *same integer lattice* for the int8 path to be
  bitwise equal to the quantize-then-float oracle, so they all spell the
  expression through this one helper (built on core/vdp.inv_qmax, the
  single home of the reciprocal-multiply DAC constant).
  core/vdp.quantize_symmetric is the seed paper-reference twin: it spells
  the identical expression but stays standalone (core cannot import the
  kernel package back) — keep the two in sync if the lattice ever
  changes.

* ``dequant_epilogue`` — the fused epilogue ``act(acc * scale + bias)``
  consuming the int32 (or exact-f32) accumulator directly.  The GEMM
  kernels (vdpe_gemm.py) and the implicit-GEMM conv kernels
  (vdpe_conv.py) apply the identical expression, which is what keeps the
  paths bitwise-comparable.

``stable_scale`` pins a DAC scale against XLA algebraic reassociation
(the PR-3 reciprocal/optimization_barrier lesson): the scale is
``absmax * (1/qmax)`` with 1/qmax a compile-time constant, and under a
whole-model jit XLA's simplifier reassociates its later multiply by the
weight scale — ``(m * c) * w -> m * (c * w)`` — shifting the epilogue
scale by 1 ulp, which the quantizer's round() amplifies into integer
flips.  The barrier freezes the association in eager, per-kernel-jit,
whole-model-jit AND in-kernel-prologue regimes alike (interpret-mode
kernel bodies are jax-traced and run through the same simplifier).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# THE reciprocal-multiply DAC constant, re-exported from its single home
# (core does not import kernels, so this direction is cycle-free); the
# lattice expression below (quantize_tile) builds on it
from ..core.vdp import inv_qmax  # noqa: F401

#: Fused-epilogue activations supported by every kernel in this package.
ACTIVATIONS = ("none", "relu", "relu6")


def round_up(v: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= ``v``."""
    return (v + mult - 1) // mult * mult


def pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def apply_act(r: jax.Array, act: str) -> jax.Array:
    """Compile-time activation branch of the fused epilogue."""
    if act == "relu":
        return jnp.maximum(r, 0.0)
    if act == "relu6":
        return jnp.clip(r, 0.0, 6.0)
    assert act == "none", act
    return r


def qmax_for(bits: int) -> int:
    """Largest symmetric quantization level for ``bits`` signed bits."""
    return 2 ** (bits - 1) - 1


def stable_scale(x: jax.Array) -> jax.Array:
    """Pin a DAC scale against XLA algebraic reassociation (module doc)."""
    return jax.lax.optimization_barrier(x)


def quantize_tile(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """THE symmetric-quantizer expression: round x/scale onto the int8
    lattice.  ``scale`` broadcasts (scalar, per-row column, per-channel)."""
    q = qmax_for(bits)
    return jnp.clip(jnp.round(x / scale), -q, q).astype(jnp.int8)


def dequant_epilogue(acc: jax.Array, scale: jax.Array, bias: jax.Array,
                     act: str) -> jax.Array:
    """THE fused epilogue: act(acc * scale + bias), f32 out.

    ``acc`` is the int32 MXU accumulator (or the bit-identical exact-f32
    accumulator of the float oracle path); ``scale`` broadcasts.
    """
    return apply_act(acc.astype(jnp.float32) * scale + bias, act)
