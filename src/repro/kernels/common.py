"""Shared kernel-side helpers: MXU alignment and the fused-epilogue branch.

Single home for the ``round_up``/``pad_to`` alignment arithmetic that was
copy-pasted across kernels/ops.py, engine/plan.py and engine/executor.py,
and for the compile-time activation branch every fused epilogue shares —
the GEMM kernels (vdpe_gemm.py) and the implicit-GEMM conv kernels
(vdpe_conv.py) apply the identical ``act(acc * scale + bias)`` expression,
which is what keeps the two paths bitwise-comparable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Fused-epilogue activations supported by every kernel in this package.
ACTIVATIONS = ("none", "relu", "relu6")


def round_up(v: int, mult: int) -> int:
    """Smallest multiple of ``mult`` >= ``v``."""
    return (v + mult - 1) // mult * mult


def pad_to(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def apply_act(r: jax.Array, act: str) -> jax.Array:
    """Compile-time activation branch of the fused epilogue."""
    if act == "relu":
        return jnp.maximum(r, 0.0)
    if act == "relu6":
        return jnp.clip(r, 0.0, 6.0)
    assert act == "none", act
    return r
