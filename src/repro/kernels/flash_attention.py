"""Fused (flash) attention forward — Pallas TPU kernel.

The §Perf Cell-B analysis shows the (B, H, S, T) score tensor dominates
train/prefill memory traffic; on TPU the answer is to never materialize it
in HBM.  This kernel computes one (q-block × head) tile with an online-
softmax running (max, sum) state, streaming K/V blocks through VMEM:

    HBM traffic = Q + K + V + O        (vs  Q+K+V+O + 2·S·T scores)

Forward-only (serving/prefill path; training keeps the XLA attention whose
backward is generated automatically).  Causal masking by absolute position;
GQA via q-head -> kv-head grouping handled in ops.flash_attention.
Validated against a pure-jnp oracle in interpret mode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool,
                  sm_scale: float, block_k: int, kv_len: int):
    """One (batch*head, q-block) tile; loops KV blocks with online softmax.

    Block refs: q (1, block_q, hd); k/v (1, kv_len, hd); o (1, block_q, hd).
    """
    _, block_q, hd = q_ref.shape
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_all = k_ref[0]
    v_all = v_ref[0]

    def body(start, carry):
        acc, m_prev, l_prev = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_all, start * block_k, block_k, 0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_all, start * block_k, block_k, 0).astype(jnp.float32)
        s = q @ k.T                                     # (bq, bk)
        k_pos = start * block_k + jax.lax.iota(jnp.int32, block_k)
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    n_kv = kv_len // block_k
    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, S, hd); k/v: (BH, T, hd) -> (BH, S, hd).

    S % block_q == 0 and T % block_k == 0 (ops.py pads).
    """
    bh, s, hd = q.shape
    _, t, _ = k.shape
    assert s % block_q == 0 and t % block_k == 0
    sm_scale = 1.0 / math.sqrt(hd)
    grid = (bh, s // block_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, sm_scale=sm_scale,
                          block_k=block_k, kv_len=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
