"""Pallas grouped (ragged) GEMM for MoE expert compute.

The paper's mixed-size-tensor problem reappears in MoE layers: each expert
serves a different-sized token group, and padding every group to the max
wastes MXU passes exactly like S < N strands MRRs.  This kernel runs one
token-block per grid row with the expert id scalar-prefetched, so a block
reads ONLY its expert's weight tile — groups are padded to the block size
(128) instead of the max group size.

Layout contract (prepared by ops.grouped_matmul):
    tokens   (T_pad, D)   — sorted by expert, each group padded to block_t
    weights  (E, D, H)
    block_to_expert (T_pad / block_t,) int32 — scalar-prefetched map
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128
BLOCK_H = 128


def _kernel(block_to_expert, tokens_ref, w_ref, out_ref):
    del block_to_expert  # consumed by the index maps
    out_ref[...] = jax.lax.dot_general(
        tokens_ref[...], w_ref[0],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_h",
                                             "interpret"))
def grouped_matmul_kernel(tokens: jax.Array, weights: jax.Array,
                          block_to_expert: jax.Array,
                          block_t: int = BLOCK_T, block_h: int = BLOCK_H,
                          interpret: bool = True) -> jax.Array:
    t_pad, d = tokens.shape
    e, _, h = weights.shape
    assert t_pad % block_t == 0 and h % block_h == 0
    nb = t_pad // block_t
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, h // block_h),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j, bmap: (i, 0)),
            pl.BlockSpec((1, d, block_h), lambda i, j, bmap: (bmap[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_t, block_h), lambda i, j, bmap: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, h), jnp.float32),
        interpret=interpret,
    )(block_to_expert, tokens, weights)
