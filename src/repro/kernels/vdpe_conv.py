"""Implicit-GEMM convolution Pallas kernels: no im2col matrix in HBM.

The im2col -> GEMM path materializes the full (B, P, K*K*D) DIV matrix in
HBM — a K^2x blow-up of the activation footprint — before the GEMM reads
it back.  The photonic accelerator never pays that: DIV streams are formed
on the fly from the activation map as they enter the VDPE lanes.  These
kernels are the software analogue: the activation rides to VMEM *once* at
its natural NHWC size, and each kernel instance gathers its K*K patch taps
with in-kernel strided loads, contracting each tap's (P, D) window D-deep
against the matching D-row band of the resident packed DKV operand.  The
K*K-tap loop is unrolled at trace time (K is static), so the full
S = K*K*D contraction accumulates in registers/VMEM and the DIV matrix
never exists anywhere.

Quantized-domain entry points (the serving hot path):

* ``vdpe_conv_q8`` — Mode 1: the *raw f32* activation map enters the
  kernel and the whole input-DAC stage runs in the prologue, off the VMEM
  tile: covered-window absmax (the exact pixel set the taps enumerate),
  DAC scale ``max(absmax, 1e-12) * (1/qmax)``, and the int8 quantize.
  The separate XLA absmax/round/clip passes of the pre-quantized path —
  two f32 reads plus an int8 round-trip of the activation through HBM —
  collapse into the kernel's single activation fetch.

* ``vdpe_pack_conv_zs_q8`` — Mode 2, zero-skipping, same fused prologue.

Pre-quantized entry points (oracles + the im2col baseline):

* ``vdpe_conv`` — Mode 1 over an already-quantized activation: rhs is
  the plan's (S_pad, F_pad) MXU-tiled operand; only the first K*K*D rows
  are read, as D-row bands.  Accepts int8 or lattice-f32 operands (f32
  accumulation of int8 products is exact — the quantize-then-float
  oracle's conv).

* ``vdpe_pack_conv_zs`` — Mode 2, zero-skipping: rhs is the (x, F_pad)
  dense segment-sum pack (ops.pack_mode2_segments), never the (y*x, F)
  block-diagonal — asserted structurally, like vdpe_pack_gemm_zs.  The
  contraction is S-deep (S <= x), so the kernel keeps both wins at once:
  no im2col blow-up AND no (y-1)/y zero-FLOPs.

All carry the fused dequant/bias/ReLU(6) epilogue from the GEMM kernels
(kernels/common.dequant_epilogue): a scalar ``scale`` rides SMEM; the
batched engine's per-image dequant scales ride SMEM too, one (1, 1) block
indexed by the image grid axis — per-image is the conv twin of the GEMM
kernels' per-row scale, because every position of image b shares b's
input-DAC swing.  The q8 kernels need no scale input at all: the image's
DAC scale is born in the prologue and multiplied by the plan's scalar
``w_scale`` in-kernel (same association as the oracle paths, pinned by
``common.stable_scale`` against XLA reassociation).  ``bias`` is blocked
over the output-channel axis.

Grid: (B, F_pad / block_o).  Per instance, VMEM holds one image's padded
activation map (Hp, Wp, D) plus one (S_rows, block_o) weight block — for
the paper CNNs' conv shapes that is far below the ~16 MB VMEM budget (the
largest, 112x112x64 f32, is ~3.2 MB).  Unlike the Mode-1 GEMM's K axis,
the conv stream operand (the next image's activation) is already
double-buffered by the Pallas grid pipeline itself: every block index map
here is grid-linear and each output tile is visited exactly once, so the
revolving-window prefetch of instance (b+1, j) overlaps instance (b, j)'s
MXU passes without manual DMA.  Validated in interpret mode (CI is
CPU-only) against the im2col oracle; a first real-TPU run should confirm
the Mosaic lowering of the strided window loads like any other kernel
change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (dequant_epilogue, inv_qmax, quantize_tile,
                     stable_scale)
from .vdpe_gemm import BLOCK_O, _acc_dtype


def conv_window_bounds(k: int, stride: int, ho: int, wo: int) -> tuple:
    """(min Hp, min Wp) the padded activation must satisfy for the taps.

    Tap (di, dj) reads rows di, di+stride, ..., di+stride*(ho-1); with
    di <= k-1 the last read is at stride*(ho-1) + k - 1.  Shared with the
    executor's spatial padding and the tests' structural checks.
    """
    return stride * (ho - 1) + k, stride * (wo - 1) + k


def tap_window(x: jax.Array, di: int, dj: int, stride: int,
               ho: int, wo: int) -> jax.Array:
    """Tap (di, dj)'s strided window: (..., Hp, Wp, D) -> (..., ho, wo, D).

    THE tap-geometry definition: the executor's covered-set quantization
    max, the depthwise taps, this kernel's gather AND the q8 prologue's
    in-kernel absmax must enumerate exactly the same pixels for the
    bitwise contract with the im2col oracle to hold, so they all slice
    through this one helper.
    """
    return x[..., di:di + stride * (ho - 1) + 1:stride,
             dj:dj + stride * (wo - 1) + 1:stride, :]


def _gather_tap(xb: jax.Array, di: int, dj: int, stride: int,
                ho: int, wo: int, d: int) -> jax.Array:
    """One tap's (ho*wo, D) window, strided-loaded from the VMEM image."""
    return tap_window(xb, di, dj, stride, ho, wo).reshape(ho * wo, d)


def _accumulate_taps(xb: jax.Array, rhs_ref, *, k: int, stride: int,
                     ho: int, wo: int, d: int) -> jax.Array:
    """The implicit-GEMM body: K*K tap gathers, each contracted D deep.

    Integer accumulation is associative (and exact in f32 for the lattice
    oracle operands), so the tap-major sum is bit-identical to the single
    S-deep im2col contraction.
    """
    acc_dtype = _acc_dtype(xb.dtype)
    acc = None
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        lhs = _gather_tap(xb, di, dj, stride, ho, wo, d)
        part = jax.lax.dot_general(
            lhs, rhs_ref[kk * d:(kk + 1) * d, :], (((1,), (0,)), ((), ())),
            preferred_element_type=acc_dtype)
        acc = part if acc is None else acc + part
    return acc                                   # (ho*wo, block_o)


def _conv_accumulate(x_ref, rhs_ref, *, k: int, stride: int, ho: int,
                     wo: int, d: int) -> jax.Array:
    return _accumulate_taps(x_ref[0], rhs_ref, k=k, stride=stride,
                            ho=ho, wo=wo, d=d)


def _conv_kernel(x_ref, rhs_ref, out_ref, *, k, stride, ho, wo, d):
    out_ref[0] = _conv_accumulate(x_ref, rhs_ref, k=k, stride=stride,
                                  ho=ho, wo=wo, d=d)


def _conv_epilogue_kernel(scale_ref, x_ref, rhs_ref, bias_ref, out_ref,
                          *, k, stride, ho, wo, d, act):
    """Fused epilogue: the (1, 1) SMEM scale block is the whole-stream
    scalar or — indexed by the image grid axis — image b's dequant scale."""
    acc = _conv_accumulate(x_ref, rhs_ref, k=k, stride=stride,
                           ho=ho, wo=wo, d=d)
    out_ref[0] = dequant_epilogue(acc, scale_ref[0, 0], bias_ref[...], act)


def _conv_q8_kernel(w_scale_ref, x_ref, rhs_ref, bias_ref, out_ref,
                    *, k, stride, ho, wo, d, bits, act):
    """Quantized-domain body: the whole input-DAC stage in the prologue.

    The f32 image tile is already in VMEM, so the covered-window absmax
    (the exact pixel set the taps enumerate — a strided layer can leave
    border pixels uncovered, and the whole-image max would break the
    bitwise contract with the im2col oracle), the DAC scale and the int8
    quantize all run in-kernel; the XLA-side passes disappear.

    Known tradeoff: the prologue runs per grid instance, so a layer with
    F_pad / block_o > 1 recomputes the absmax+quantize of its image once
    per output-channel block (the serving zoo's layers all fit one block;
    for wide-F layers, hoisting the scale to SMEM like the FC path's
    _row_dac_scales would trade one XLA absmax pass for the recompute).
    """
    xb = x_ref[0]                                # (Hp, Wp, D) f32
    m = None
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        wm = jnp.max(jnp.abs(tap_window(xb, di, dj, stride, ho, wo)))
        m = wm if m is None else jnp.maximum(m, wm)
    # same expression, same association, same barrier as the XLA-side
    # oracle (executor._window_absmax + common.stable_scale): the barrier
    # keeps the jitted simplifier from reassociating the later w_scale
    # multiply and shifting the scale by 1 ulp (the PR-3 lesson)
    a_scale = stable_scale(jnp.maximum(m, 1e-12) * inv_qmax(bits))
    x_q = quantize_tile(xb, a_scale, bits)
    acc = _accumulate_taps(x_q, rhs_ref, k=k, stride=stride, ho=ho, wo=wo,
                           d=d)
    out_ref[0] = dequant_epilogue(acc, a_scale * w_scale_ref[0, 0],
                                  bias_ref[...], act)


def _conv_call(x_q: jax.Array, rhs: jax.Array, k: int, stride: int,
               ho: int, wo: int, block_o: int, interpret: bool,
               scale, bias, act: str, quantize_bits: int | None = None,
               w_scale=None) -> jax.Array:
    b, hp, wp, d = x_q.shape
    s_rows, f_pad = rhs.shape
    min_h, min_w = conv_window_bounds(k, stride, ho, wo)
    assert hp >= min_h and wp >= min_w, (
        f"activation ({hp}, {wp}) too small for {k}x{k}/s{stride} taps over "
        f"({ho}, {wo}) outputs; pad to at least ({min_h}, {min_w})")
    assert k * k * d <= s_rows, (k, d, s_rows)
    assert f_pad % block_o == 0, (f_pad, block_o)
    p = ho * wo
    grid = (b, f_pad // block_o)
    x_spec = pl.BlockSpec((1, hp, wp, d), lambda i, j: (i, 0, 0, 0))
    rhs_spec = pl.BlockSpec((s_rows, block_o), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((1, p, block_o), lambda i, j: (i, 0, j))
    if bias is None and (quantize_bits is not None or scale is not None):
        bias = jnp.zeros((1, f_pad), jnp.float32)
    if quantize_bits is not None:                # fused-quantize q8 path
        assert scale is None, "q8 path derives the DAC scale in-kernel"
        assert rhs.dtype == jnp.int8, rhs.dtype
        return pl.pallas_call(
            functools.partial(_conv_q8_kernel, k=k, stride=stride, ho=ho,
                              wo=wo, d=d, bits=quantize_bits, act=act),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                             memory_space=pltpu.SMEM),
                x_spec, rhs_spec,
                pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, p, f_pad), jnp.float32),
            interpret=interpret,
        )(jnp.asarray(w_scale, jnp.float32).reshape(1, 1),
          x_q.astype(jnp.float32), rhs, bias)
    if scale is None:
        assert bias is None and act == "none", "epilogue requires a scale"
        return pl.pallas_call(
            functools.partial(_conv_kernel, k=k, stride=stride, ho=ho,
                              wo=wo, d=d),
            grid=grid,
            in_specs=[x_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, p, f_pad),
                                           _acc_dtype(x_q.dtype)),
            interpret=interpret,
        )(x_q, rhs)
    scale = jnp.asarray(scale, jnp.float32)
    if scale.size == 1:                # one swing for the whole stream
        scale_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                                  memory_space=pltpu.SMEM)
        scale = scale.reshape(1, 1)
    else:                              # per-image input-DAC swings
        if scale.size != b:
            raise ValueError(
                f"per-image scale must have one entry per image ({b}), "
                f"got shape {scale.shape}")
        scale_spec = pl.BlockSpec((1, 1), lambda i, j: (i, 0),
                                  memory_space=pltpu.SMEM)
        scale = scale.reshape(b, 1)
    return pl.pallas_call(
        functools.partial(_conv_epilogue_kernel, k=k, stride=stride,
                          ho=ho, wo=wo, d=d, act=act),
        grid=grid,
        in_specs=[
            scale_spec, x_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, p, f_pad), jnp.float32),
        interpret=interpret,
    )(scale, x_q, rhs, bias)


@functools.partial(jax.jit, static_argnames=("k", "stride", "ho", "wo",
                                             "block_o", "interpret", "act"))
def vdpe_conv(x_q: jax.Array, rhs: jax.Array, k: int, stride: int,
              ho: int, wo: int, block_o: int = BLOCK_O,
              interpret: bool = True,
              scale: jax.Array | None = None,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """Mode-1 implicit-GEMM conv over a *pre-quantized* activation.

    ``x_q`` is the quantized activation (int8, or the same lattice held
    in f32 for the quantize-then-float oracle), already spatially padded
    for the layer's SAME/VALID policy (conv_window_bounds gives the
    minimum).  ``rhs`` is the plan's Mode-1 (S_pad, F_pad) operand; rows
    beyond K*K*D padding are never read.  Without ``scale`` the result is
    the raw accumulator; with it the f32 epilogue ``act(acc * scale +
    bias)`` is fused.  ``scale`` may be a scalar or a per-image (B,) /
    (B, 1) vector.  The caller slices F_pad -> F and reshapes
    P -> (ho, wo).
    """
    return _conv_call(x_q, rhs, k, stride, ho, wo, block_o, interpret,
                      scale, bias, act)


@functools.partial(jax.jit, static_argnames=("k", "stride", "ho", "wo",
                                             "bits", "block_o", "interpret",
                                             "act"))
def vdpe_conv_q8(x: jax.Array, rhs: jax.Array, w_scale: jax.Array, k: int,
                 stride: int, ho: int, wo: int, bits: int = 4,
                 block_o: int = BLOCK_O, interpret: bool = True,
                 bias: jax.Array | None = None,
                 act: str = "none") -> jax.Array:
    """Quantized-domain Mode-1 conv: raw f32 activation in, DAC in-kernel.

    ``x`` is the *unquantized* f32 activation (spatially padded as for
    ``vdpe_conv``); the kernel prologue computes the covered-window
    absmax, the per-image DAC scale and the int8 quantize off the VMEM
    tile, and the fused epilogue dequantizes with ``a_scale * w_scale``.
    Bitwise-identical to quantizing in XLA and calling ``vdpe_conv``.
    """
    return _conv_call(x, rhs, k, stride, ho, wo, block_o, interpret,
                      None, bias, act, quantize_bits=bits, w_scale=w_scale)


@functools.partial(jax.jit, static_argnames=("k", "stride", "ho", "wo", "x",
                                             "block_o", "interpret", "act"))
def vdpe_pack_conv_zs(x_q: jax.Array, rhs_seg: jax.Array, k: int,
                      stride: int, ho: int, wo: int, x: int,
                      block_o: int = BLOCK_O, interpret: bool = True,
                      scale: jax.Array | None = None,
                      bias: jax.Array | None = None,
                      act: str = "none") -> jax.Array:
    """Zero-skipping Mode-2 implicit-GEMM conv (small S = K*K*D <= x).

    ``rhs_seg`` must be the dense (x, F_pad) segment-sum pack
    (ops.pack_mode2_segments) — the (y*x, F) block-diagonal operand is
    structurally rejected, same as vdpe_pack_gemm_zs: the contraction this
    kernel issues is S-deep, never y*x-deep.
    """
    d = x_q.shape[3]
    assert rhs_seg.shape[0] == x, (
        f"rhs must be the (x={x}, F) segment-sum pack, got "
        f"{rhs_seg.shape} (block-diagonal operands are rejected)")
    assert k * k * d <= x, (k, d, x)
    return _conv_call(x_q, rhs_seg, k, stride, ho, wo, block_o, interpret,
                      scale, bias, act)


@functools.partial(jax.jit, static_argnames=("k", "stride", "ho", "wo", "x",
                                             "bits", "block_o", "interpret",
                                             "act"))
def vdpe_pack_conv_zs_q8(xa: jax.Array, rhs_seg: jax.Array,
                         w_scale: jax.Array, k: int, stride: int, ho: int,
                         wo: int, x: int, bits: int = 4,
                         block_o: int = BLOCK_O, interpret: bool = True,
                         bias: jax.Array | None = None,
                         act: str = "none") -> jax.Array:
    """Quantized-domain zero-skipping Mode-2 conv (fused DAC prologue).

    ``xa`` is the raw f32 activation; the segment-sum pack contract is
    the same as ``vdpe_pack_conv_zs``.
    """
    d = xa.shape[3]
    assert rhs_seg.shape[0] == x, (
        f"rhs must be the (x={x}, F) segment-sum pack, got "
        f"{rhs_seg.shape} (block-diagonal operands are rejected)")
    assert k * k * d <= x, (k, d, x)
    return _conv_call(xa, rhs_seg, k, stride, ho, wo, block_o, interpret,
                      None, bias, act, quantize_bits=bits, w_scale=w_scale)
