"""jit'd wrappers around the Pallas VDPE kernels: padding, packing, routing.

`mixed_size_gemm` is the public entry point the framework layers use: given
a DIV matrix and a DKV matrix of arbitrary contraction size S, it routes to
the Mode-1 K-blocked kernel (S >= the MXU lane budget) or the zero-skipping
Mode-2 segment-sum kernel (small S), exactly mirroring the paper's
Case-1/2/3 selection with N = 128 lanes and x = the natural small-tensor
width.  All paths take an optional fused epilogue (dequant scale, bias,
ReLU/ReLU6).  ref.py holds the oracles, including the historical
block-diagonal Mode-2 kernel.  For the pack-once weight-stationary path
that skips the per-call padding/packing done here, see repro.engine.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import vdpe_gemm as k
from .common import pad_to as _pad_to, round_up as _round_up


def _is_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def default_interpret() -> bool:
    """interpret=True everywhere except on real TPU backends."""
    return not _is_tpu()


def pack_mode2_weights(dkvs: jax.Array, x: int, y: int) -> jax.Array:
    """Pack (F, s<=x) small DKVs into a (y*x, F) block-diagonal matrix.

    Column f carries kernel f's weights in lane-segment (f mod y); the
    Mode-2 kernel replicates the DIV tile across segments so each column's
    dot product sees exactly its own kernel.
    """
    f, s = dkvs.shape
    assert s <= x, (s, x)
    seg = jnp.arange(f, dtype=jnp.int32) % y            # (F,)
    row = jnp.arange(y * x, dtype=jnp.int32)            # (y*x,)
    # row r belongs to segment r // x at offset r % x
    row_seg = row // x
    row_off = row % x
    dkvs_padded = jnp.pad(dkvs, ((0, 0), (0, x - s)))   # (F, x)
    # out[r, f] = dkvs_padded[f, row_off[r]] if row_seg[r] == seg[f] else 0
    vals = dkvs_padded[:, row_off].T                    # (y*x, F)
    mask = row_seg[:, None] == seg[None, :]
    return jnp.where(mask, vals, jnp.zeros_like(vals))


def pack_mode2_segments(dkvs: jax.Array, x: int) -> jax.Array:
    """Pack (F, s<=x) small DKVs into the dense (x, F) segment-sum.

    The zero-skipping Mode-2 operand: because `pack_mode2_weights` assigns
    column f to lane-segment f mod y and segments are therefore
    column-disjoint, summing the y row-segments of the block-diagonal pack
    loses nothing — column f simply carries kernel f's weights at their
    natural offset.  1/y the footprint, and the kernel contracts x deep
    instead of y*x deep.
    """
    f, s = dkvs.shape
    assert s <= x, (s, x)
    return jnp.pad(dkvs, ((0, 0), (0, x - s))).T


@functools.partial(jax.jit, static_argnames=("interpret", "act"))
def mode1_gemm(divs_q: jax.Array, dkvs_q: jax.Array,
               interpret: bool = True,
               scale: jax.Array | None = None,
               bias: jax.Array | None = None,
               act: str = "none") -> jax.Array:
    """Mode-1 path: (P, S) x (F, S) -> (P, F), padded to MXU tiles.

    int32 without ``scale``; f32 with the fused `act(acc*scale+bias)`
    epilogue.
    """
    p, s = divs_q.shape
    f, _ = dkvs_q.shape
    pp, ss, ff = _round_up(p, k.BLOCK_B), _round_up(s, k.BLOCK_K), \
        _round_up(f, k.BLOCK_O)
    lhs = _pad_to(divs_q, pp, ss)
    rhs = _pad_to(dkvs_q.T, ss, ff)
    if bias is not None:
        bias = jnp.pad(bias.reshape(1, -1), ((0, 0), (0, ff - f)))
    out = k.vdpe_gemm(lhs, rhs, interpret=interpret,
                      scale=scale, bias=bias, act=act)
    return out[:p, :f]


@functools.partial(jax.jit, static_argnames=("x", "y", "interpret", "act"))
def mode2_gemm(divs_q: jax.Array, dkvs_q: jax.Array, x: int, y: int,
               interpret: bool = True,
               scale: jax.Array | None = None,
               bias: jax.Array | None = None,
               act: str = "none") -> jax.Array:
    """Mode-2 path: (P, s<=x) x (F, s) -> (P, F) via the zero-skipping kernel.

    ``y`` is the hardware lane count (comb-switch pairs); it sizes the
    perf model (ceil(F/y) passes per slice), not the computation — the
    segment-sum operand already collapses the y lane-segments.
    """
    del y  # hardware lane count; see docstring
    p, s = divs_q.shape
    f, _ = dkvs_q.shape
    pp, ff = _round_up(p, k.BLOCK_B), _round_up(f, k.BLOCK_O)
    lhs = _pad_to(divs_q, pp, x)
    rhs = _pad_to(pack_mode2_segments(dkvs_q, x), x, ff)
    if bias is not None:
        bias = jnp.pad(bias.reshape(1, -1), ((0, 0), (0, ff - f)))
    out = k.vdpe_pack_gemm_zs(lhs, rhs, interpret=interpret,
                              scale=scale, bias=bias, act=act)
    return out[:p, :f]


#: TPU "VDPE size": the MXU contraction-lane budget per pass.
N_TPU = 128
#: TPU re-aggregation width: small-tensor lane segment (the paper's x=9
#: generalizes to the most common small contraction; 32 aligns to the int8
#: sublane tile).
X_TPU = 32


def mixed_size_gemm(divs_q: jax.Array, dkvs_q: jax.Array,
                    interpret: bool | None = None,
                    scale: jax.Array | None = None,
                    bias: jax.Array | None = None,
                    act: str = "none") -> jax.Array:
    """Route a (P, S) x (F, S) quantized contraction per the paper's cases.

    S >= N_TPU           -> Mode 1 (K-blocked dense kernel)
    S <= X_TPU           -> Mode 2 (zero-skipping segment-sum kernel)
    X_TPU < S < N_TPU    -> Mode 1 with a single padded K block (the MXU has
                            no sub-128 pass, so Case 2 re-aggregation only
                            pays above the segment width)

    Optional fused epilogue (scale/bias/act) as in mode1_gemm/mode2_gemm.
    """
    if interpret is None:
        interpret = default_interpret()
    s = divs_q.shape[1]
    if s <= X_TPU:
        y = N_TPU // X_TPU
        return mode2_gemm(divs_q, dkvs_q, X_TPU, y, interpret=interpret,
                          scale=scale, bias=bias, act=act)
    return mode1_gemm(divs_q, dkvs_q, interpret=interpret,
                      scale=scale, bias=bias, act=act)


def grouped_matmul(tokens: jax.Array, weights: jax.Array,
                   group_ids: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """MoE ragged GEMM: out[t] = tokens[t] @ weights[group_ids[t]].

    Sorts tokens by expert, pads each group to the 128-token block size
    (the Mode-2 analogue: small expert batches share MXU passes instead of
    padding to the max group), runs the scalar-prefetch grouped kernel,
    and unsorts.
    """
    from . import moe_gemm
    if interpret is None:
        interpret = default_interpret()
    t, d = tokens.shape
    e = weights.shape[0]
    order = jnp.argsort(group_ids)
    sorted_ids = group_ids[order]
    sorted_tokens = tokens[order]
    bt = moe_gemm.BLOCK_T
    # scatter each sorted token into its group's padded region
    counts = jnp.bincount(group_ids, length=e)
    padded = ((counts + bt - 1) // bt) * bt
    starts = jnp.concatenate([jnp.zeros(1, padded.dtype),
                              jnp.cumsum(padded)[:-1]])
    # position within group = running index minus group's first index
    group_first = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                   jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(t) - group_first[sorted_ids]
    dest = starts[sorted_ids] + pos_in_group
    t_pad = int(e * bt + ((t + bt - 1) // bt) * bt)  # static upper bound
    buf = jnp.zeros((t_pad, d), tokens.dtype)
    buf = buf.at[dest].set(sorted_tokens)
    nb = t_pad // bt
    # block -> expert map (blocks beyond a group's padded range point at
    # expert 0; their rows are zero so the product is zero)
    block_starts = jnp.arange(nb) * bt
    block_expert = jnp.clip(
        jnp.searchsorted(jnp.cumsum(padded), block_starts, side="right"),
        0, e - 1).astype(jnp.int32)
    hp = _round_up(weights.shape[2], moe_gemm.BLOCK_H)
    w = jnp.pad(weights, ((0, 0), (0, 0), (0, hp - weights.shape[2])))
    out = moe_gemm.grouped_matmul_kernel(buf, w, block_expert,
                                         interpret=interpret)
    gathered = out[dest]                     # back to sorted order
    inv = jnp.argsort(order)
    return gathered[inv][:, :weights.shape[2]]


def gemm_bf16(lhs: jax.Array, rhs: jax.Array,
              interpret: bool | None = None,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """Padded bf16 GEMM through the Pallas dense kernel (+fused bias/act)."""
    if interpret is None:
        interpret = default_interpret()
    b, s = lhs.shape
    _, o = rhs.shape
    bb, ss, oo = _round_up(b, k.BLOCK_B), _round_up(s, k.BLOCK_K), \
        _round_up(o, k.BLOCK_O)
    if bias is not None:
        bias = jnp.pad(bias.reshape(1, -1), ((0, 0), (0, oo - o)))
    out = k.gemm_bf16(_pad_to(lhs, bb, ss), _pad_to(rhs, ss, oo),
                      interpret=interpret, bias=bias, act=act)
    return out[:b, :o]
