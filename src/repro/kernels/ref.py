"""Oracles for every Pallas kernel in this package.

Mostly pure-jnp references; additionally holds the *historical*
block-diagonal Mode-2 Pallas kernel (``vdpe_pack_gemm_blockdiag``), kept
verbatim as the oracle + benchmark baseline for the zero-skipping kernel
that replaced it in vdpe_gemm.py (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def vdpe_gemm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    """Mode-1 oracle: exact int32 GEMM."""
    return jax.lax.dot_general(
        lhs.astype(jnp.int32), rhs.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def vdpe_pack_gemm_ref(lhs: jax.Array, rhs_packed: jax.Array,
                       y: int) -> jax.Array:
    """Mode-2 oracle: replicate the DIV tile then dense int32 GEMM."""
    a_rep = jnp.concatenate([lhs] * y, axis=1)
    return vdpe_gemm_ref(a_rep, rhs_packed)


def _pack_gemm_blockdiag_kernel(lhs_ref, rhs_ref, out_ref, *, y: int):
    """Pre-zero-skipping Mode-2 body: replicate the DIV tile y times and
    contract (y*x)-deep against the mostly-zero block-diagonal operand."""
    a = lhs_ref[...]                           # (bb, x)
    a_rep = jnp.concatenate([a] * y, axis=1)   # (bb, y*x) in VMEM/VREGs
    out_ref[...] = jax.lax.dot_general(
        a_rep, rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("y", "block_b", "block_o",
                                             "interpret"))
def vdpe_pack_gemm_blockdiag(lhs: jax.Array, rhs_packed: jax.Array, y: int,
                             block_b: int = 128, block_o: int = 128,
                             interpret: bool = True) -> jax.Array:
    """The original Mode-2 Pallas kernel: (B, x) x (y*x, O) packed -> (B, O).

    ``rhs_packed`` is block-diagonal (ops.pack_mode2_weights): column f is
    non-zero only inside lane-segment f mod y, so (y-1)/y of the operand —
    and of the MXU contraction depth — is zeros.  Kept as the oracle and
    benchmark baseline for vdpe_gemm.vdpe_pack_gemm_zs.
    """
    b, x = lhs.shape
    k, o = rhs_packed.shape
    assert k == y * x, (k, y, x)
    assert b % block_b == 0 and o % block_o == 0
    grid = (b // block_b, o // block_o)
    return pl.pallas_call(
        functools.partial(_pack_gemm_blockdiag_kernel, y=y),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, x), lambda i, j: (i, 0)),
            pl.BlockSpec((y * x, block_o), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
        interpret=interpret,
    )(lhs, rhs_packed)


def pack_mode2_segments_ref(dkvs: jax.Array, x: int, y: int) -> jax.Array:
    """Oracle for ops.pack_mode2_segments: the dense segment-sum (x, F).

    Derived independently of the implementation: build the block-diagonal
    pack (pack_block_diagonal_ref) and sum its y row-segments — lossless
    because segments are column-disjoint.
    """
    f, _ = dkvs.shape
    bd = pack_block_diagonal_ref(dkvs, x, y).astype(jnp.int32)
    return bd.reshape(y, x, f).sum(axis=0).astype(dkvs.dtype)


def epilogue_ref(acc: jax.Array, scale, bias, act: str) -> jax.Array:
    """Oracle for the fused GEMM epilogue: act(acc * scale + bias)."""
    r = acc.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
    if bias is not None:
        r = r + bias
    if act == "relu":
        r = jnp.maximum(r, 0.0)
    elif act == "relu6":
        r = jnp.clip(r, 0.0, 6.0)
    else:
        assert act == "none", act
    return r


def gemm_bf16_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        lhs, rhs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def pack_block_diagonal_ref(dkvs: jax.Array, x: int, y: int) -> jax.Array:
    """Oracle for ops.pack_mode2_weights: (F, s<=x) -> (y*x, F) packed.

    Column f carries kernel f's weights in segment (f mod y).
    """
    f, s = dkvs.shape
    assert s <= x
    out = jnp.zeros((y * x, f), dkvs.dtype)
    for i in range(f):
        seg = i % y
        out = out.at[seg * x:seg * x + s, i].set(dkvs[i])
    return out


def grouped_matmul_ref(tokens: jax.Array, weights: jax.Array,
                       group_ids: jax.Array) -> jax.Array:
    """Oracle for the MoE grouped GEMM: per-token expert matmul.

    tokens: (T, D); weights: (E, D, H); group_ids: (T,) in [0, E).
    Returns (T, H) with out[t] = tokens[t] @ weights[group_ids[t]].
    """
    gathered = weights[group_ids]            # (T, D, H)
    return jnp.einsum("td,tdh->th", tokens, gathered)


def flash_attention_ref(q, w_k, v, causal: bool = True):
    """Oracle for the fused attention kernel: naive softmax attention.

    q: (BH, S, hd); w_k/v: (BH, T, hd) -> (BH, S, hd).
    """
    import math
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   w_k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        sq, t = q.shape[1], w_k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(t)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p,
                      v.astype(jnp.float32)).astype(q.dtype)
