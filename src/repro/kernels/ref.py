"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def vdpe_gemm_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    """Mode-1 oracle: exact int32 GEMM."""
    return jax.lax.dot_general(
        lhs.astype(jnp.int32), rhs.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


def vdpe_pack_gemm_ref(lhs: jax.Array, rhs_packed: jax.Array,
                       y: int) -> jax.Array:
    """Mode-2 oracle: replicate the DIV tile then dense int32 GEMM."""
    a_rep = jnp.concatenate([lhs] * y, axis=1)
    return vdpe_gemm_ref(a_rep, rhs_packed)


def gemm_bf16_ref(lhs: jax.Array, rhs: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        lhs, rhs, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def pack_block_diagonal_ref(dkvs: jax.Array, x: int, y: int) -> jax.Array:
    """Oracle for ops.pack_mode2_weights: (F, s<=x) -> (y*x, F) packed.

    Column f carries kernel f's weights in segment (f mod y).
    """
    f, s = dkvs.shape
    assert s <= x
    out = jnp.zeros((y * x, f), dkvs.dtype)
    for i in range(f):
        seg = i % y
        out = out.at[seg * x:seg * x + s, i].set(dkvs[i])
    return out


def grouped_matmul_ref(tokens: jax.Array, weights: jax.Array,
                       group_ids: jax.Array) -> jax.Array:
    """Oracle for the MoE grouped GEMM: per-token expert matmul.

    tokens: (T, D); weights: (E, D, H); group_ids: (T,) in [0, E).
    Returns (T, H) with out[t] = tokens[t] @ weights[group_ids[t]].
    """
    gathered = weights[group_ids]            # (T, D, H)
    return jnp.einsum("td,tdh->th", tokens, gathered)


def flash_attention_ref(q, w_k, v, causal: bool = True):
    """Oracle for the fused attention kernel: naive softmax attention.

    q: (BH, S, hd); w_k/v: (BH, T, hd) -> (BH, S, hd).
    """
    import math
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   w_k.astype(jnp.float32)) / math.sqrt(q.shape[-1])
    if causal:
        sq, t = q.shape[1], w_k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(t)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p,
                      v.astype(jnp.float32)).astype(q.dtype)
