"""Pallas TPU kernels for Mode-1 / Mode-2 VDPE GEMMs.

Hardware adaptation (EXPERIMENTS.md §Perf): the photonic VDPE's fixed N
optical lanes map onto the MXU's fixed 128-wide contraction lanes.  A small
contraction (S << 128) wastes MXU lanes exactly the way S < N strands MRRs
in the paper; Mode-2 re-aggregation maps onto *segment packing*: y small
DKVs occupy disjoint row-segments of one 128-deep K block, and one MXU pass
produces y independent dot products.

Kernels:

* ``vdpe_gemm_q8`` — Mode 1, quantized-domain serving path: the f32 DIV
  stream enters the kernel raw and is quantized onto the int8 lattice *in
  the prologue* (per-row DAC scales — the batched engine's per-image
  swings), contracted int8 x int8 -> int32 against the plan's resident
  int8 operand, and dequantized by the fused epilogue.  The K axis is
  streamed *inside* the kernel with explicit double buffering: lhs/rhs
  live in HBM (``memory_space=ANY``) and the kernel prefetches K-block
  ``kk+1`` into the alternate VMEM slot while the MXU contracts block
  ``kk`` — one grid step per output tile instead of n_k, and the int32
  accumulator lives its whole life in registers/VMEM.

* ``vdpe_pack_gemm_zs_q8`` — Mode 2, quantized-domain + zero-skipping:
  same fused quantize prologue and epilogue, segment-sum rhs resident in
  VMEM, and the *position stream* (the B axis — Mode 2's stream-bound
  side, since its contraction is a single x-deep pass) double-buffered:
  DIV block ``n+1`` is prefetched from HBM while block ``n`` rides the
  MXU.

* ``vdpe_gemm`` — Mode 1: K-blocked dense int8 x int8 -> int32 GEMM over
  *pre-quantized* operands (the S >= N slice path).  Also accepts f32
  operands on the quantized lattice (f32 accumulation is exact for int8
  products, so it doubles as the quantize-then-float oracle's GEMM).

* ``vdpe_pack_gemm_zs`` — Mode 2, zero-skipping: because Mode-2 lane
  segments are *column-disjoint* (kernel f lives only in segment f mod y),
  the block-diagonal (y*x, O) operand collapses losslessly to its dense
  segment-sum (x, O).  The kernel therefore issues a single x-deep
  contraction per output tile instead of a (y*x)-deep one against an
  operand that is (y-1)/y zeros — cutting both the y-fold zero-FLOPs and
  the y× RHS VMEM/HBM footprint.  Accepts lattice-f32 operands like
  ``vdpe_gemm``.  The historical block-diagonal kernel lives in
  kernels/ref.py (``vdpe_pack_gemm_blockdiag``) as the oracle.

* ``gemm_bf16`` — bf16 GEMM with f32 accumulation (dense tile path).

All take an optional fused epilogue (dequant scale, bias add, ReLU/ReLU6)
so integer accumulators never round-trip HBM between the GEMM and the
activation: a scalar ``scale`` rides in SMEM, ``bias`` is blocked over O,
and the activation is a compile-time branch.  The pre-quantized GEMMs also
accept a *per-row* scale (shape (B,) or (B, 1)): the batched engine folds
many images' DIV streams into one GEMM, and each image keeps its own
activation-DAC quantization scale, so the dequant scale varies along B.
Per-row scales ride as a (block_b, 1) VMEM column blocked over the B grid
axis and broadcast across the O lanes.

Blocked operands use explicit BlockSpec VMEM tiling with MXU-aligned block
shapes (multiples of (32, 128) for int8 operands, (8, 128) for f32); the
q8 kernels' streamed operands stay in HBM and ride explicit
``pltpu.make_async_copy`` DMAs into double-buffered VMEM scratch.
Validated against kernels/ref.py in interpret mode (tests/test_kernels.py,
tests/test_engine.py, tests/test_quantized.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (ACTIVATIONS, apply_act as _apply_act,  # noqa: F401
                     dequant_epilogue as _dequant_epilogue, quantize_tile)

# MXU-aligned default tile sizes (int8 operands tile as (32, 128) in VMEM).
BLOCK_B = 128
BLOCK_O = 128
BLOCK_K = 128


def _acc_dtype(operand_dtype) -> jnp.dtype:
    """int32 accumulation for int8 operands; exact f32 for the lattice-f32
    oracle operands (int8 products summed in f32 stay < 2^24: exact)."""
    return (jnp.int32 if jnp.issubdtype(operand_dtype, jnp.integer)
            else jnp.float32)


def _dot(lhs, rhs, acc_dtype):
    return jax.lax.dot_general(lhs, rhs, (((1,), (0,)), ((), ())),
                               preferred_element_type=acc_dtype)


# ---------------------------------------------------------------------------
# Mode 1: K-blocked dense GEMM over pre-quantized operands
# ---------------------------------------------------------------------------

def _gemm_kernel(lhs_ref, rhs_ref, out_ref):
    """Mode-1 kernel body: K-accumulating GEMM tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _dot(lhs_ref[...], rhs_ref[...], out_ref.dtype)


def _gemm_epilogue_kernel(scale_ref, lhs_ref, rhs_ref, bias_ref, out_ref,
                          acc_ref, *, n_k: int, act: str):
    """Mode-1 fused kernel: accumulator scratch, f32 epilogue at last K.

    The partial sums live only in the ``acc_ref`` scratch; the HBM output
    is the already-dequantized, biased, activated f32 tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _dot(lhs_ref[...], rhs_ref[...], acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = _dequant_epilogue(acc_ref[...], scale_ref[0, 0],
                                         bias_ref[...], act)


def _gemm_epilogue_rows_kernel(lhs_ref, rhs_ref, scale_ref, bias_ref,
                               out_ref, acc_ref, *, n_k: int, act: str):
    """Mode-1 fused kernel with a per-row dequant scale column in VMEM.

    The (block_b, 1) scale block is a narrow f32 block (lane dim < 128),
    the row-wise twin of the (1, block_o) bias block every epilogue here
    already uses; Mosaic pads narrow blocks to the native tile.  Validated
    in interpret mode (CI is CPU-only) — first real-TPU run of the batched
    path should confirm the lowering like any other kernel change.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _dot(lhs_ref[...], rhs_ref[...], acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = _dequant_epilogue(acc_ref[...], scale_ref[...],
                                         bias_ref[...], act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret", "act"))
def vdpe_gemm(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True,
              scale: jax.Array | None = None,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """Mode-1 VDPE GEMM: (B, K) x (K, O) pre-quantized -> (B, O).

    B, K, O must be multiples of the block sizes (ops.py / engine pad).
    int8 operands accumulate in int32; lattice-f32 operands (the float
    oracle path) accumulate exactly in f32.  Without ``scale`` the result
    is the raw accumulator; with it the epilogue ``act(acc * scale +
    bias)`` is fused and the result is f32.  ``scale`` may be a scalar
    (one dequant scale for the whole stream) or a (B,) / (B, 1) per-row
    vector (the batched engine's per-image scales).
    """
    b, k = lhs.shape
    k2, o = rhs.shape
    assert k == k2 and b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (b // block_b, o // block_o, n_k)
    acc_dtype = _acc_dtype(lhs.dtype)
    lhs_spec = pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk))
    rhs_spec = pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j))
    out_spec = pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j))
    if scale is None:
        assert bias is None and act == "none", "epilogue requires a scale"
        return pl.pallas_call(
            _gemm_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), acc_dtype),
            interpret=interpret,
        )(lhs, rhs)
    scale = jnp.asarray(scale, jnp.float32)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    if scale.size != 1:
        if scale.size != b:
            raise ValueError(
                f"per-row scale must have one entry per lhs row "
                f"({b}, block-padded), got shape {scale.shape}")
        return pl.pallas_call(
            functools.partial(_gemm_epilogue_rows_kernel, n_k=n_k, act=act),
            grid=grid,
            in_specs=[
                lhs_spec, rhs_spec,
                pl.BlockSpec((block_b, 1), lambda i, j, kk: (i, 0)),
                pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            scratch_shapes=[pltpu.VMEM((block_b, block_o), acc_dtype)],
            interpret=interpret,
        )(lhs, rhs, scale.reshape(b, 1), bias)
    scale = scale.reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_gemm_epilogue_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0),
                         memory_space=pltpu.SMEM),
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_o), acc_dtype)],
        interpret=interpret,
    )(scale, lhs, rhs, bias)


# ---------------------------------------------------------------------------
# Mode 1, quantized-domain: fused quantize prologue + K-pipelined stream
# ---------------------------------------------------------------------------

def _gemm_q8_kernel(w_scale_ref, lhs_hbm, rhs_hbm, a_scale_ref, bias_ref,
                    out_ref, lhs_buf, rhs_buf, sems, *, n_k: int,
                    block_b: int, block_o: int, block_k: int, bits: int,
                    act: str):
    """Quantized-domain Mode-1 body: in-kernel quantize, K double-buffered.

    lhs/rhs stay in HBM (``ANY``); K-block ``kk+1`` is DMA'd into the
    alternate VMEM slot while block ``kk`` is quantized and contracted.
    The K loop is unrolled at trace time (n_k is static), so the int32
    accumulator never leaves registers/VMEM and the epilogue runs once.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    def copies(slot: int, kk: int):
        return (
            pltpu.make_async_copy(
                lhs_hbm.at[pl.ds(i * block_b, block_b),
                           pl.ds(kk * block_k, block_k)],
                lhs_buf.at[slot], sems.at[slot, 0]),
            pltpu.make_async_copy(
                rhs_hbm.at[pl.ds(kk * block_k, block_k),
                           pl.ds(j * block_o, block_o)],
                rhs_buf.at[slot], sems.at[slot, 1]),
        )

    for c in copies(0, 0):
        c.start()
    a_col = a_scale_ref[...]                       # (block_b, 1) f32
    acc = jnp.zeros((block_b, block_o), jnp.int32)
    for kk in range(n_k):
        slot = kk % 2
        if kk + 1 < n_k:                           # prefetch next K block
            for c in copies((kk + 1) % 2, kk + 1):
                c.start()
        for c in copies(slot, kk):
            c.wait()
        lhs_q = quantize_tile(lhs_buf[slot], a_col, bits)
        acc += _dot(lhs_q, rhs_buf[slot], jnp.int32)
    # per-row dequant scale: image scale x the plan's weight scale, the
    # same association the oracle paths compute outside the kernel
    out_ref[...] = _dequant_epilogue(acc, a_col * w_scale_ref[0, 0],
                                     bias_ref[...], act)


@functools.partial(jax.jit, static_argnames=("bits", "block_b", "block_o",
                                             "block_k", "interpret", "act"))
def vdpe_gemm_q8(lhs: jax.Array, rhs: jax.Array, a_scale: jax.Array,
                 w_scale: jax.Array, bits: int = 4,
                 block_b: int = BLOCK_B, block_o: int = BLOCK_O,
                 block_k: int = BLOCK_K, interpret: bool = True,
                 bias: jax.Array | None = None,
                 act: str = "none") -> jax.Array:
    """Quantized-domain Mode-1 GEMM: (B, K) f32 x (K, O) int8 -> (B, O) f32.

    ``lhs`` is the *raw* f32 DIV stream; the kernel prologue quantizes it
    onto the int8 lattice with the per-row DAC scales ``a_scale`` ((B,) or
    (B, 1); pad rows use scale 1).  ``rhs`` is the plan's resident int8
    operand, ``w_scale`` its scalar dequant scale.  The fused epilogue is
    ``act(acc * (a_scale * w_scale) + bias)`` — bitwise-identical to
    quantizing outside and calling ``vdpe_gemm`` with per-row scales,
    while the int8 stream never round-trips HBM and the K axis streams
    through explicitly double-buffered VMEM slots.
    """
    b, k = lhs.shape
    k2, o = rhs.shape
    assert k == k2 and b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    assert rhs.dtype == jnp.int8, rhs.dtype
    n_k = k // block_k
    a_scale = jnp.asarray(a_scale, jnp.float32)
    if a_scale.size != b:
        raise ValueError(
            f"per-row a_scale must have one entry per lhs row "
            f"({b}, block-padded), got shape {a_scale.shape}")
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    return pl.pallas_call(
        functools.partial(_gemm_q8_kernel, n_k=n_k, block_b=block_b,
                          block_o=block_o, block_k=block_k, bits=bits,
                          act=act),
        grid=(b // block_b, o // block_o),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, block_b, block_k), jnp.float32),
            pltpu.VMEM((2, block_k, block_o), jnp.int8),
            pltpu.SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(jnp.asarray(w_scale, jnp.float32).reshape(1, 1), lhs, rhs,
      a_scale.reshape(b, 1), bias)


# ---------------------------------------------------------------------------
# Mode 2: zero-skipping segment-sum GEMM
# ---------------------------------------------------------------------------

def zs_block_shapes(x: int, block_b: int = BLOCK_B,
                    block_o: int = BLOCK_O) -> tuple:
    """(lhs, rhs, out) block shapes of the zero-skipping Mode-2 kernel.

    Single source of truth for the kernel's BlockSpecs — the engine tests
    assert the rhs block (and therefore the contraction issued per output
    tile) is x deep, not y*x deep.
    """
    return (block_b, x), (x, block_o), (block_b, block_o)


def _pack_gemm_zs_kernel(lhs_ref, rhs_ref, out_ref):
    """Zero-skipping Mode-2 body: one x-deep dot per output tile."""
    out_ref[...] = _dot(lhs_ref[...], rhs_ref[...], out_ref.dtype)


def _pack_gemm_zs_epilogue_kernel(scale_ref, lhs_ref, rhs_ref, bias_ref,
                                  out_ref, *, act: str):
    acc = _dot(lhs_ref[...], rhs_ref[...], _acc_dtype(lhs_ref.dtype))
    out_ref[...] = _dequant_epilogue(acc, scale_ref[0, 0], bias_ref[...],
                                     act)


def _pack_gemm_zs_epilogue_rows_kernel(lhs_ref, rhs_ref, scale_ref, bias_ref,
                                       out_ref, *, act: str):
    """Zero-skipping Mode-2 body with a per-row dequant scale column."""
    acc = _dot(lhs_ref[...], rhs_ref[...], _acc_dtype(lhs_ref.dtype))
    out_ref[...] = _dequant_epilogue(acc, scale_ref[...], bias_ref[...],
                                     act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o",
                                             "interpret", "act"))
def vdpe_pack_gemm_zs(lhs: jax.Array, rhs_seg: jax.Array,
                      block_b: int = BLOCK_B, block_o: int = BLOCK_O,
                      interpret: bool = True,
                      scale: jax.Array | None = None,
                      bias: jax.Array | None = None,
                      act: str = "none") -> jax.Array:
    """Zero-skipping Mode-2 GEMM: (B, x) x (x, O) pre-quantized -> (B, O).

    ``rhs_seg`` is the dense *segment-sum* of the block-diagonal packed
    operand (ops.pack_mode2_segments): column f holds kernel f's weights at
    their natural offset.  Because lane segments are column-disjoint the
    result is bit-identical to the (y*x)-deep block-diagonal oracle
    (ref.vdpe_pack_gemm_blockdiag) while issuing only an x-deep contraction
    and reading/holding 1/y of the RHS bytes.  Lattice-f32 operands (the
    float oracle path) accumulate exactly in f32.

    ``scale`` follows the vdpe_gemm convention: scalar, or per-row (B,) /
    (B, 1) for the batched engine's folded multi-image streams.
    """
    b, x = lhs.shape
    x2, o = rhs_seg.shape
    assert x == x2, (x, x2)  # structurally cannot issue a (y*x)-deep pass
    assert b % block_b == 0 and o % block_o == 0
    grid = (b // block_b, o // block_o)
    acc_dtype = _acc_dtype(lhs.dtype)
    lhs_shape, rhs_shape, out_shape = zs_block_shapes(x, block_b, block_o)
    lhs_spec = pl.BlockSpec(lhs_shape, lambda i, j: (i, 0))
    rhs_spec = pl.BlockSpec(rhs_shape, lambda i, j: (0, j))
    out_spec = pl.BlockSpec(out_shape, lambda i, j: (i, j))
    if scale is None:
        assert bias is None and act == "none", "epilogue requires a scale"
        return pl.pallas_call(
            _pack_gemm_zs_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), acc_dtype),
            interpret=interpret,
        )(lhs, rhs_seg)
    scale = jnp.asarray(scale, jnp.float32)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    if scale.size != 1:
        if scale.size != b:
            raise ValueError(
                f"per-row scale must have one entry per lhs row "
                f"({b}, block-padded), got shape {scale.shape}")
        return pl.pallas_call(
            functools.partial(_pack_gemm_zs_epilogue_rows_kernel, act=act),
            grid=grid,
            in_specs=[
                lhs_spec, rhs_spec,
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            interpret=interpret,
        )(lhs, rhs_seg, scale.reshape(b, 1), bias)
    scale = scale.reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_pack_gemm_zs_epilogue_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(scale, lhs, rhs_seg, bias)


# ---------------------------------------------------------------------------
# Mode 2, quantized-domain: fused quantize + double-buffered DIV stream
# ---------------------------------------------------------------------------

def _pack_gemm_zs_q8_kernel(w_scale_ref, lhs_hbm, rhs_ref, a_scale_ref,
                            bias_ref, out_ref, lhs_buf, sems, *, n_b: int,
                            block_b: int, bits: int, act: str):
    """Quantized-domain zero-skipping body: B-stream double-buffered.

    The (x, block_o) segment-sum rhs stays resident in VMEM; DIV block
    ``n+1`` is DMA'd from HBM into the alternate slot while block ``n``
    is quantized and contracted (Mode 2's single x-deep pass makes the
    position stream, not the contraction, the bound resource).
    """
    def copy(slot: int, n: int):
        return pltpu.make_async_copy(
            lhs_hbm.at[pl.ds(n * block_b, block_b), :],
            lhs_buf.at[slot], sems.at[slot])

    copy(0, 0).start()
    rhs = rhs_ref[...]
    w_scale = w_scale_ref[0, 0]
    for n in range(n_b):
        slot = n % 2
        if n + 1 < n_b:                            # prefetch next DIV block
            copy((n + 1) % 2, n + 1).start()
        copy(slot, n).wait()
        a_col = a_scale_ref[pl.ds(n * block_b, block_b), :]
        lhs_q = quantize_tile(lhs_buf[slot], a_col, bits)
        acc = _dot(lhs_q, rhs, jnp.int32)
        out_ref[pl.ds(n * block_b, block_b), :] = _dequant_epilogue(
            acc, a_col * w_scale, bias_ref[...], act)


@functools.partial(jax.jit, static_argnames=("bits", "block_b", "block_o",
                                             "interpret", "act"))
def vdpe_pack_gemm_zs_q8(lhs: jax.Array, rhs_seg: jax.Array,
                         a_scale: jax.Array, w_scale: jax.Array,
                         bits: int = 4, block_b: int = BLOCK_B,
                         block_o: int = BLOCK_O, interpret: bool = True,
                         bias: jax.Array | None = None,
                         act: str = "none") -> jax.Array:
    """Quantized-domain Mode-2 GEMM: (B, x) f32 x (x, O) int8 -> (B, O) f32.

    ``lhs`` is the raw f32 DIV stream (quantized in the kernel prologue
    with per-row DAC scales ``a_scale``; pad rows use scale 1); ``rhs_seg``
    the dense int8 segment-sum pack with scalar dequant scale ``w_scale``.
    Bitwise-identical to quantizing outside and calling
    ``vdpe_pack_gemm_zs`` with per-row scales.
    """
    b, x = lhs.shape
    x2, o = rhs_seg.shape
    assert x == x2, (x, x2)
    assert b % block_b == 0 and o % block_o == 0
    assert rhs_seg.dtype == jnp.int8, rhs_seg.dtype
    a_scale = jnp.asarray(a_scale, jnp.float32)
    if a_scale.size != b:
        raise ValueError(
            f"per-row a_scale must have one entry per lhs row "
            f"({b}, block-padded), got shape {a_scale.shape}")
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    return pl.pallas_call(
        functools.partial(_pack_gemm_zs_q8_kernel, n_b=b // block_b,
                          block_b=block_b, bits=bits, act=act),
        grid=(o // block_o,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((x, block_o), lambda j: (0, j)),
            pl.BlockSpec((b, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, block_o), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((b, block_o), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, block_b, x), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(jnp.asarray(w_scale, jnp.float32).reshape(1, 1), lhs, rhs_seg,
      a_scale.reshape(b, 1), bias)


# ---------------------------------------------------------------------------
# Dense bf16 tile path
# ---------------------------------------------------------------------------

def _gemm_bf16_kernel(lhs_ref, rhs_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _dot(lhs_ref[...], rhs_ref[...], jnp.float32)


def _gemm_bf16_epilogue_kernel(lhs_ref, rhs_ref, bias_ref, out_ref, acc_ref,
                               *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _dot(lhs_ref[...], rhs_ref[...], jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = _apply_act(acc_ref[...] + bias_ref[...], act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret", "act"))
def gemm_bf16(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """bf16 GEMM with f32 accumulation — the framework's dense tile path.

    With ``bias``/``act`` the epilogue fuses into the last K step (no
    dequant scale: the operands are already real-valued).
    """
    b, k = lhs.shape
    _, o = rhs.shape
    assert b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (b // block_b, o // block_o, n_k)
    lhs_spec = pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk))
    rhs_spec = pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j))
    out_spec = pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j))
    if bias is None and act == "none":
        return pl.pallas_call(
            _gemm_bf16_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            interpret=interpret,
        )(lhs, rhs)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    return pl.pallas_call(
        functools.partial(_gemm_bf16_epilogue_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_o), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs, bias)
