"""Pallas TPU kernels for Mode-1 / Mode-2 VDPE GEMMs.

Hardware adaptation (DESIGN.md §2): the photonic VDPE's fixed N optical
lanes map onto the MXU's fixed 128-wide contraction lanes.  A small
contraction (S << 128) wastes MXU lanes exactly the way S < N strands MRRs
in the paper; Mode-2 re-aggregation maps onto *block-diagonal packing*: y
small DKVs occupy disjoint row-segments of one 128-deep K block, and one
MXU pass produces y independent dot products.

Two kernels:

* ``vdpe_gemm_kernel`` — Mode 1: K-blocked dense int8 x int8 -> int32 GEMM
  (the S >= N slice path).  lhs (B, K), rhs (K, O), out (B, O); the K grid
  axis is innermost and accumulates into the VMEM out block.

* ``vdpe_pack_gemm_kernel`` — Mode 2: the DIV tile is loaded ONCE at its
  natural width x and re-aggregated (replicated) across the y lane-segments
  *inside VMEM*, mirroring the comb switches re-aggregating wavelengths
  instead of regenerating signals.  HBM traffic for the input drops y-fold
  versus materializing the replicated operand.

Both kernels use explicit BlockSpec VMEM tiling with MXU-aligned block
shapes (multiples of (32, 128) for int8 operands, (8, 128) for f32).
Validated against kernels/ref.py in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# MXU-aligned default tile sizes (int8 operands tile as (32, 128) in VMEM).
BLOCK_B = 128
BLOCK_O = 128
BLOCK_K = 128


def _gemm_kernel(lhs_ref, rhs_ref, out_ref, *, n_k: int):
    """Mode-1 kernel body: K-accumulating int8 GEMM tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = lhs_ref[...]
    b = rhs_ref[...]
    out_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret"))
def vdpe_gemm(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True) -> jax.Array:
    """Mode-1 VDPE GEMM: (B, K) int8 x (K, O) int8 -> (B, O) int32.

    B, K, O must be multiples of the block sizes (ops.py pads).
    """
    b, k = lhs.shape
    k2, o = rhs.shape
    assert k == k2 and b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (b // block_b, o // block_o, n_k)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
        interpret=interpret,
    )(lhs, rhs)


def _pack_gemm_kernel(lhs_ref, rhs_ref, out_ref, *, y: int):
    """Mode-2 kernel body: re-aggregate the DIV tile across y lane-segments.

    lhs block: (block_b, x) — the small DIV tile, loaded once.
    rhs block: (y * x, block_o) — block-diagonal packed DKVs.
    out block: (block_b, block_o).
    """
    a = lhs_ref[...]                       # (bb, x)
    # comb-switch re-aggregation: replicate the x-wide tile onto y segments
    a_rep = jnp.concatenate([a] * y, axis=1)   # (bb, y*x) in VMEM/VREGs
    b = rhs_ref[...]
    out_ref[...] = jax.lax.dot_general(
        a_rep, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("y", "block_b", "block_o",
                                             "interpret"))
def vdpe_pack_gemm(lhs: jax.Array, rhs_packed: jax.Array, y: int,
                   block_b: int = BLOCK_B, block_o: int = BLOCK_O,
                   interpret: bool = True) -> jax.Array:
    """Mode-2 VDPE GEMM: (B, x) int8 x (y*x, O) packed int8 -> (B, O) int32.

    ``rhs_packed`` holds y independent DKV segments along its K dimension
    (column f non-zero only inside its segment); the kernel replicates the
    (B, x) DIV tile y times inside VMEM, so HBM reads of the input are y
    times smaller than the equivalent dense GEMM.
    """
    b, x = lhs.shape
    k, o = rhs_packed.shape
    assert k == y * x, (k, y, x)
    assert b % block_b == 0 and o % block_o == 0
    grid = (b // block_b, o // block_o)
    return pl.pallas_call(
        functools.partial(_pack_gemm_kernel, y=y),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, x), lambda i, j: (i, 0)),
            pl.BlockSpec((y * x, block_o), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
        interpret=interpret,
    )(lhs, rhs_packed)


def _gemm_bf16_kernel(lhs_ref, rhs_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret"))
def gemm_bf16(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True) -> jax.Array:
    """bf16 GEMM with f32 accumulation — the framework's dense tile path."""
    b, k = lhs.shape
    _, o = rhs.shape
    assert b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    grid = (b // block_b, o // block_o, k // block_k)
    return pl.pallas_call(
        _gemm_bf16_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(lhs, rhs)
