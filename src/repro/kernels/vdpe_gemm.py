"""Pallas TPU kernels for Mode-1 / Mode-2 VDPE GEMMs.

Hardware adaptation (EXPERIMENTS.md §Perf): the photonic VDPE's fixed N
optical lanes map onto the MXU's fixed 128-wide contraction lanes.  A small
contraction (S << 128) wastes MXU lanes exactly the way S < N strands MRRs
in the paper; Mode-2 re-aggregation maps onto *segment packing*: y small
DKVs occupy disjoint row-segments of one 128-deep K block, and one MXU pass
produces y independent dot products.

Kernels:

* ``vdpe_gemm`` — Mode 1: K-blocked dense int8 x int8 -> int32 GEMM
  (the S >= N slice path).  lhs (B, K), rhs (K, O), out (B, O); the K grid
  axis is innermost and accumulates into the VMEM out block.

* ``vdpe_pack_gemm_zs`` — Mode 2, zero-skipping: because Mode-2 lane
  segments are *column-disjoint* (kernel f lives only in segment f mod y),
  the block-diagonal (y*x, O) operand collapses losslessly to its dense
  segment-sum (x, O).  The kernel therefore issues a single x-deep
  contraction per output tile instead of a (y*x)-deep one against an
  operand that is (y-1)/y zeros — cutting both the y-fold zero-FLOPs and
  the y× RHS VMEM/HBM footprint.  The historical block-diagonal kernel
  lives in kernels/ref.py (``vdpe_pack_gemm_blockdiag``) as the oracle.

* ``gemm_bf16`` — bf16 GEMM with f32 accumulation (dense tile path).

All three take an optional fused epilogue (dequant scale, bias add,
ReLU/ReLU6) so integer accumulators never round-trip HBM between the GEMM
and the activation: a scalar ``scale`` rides in SMEM, ``bias`` is blocked
over O, and the activation is a compile-time branch.  The int8 GEMMs also
accept a *per-row* scale (shape (B,) or (B, 1)): the batched engine folds
many images' DIV streams into one GEMM, and each image keeps its own
activation-DAC quantization scale, so the dequant scale varies along B.
Per-row scales ride as a (block_b, 1) VMEM column blocked over the B grid
axis and broadcast across the O lanes.

Both kernels use explicit BlockSpec VMEM tiling with MXU-aligned block
shapes (multiples of (32, 128) for int8 operands, (8, 128) for f32).
Validated against kernels/ref.py in interpret mode (tests/test_kernels.py,
tests/test_engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import ACTIVATIONS, apply_act as _apply_act  # noqa: F401

# MXU-aligned default tile sizes (int8 operands tile as (32, 128) in VMEM).
BLOCK_B = 128
BLOCK_O = 128
BLOCK_K = 128


# ---------------------------------------------------------------------------
# Mode 1: K-blocked dense int8 GEMM
# ---------------------------------------------------------------------------

def _gemm_kernel(lhs_ref, rhs_ref, out_ref):
    """Mode-1 kernel body: K-accumulating int8 GEMM tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _gemm_epilogue_kernel(scale_ref, lhs_ref, rhs_ref, bias_ref, out_ref,
                          acc_ref, *, n_k: int, act: str):
    """Mode-1 fused kernel: int32 VMEM accumulator, f32 epilogue at last K.

    The int32 partial sums live only in the ``acc_ref`` scratch; the HBM
    output is the already-dequantized, biased, activated f32 tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        r = acc_ref[...].astype(jnp.float32) * scale_ref[0, 0] + bias_ref[...]
        out_ref[...] = _apply_act(r, act)


def _gemm_epilogue_rows_kernel(lhs_ref, rhs_ref, scale_ref, bias_ref,
                               out_ref, acc_ref, *, n_k: int, act: str):
    """Mode-1 fused kernel with a per-row dequant scale column in VMEM.

    The (block_b, 1) scale block is a narrow f32 block (lane dim < 128),
    the row-wise twin of the (1, block_o) bias block every epilogue here
    already uses; Mosaic pads narrow blocks to the native tile.  Validated
    in interpret mode (CI is CPU-only) — first real-TPU run of the batched
    path should confirm the lowering like any other kernel change.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        r = acc_ref[...].astype(jnp.float32) * scale_ref[...] + bias_ref[...]
        out_ref[...] = _apply_act(r, act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret", "act"))
def vdpe_gemm(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True,
              scale: jax.Array | None = None,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """Mode-1 VDPE GEMM: (B, K) int8 x (K, O) int8 -> (B, O).

    B, K, O must be multiples of the block sizes (ops.py / engine pad).
    Without ``scale`` the result is the raw int32 accumulator; with it the
    epilogue ``act(acc * scale + bias)`` is fused and the result is f32.
    ``scale`` may be a scalar (one dequant scale for the whole stream) or a
    (B,) / (B, 1) per-row vector (the batched engine's per-image scales).
    """
    b, k = lhs.shape
    k2, o = rhs.shape
    assert k == k2 and b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (b // block_b, o // block_o, n_k)
    lhs_spec = pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk))
    rhs_spec = pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j))
    out_spec = pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j))
    if scale is None:
        assert bias is None and act == "none", "epilogue requires a scale"
        return pl.pallas_call(
            _gemm_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
            interpret=interpret,
        )(lhs, rhs)
    scale = jnp.asarray(scale, jnp.float32)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    if scale.size != 1:
        if scale.size != b:
            raise ValueError(
                f"per-row scale must have one entry per lhs row "
                f"({b}, block-padded), got shape {scale.shape}")
        return pl.pallas_call(
            functools.partial(_gemm_epilogue_rows_kernel, n_k=n_k, act=act),
            grid=grid,
            in_specs=[
                lhs_spec, rhs_spec,
                pl.BlockSpec((block_b, 1), lambda i, j, kk: (i, 0)),
                pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            scratch_shapes=[pltpu.VMEM((block_b, block_o), jnp.int32)],
            interpret=interpret,
        )(lhs, rhs, scale.reshape(b, 1), bias)
    scale = scale.reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_gemm_epilogue_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0),
                         memory_space=pltpu.SMEM),
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_o), jnp.int32)],
        interpret=interpret,
    )(scale, lhs, rhs, bias)


# ---------------------------------------------------------------------------
# Mode 2: zero-skipping segment-sum GEMM
# ---------------------------------------------------------------------------

def zs_block_shapes(x: int, block_b: int = BLOCK_B,
                    block_o: int = BLOCK_O) -> tuple:
    """(lhs, rhs, out) block shapes of the zero-skipping Mode-2 kernel.

    Single source of truth for the kernel's BlockSpecs — the engine tests
    assert the rhs block (and therefore the contraction issued per output
    tile) is x deep, not y*x deep.
    """
    return (block_b, x), (x, block_o), (block_b, block_o)


def _pack_gemm_zs_kernel(lhs_ref, rhs_ref, out_ref):
    """Zero-skipping Mode-2 body: one x-deep dot per output tile."""
    out_ref[...] = jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def _pack_gemm_zs_epilogue_kernel(scale_ref, lhs_ref, rhs_ref, bias_ref,
                                  out_ref, *, act: str):
    acc = jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    r = acc.astype(jnp.float32) * scale_ref[0, 0] + bias_ref[...]
    out_ref[...] = _apply_act(r, act)


def _pack_gemm_zs_epilogue_rows_kernel(lhs_ref, rhs_ref, scale_ref, bias_ref,
                                       out_ref, *, act: str):
    """Zero-skipping Mode-2 body with a per-row dequant scale column."""
    acc = jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    r = acc.astype(jnp.float32) * scale_ref[...] + bias_ref[...]
    out_ref[...] = _apply_act(r, act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o",
                                             "interpret", "act"))
def vdpe_pack_gemm_zs(lhs: jax.Array, rhs_seg: jax.Array,
                      block_b: int = BLOCK_B, block_o: int = BLOCK_O,
                      interpret: bool = True,
                      scale: jax.Array | None = None,
                      bias: jax.Array | None = None,
                      act: str = "none") -> jax.Array:
    """Zero-skipping Mode-2 GEMM: (B, x) int8 x (x, O) int8 -> (B, O).

    ``rhs_seg`` is the dense *segment-sum* of the block-diagonal packed
    operand (ops.pack_mode2_segments): column f holds kernel f's weights at
    their natural offset.  Because lane segments are column-disjoint the
    result is bit-identical to the (y*x)-deep block-diagonal oracle
    (ref.vdpe_pack_gemm_blockdiag) while issuing only an x-deep contraction
    and reading/holding 1/y of the RHS bytes.

    ``scale`` follows the vdpe_gemm convention: scalar, or per-row (B,) /
    (B, 1) for the batched engine's folded multi-image streams.
    """
    b, x = lhs.shape
    x2, o = rhs_seg.shape
    assert x == x2, (x, x2)  # structurally cannot issue a (y*x)-deep pass
    assert b % block_b == 0 and o % block_o == 0
    grid = (b // block_b, o // block_o)
    lhs_shape, rhs_shape, out_shape = zs_block_shapes(x, block_b, block_o)
    lhs_spec = pl.BlockSpec(lhs_shape, lambda i, j: (i, 0))
    rhs_spec = pl.BlockSpec(rhs_shape, lambda i, j: (0, j))
    out_spec = pl.BlockSpec(out_shape, lambda i, j: (i, j))
    if scale is None:
        assert bias is None and act == "none", "epilogue requires a scale"
        return pl.pallas_call(
            _pack_gemm_zs_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.int32),
            interpret=interpret,
        )(lhs, rhs_seg)
    scale = jnp.asarray(scale, jnp.float32)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    if scale.size != 1:
        if scale.size != b:
            raise ValueError(
                f"per-row scale must have one entry per lhs row "
                f"({b}, block-padded), got shape {scale.shape}")
        return pl.pallas_call(
            functools.partial(_pack_gemm_zs_epilogue_rows_kernel, act=act),
            grid=grid,
            in_specs=[
                lhs_spec, rhs_spec,
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            interpret=interpret,
        )(lhs, rhs_seg, scale.reshape(b, 1), bias)
    scale = scale.reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_pack_gemm_zs_epilogue_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(scale, lhs, rhs_seg, bias)


# ---------------------------------------------------------------------------
# Dense bf16 tile path
# ---------------------------------------------------------------------------

def _gemm_bf16_kernel(lhs_ref, rhs_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _gemm_bf16_epilogue_kernel(lhs_ref, rhs_ref, bias_ref, out_ref, acc_ref,
                               *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[...], rhs_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = _apply_act(acc_ref[...] + bias_ref[...], act)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o", "block_k",
                                             "interpret", "act"))
def gemm_bf16(lhs: jax.Array, rhs: jax.Array,
              block_b: int = BLOCK_B, block_o: int = BLOCK_O,
              block_k: int = BLOCK_K, interpret: bool = True,
              bias: jax.Array | None = None,
              act: str = "none") -> jax.Array:
    """bf16 GEMM with f32 accumulation — the framework's dense tile path.

    With ``bias``/``act`` the epilogue fuses into the last K step (no
    dequant scale: the operands are already real-valued).
    """
    b, k = lhs.shape
    _, o = rhs.shape
    assert b % block_b == 0 and o % block_o == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (b // block_b, o // block_o, n_k)
    lhs_spec = pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk))
    rhs_spec = pl.BlockSpec((block_k, block_o), lambda i, j, kk: (kk, j))
    out_spec = pl.BlockSpec((block_b, block_o), lambda i, j, kk: (i, j))
    if bias is None and act == "none":
        return pl.pallas_call(
            _gemm_bf16_kernel,
            grid=grid,
            in_specs=[lhs_spec, rhs_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
            interpret=interpret,
        )(lhs, rhs)
    if bias is None:
        bias = jnp.zeros((1, o), jnp.float32)
    return pl.pallas_call(
        functools.partial(_gemm_bf16_epilogue_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            lhs_spec, rhs_spec,
            pl.BlockSpec((1, block_o), lambda i, j, kk: (0, j)),
        ],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, block_o), jnp.float32)],
        interpret=interpret,
    )(lhs, rhs, bias)
