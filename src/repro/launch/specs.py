"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

Shannon-style: weak-type-correct, shardable, zero allocation.  Every model
input (tokens, labels, frontend-stub embeddings, decode caches) gets a
ShapeDtypeStruct carrying its NamedSharding, so ``jit(...).lower(**specs)``
fully determines the distributed program.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeConfig
from ..models import build_model
from ..models.sharding import Shardings, opt_state_specs, param_specs
from ..optim.optimizer import AdamWConfig, adamw_init


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def make_shardings(mesh: Mesh, cfg: ModelConfig, batch: int) -> Shardings:
    return Shardings(mesh=mesh, cfg=cfg, batch=batch)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, sh: Shardings,
                ) -> Dict[str, Any]:
    """Train/prefill batch ShapeDtypeStructs."""
    mesh = sh.mesh
    b, s = shape.global_batch, shape.seq_len
    tok = NamedSharding(mesh, sh.tokens())
    emb3 = NamedSharding(mesh, P(sh.batch_spec, None, None))
    batch = {"tokens": _sds((b, s), jnp.int32, tok),
             "labels": _sds((b, s), jnp.int32, tok)}
    if cfg.n_encoder_layers:
        batch["enc_embeds"] = _sds((b, s, cfg.d_model), jnp.float32, emb3)
    if cfg.prefix_len and shape.kind != "decode":
        batch["prefix_embeds"] = _sds((b, cfg.prefix_len, cfg.d_model),
                                      jnp.float32, emb3)
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, sh: Shardings,
                 ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(decode batch, cache) ShapeDtypeStructs for serve_step cells."""
    mesh = sh.mesh
    b, ctx = shape.global_batch, shape.seq_len
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    tok = NamedSharding(mesh, sh.tokens())
    batch = {"tokens": _sds((b, 1), jnp.int32, tok)}
    cache: Dict[str, Any] = {}
    kind_has_attn = cfg.family != "ssm"
    if kind_has_attn:
        kv_sh = NamedSharding(mesh, sh.kv_cache(nkv, hd))
        shape_kv = (cfg.n_layers, b, ctx, nkv, hd)
        cache["k"] = _sds(shape_kv, cfg.dtype, kv_sh)
        cache["v"] = _sds(shape_kv, cfg.dtype, kv_sh)
    if cfg.family in ("ssm", "hybrid"):
        from ..models.ssm import ssm_dims
        dm = ssm_dims(cfg)
        st_sh = NamedSharding(mesh, sh.ssm_state(dm.n_heads))
        cache["ssm"] = _sds((cfg.n_layers, b, dm.n_heads, dm.head_dim,
                             dm.d_state), jnp.float32, st_sh)
        conv_sh = NamedSharding(
            mesh, P(None, sh.batch_spec, None,
                    "model" if dm.conv_dim % sh.model_size == 0 else None))
        cache["conv"] = _sds((cfg.n_layers, b, dm.conv_width - 1,
                              dm.conv_dim), jnp.float32, conv_sh)
    if cfg.n_encoder_layers:
        kv_sh = NamedSharding(mesh, sh.kv_cache(nkv, hd))
        batch["cross_k"] = _sds((cfg.n_layers, b, ctx, nkv, hd), cfg.dtype,
                                kv_sh)
        batch["cross_v"] = _sds((cfg.n_layers, b, ctx, nkv, hd), cfg.dtype,
                                kv_sh)
    return batch, cache


def model_state_specs(cfg: ModelConfig, sh: Shardings,
                      with_opt: bool = True):
    """(params, opt_state) ShapeDtypeStructs with shardings attached."""
    model = build_model(cfg, sh)
    p_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_specs(p_shapes, sh)

    def attach(sd, spec):
        return _sds(sd.shape, sd.dtype, NamedSharding(sh.mesh, spec))

    params = jax.tree.map(attach, p_shapes, p_spec)
    if not with_opt:
        return params, None, p_spec
    quantized = cfg.opt_state_dtype == "int8"
    o_shapes = jax.eval_shape(
        lambda p: adamw_init(p, quantized=quantized), p_shapes)
    o_spec = opt_state_specs(o_shapes, p_spec, sh)
    opt = jax.tree.map(attach, o_shapes, o_spec,
                       is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return params, opt, p_spec
