"""Aggregate dry-run JSON artifacts into EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")
HBM_PER_CHIP = 16 * 2 ** 30          # v5e


def load_records(pattern: str = "*.json") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compile | args/dev | temp/dev | "
            "collective ops |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"FAIL | - | - | {r['error'][:60]} |")
            continue
        m = r["memory"]
        args_dev = m["argument_bytes"]
        temp_dev = m["temp_bytes"]
        cc = r["collectives"]
        kinds = ", ".join(f"{k}:{v['count']}" for k, v in cc.items()
                          if isinstance(v, dict) and v["count"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']:.0f}s | {fmt_bytes(args_dev)} | "
            f"{fmt_bytes(temp_dev)} | {kinds or 'none'} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
            "bound | useful/HLO FLOPs | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "error" in r or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"{rf['bound']} | {rf['useful_flops_ratio']:.3f} | "
            f"{rf['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summarize(recs: List[Dict]) -> str:
    ok = [r for r in recs if "error" not in r]
    fail = [r for r in recs if "error" in r]
    per_mesh: Dict[str, int] = {}
    for r in ok:
        per_mesh[r["mesh"]] = per_mesh.get(r["mesh"], 0) + 1
    return (f"{len(ok)} cells compiled, {len(fail)} failed "
            f"({per_mesh})")


if __name__ == "__main__":
    recs = load_records()
    print(summarize(recs))
    print()
    print(dryrun_table(recs))
    print()
    print(roofline_table(recs))
