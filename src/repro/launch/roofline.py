"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x 50 GB/s/link ICI)

cost_analysis() provides FLOPs and bytes; collective bytes come from a
census of the compiled HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute result sizes).  MODEL_FLOPS uses the
6·N·D (train) / 2·N·D (forward) convention with N = active params.
"""
from __future__ import annotations

import re
from typing import Any, Dict

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "s32": 32, "u32": 32, "s64": 64, "u64": 64, "bf16": 16, "f16": 16,
    "f32": 32, "f64": 64, "c64": 64, "c128": 128, "f8e4m3fn": 8,
    "f8e5m2": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")\(")


def _bytes_of(dtype: str, dims: str) -> int:
    bits = _DTYPE_BITS.get(dtype, 32)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bits // 8


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Census of collective ops: count + result bytes per op kind."""
    out: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _bytes_of(dtype, dims)
    # tuple-result collectives (grouped all-reduce): coarse fallback count
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D (train) / 2·N·D (forward) with N = active params."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def roofline_terms(record: Dict[str, Any], cfg: ModelConfig,
                   shape: ShapeConfig) -> Dict[str, Any]:
    """Three roofline terms in seconds.

    The compiled module is the per-device SPMD program, so cost_analysis
    FLOPs/bytes and the HLO collective census are already per-chip — the
    denominators are single-chip rates (equivalent to global values over
    chips x rate).  MODEL_FLOPS (6·N·D convention, global) is divided by
    the chip count for the useful-compute ratio.
    """
    chips = record["n_chips"]
    compute_s = record["flops"] / PEAK_FLOPS
    memory_s = record["bytes_accessed"] / HBM_BW
    coll_b = record["collectives"]["total_bytes"]
    collective_s = coll_b / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / chips
    return {
        **terms,
        "bound": bound.replace("_s", ""),
        "model_flops_global": mf,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": (mf_dev / record["flops"]
                               if record["flops"] else 0.0),
        "roofline_fraction": compute_s / max(max(terms.values()), 1e-30),
    }
