"""Production mesh construction.

Called as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    jax.sharding.AxisType) only exist on newer releases; Auto is the
    default there, so omitting it is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the single local device (CPU smoke tests)."""
    return _mesh((1, 1), ("data", "model"))
