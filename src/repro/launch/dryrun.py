import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  For each cell this script:

    1. builds the production mesh (16x16, or 2x16x16 with --multi-pod),
    2. assembles ShapeDtypeStruct inputs with NamedShardings (specs.py),
    3. jit-lowers the cell's step function (train_step / prefill / decode),
    4. compiles, and records memory_analysis() + cost_analysis() + the
       HLO collective-byte census into experiments/dryrun/<cell>.json.

Any sharding mismatch, unsupported collective, or compile failure is a
bug in the framework — the sweep (--all) is the acceptance gate.
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import get_config, load_all  # noqa: E402
from ..configs.shapes import SHAPES, applicable_shapes  # noqa: E402
from ..models import build_model  # noqa: E402
from ..optim.optimizer import AdamWConfig, make_schedule  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .roofline import collective_bytes_from_hlo, roofline_terms  # noqa: E402
from .specs import batch_specs, decode_specs, make_shardings, \
    model_state_specs  # noqa: E402
from .train import make_train_step  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


#: Hillclimb variants (EXPERIMENTS.md §Perf): name -> (cfg_overrides,
#: sharding_overrides).  Baseline = ({}, {}).
VARIANTS = {
    "baseline": ({}, {}),
    # inference weights TP-only: no per-token FSDP weight gather
    "tp_infer": ({}, {"fsdp": False}),
    # bf16 attention scores: halves score read/write traffic
    "bf16_scores": ({"attn_scores_dtype": "bfloat16"}, {}),
    # bf16 SSD intra-chunk tensors
    "ssm_bf16": ({"ssm_intra_dtype": "bfloat16"}, {}),
    # both activations levers
    "bf16_all": ({"attn_scores_dtype": "bfloat16",
                  "ssm_intra_dtype": "bfloat16"}, {}),
    # expert-parallel over the pod axis (multi-pod MoE)
    "ep_pod": ({}, {"ep_pod": True}),
    # context-sharded KV cache (kills the per-step cache re-layout)
    "kv_ctx": ({}, {"kv_ctx": True}),
    # full serving config: TP-only weights + context-sharded cache
    "serve_opt": ({}, {"fsdp": False, "kv_ctx": True}),
    # pad q-heads to the model-axis multiple (zero-output dummy heads):
    # removes the score-tensor psum for heads % 16 != 0 archs at ~14%
    # extra attention compute (resolved per-arch in lower_cell)
    "pad_heads": ({}, {}),
}


def _pad_heads_cfg(cfg, model_axis: int = 16):
    nq = (cfg.n_heads + model_axis - 1) // model_axis * model_axis
    if nq == cfg.n_heads:
        return cfg
    if nq % cfg.n_kv_heads:
        raise ValueError(
            f"pad_heads: padded n_heads {nq} not a multiple of "
            f"n_kv_heads {cfg.n_kv_heads} for {cfg.arch_id}")
    return dataclasses.replace(cfg, n_heads=nq,
                               head_dim=cfg.resolved_head_dim)


def _lower_step(cfg, shape, mesh, sh_overrides=None):
    """Lower + compile the cell's step function for ``cfg``."""
    sh = make_shardings(mesh, cfg, shape.global_batch)
    if sh_overrides:
        sh = dataclasses.replace(sh, **sh_overrides)
    model = build_model(cfg, sh=sh)
    with mesh:
        if shape.kind == "train":
            params, opt, _ = model_state_specs(cfg, sh, with_opt=True)
            batch = batch_specs(cfg, shape, sh)
            opt_cfg = AdamWConfig(quantized=cfg.opt_state_dtype == "int8")
            schedule = make_schedule("wsd" if cfg.wsd_schedule else "cosine",
                                     3e-4, 100, 10000)
            step = make_train_step(model, opt_cfg, schedule)
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, _, _ = model_state_specs(cfg, sh, with_opt=False)
            batch = batch_specs(cfg, shape, sh)
            fn = jax.jit(model.prefill_fn)
            lowered = fn.lower(params, batch)
        else:  # decode
            params, _, _ = model_state_specs(cfg, sh, with_opt=False)
            batch, cache = decode_specs(cfg, shape, sh)
            fn = jax.jit(model.decode_fn, donate_argnums=(2,))
            lowered = fn.lower(params, batch, cache, jnp.int32(0))
        return lowered, lowered.compile()


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"]),
            "coll": coll}


def _audit_cfg(cfg, n_layers: int):
    return dataclasses.replace(
        cfg, n_layers=n_layers,
        n_encoder_layers=(n_layers if cfg.n_encoder_layers else 0),
        scan_unroll=True)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline"):
    """Lower + compile one cell; returns the result record.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so the scanned layer stack's cost is invisible in the full
    module.  The audit pass lowers L=1 and L=2 variants with the scan
    fully unrolled; the L2-L1 delta is the exact per-layer cost and

        corrected(m) = m(L1) + delta(m) * (L_full - 1)

    recovers totals for FLOPs, bytes and collective bytes.  The full-depth
    module is still what's compiled and memory-analyzed (that is the
    artifact that proves the production program builds and fits).
    """
    cfg = get_config(arch)
    cfg_over, sh_over = VARIANTS[variant]
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    if variant == "pad_heads":
        cfg = _pad_heads_cfg(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    lowered, compiled = _lower_step(cfg, shape, mesh, sh_over)
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    raw = _cost_of(compiled)
    # ---- unrolled audit at L=1 and L=2 ----
    a1 = _cost_of(_lower_step(_audit_cfg(cfg, 1), shape, mesh, sh_over)[1])
    a2 = _cost_of(_lower_step(_audit_cfg(cfg, 2), shape, mesh, sh_over)[1])
    L = cfg.n_layers
    corr = {k: a1[k] + (a2[k] - a1[k]) * (L - 1)
            for k in ("flops", "bytes", "coll_bytes")}

    n_chips = 512 if multi_pod else 256
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "n_chips": n_chips,
        "kind": shape.kind,
        "compile_s": round(t_compile, 2),
        "flops": corr["flops"],
        "bytes_accessed": corr["bytes"],
        "collectives": {**a2["coll"], "total_bytes": corr["coll_bytes"]},
        "raw_module": {"flops": raw["flops"], "bytes": raw["bytes"],
                       "coll_bytes": raw["coll_bytes"]},
        "per_layer": {k: a2[k] - a1[k]
                      for k in ("flops", "bytes", "coll_bytes")},
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
    }
    record["roofline"] = roofline_terms(record, cfg, shape)
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True,
             variant: str = "baseline") -> Optional[Dict[str, Any]]:
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
    if variant != "baseline":
        tag += f"_{variant}"
    try:
        rec = lower_cell(arch, shape_name, multi_pod, variant)
    except Exception as e:  # noqa: BLE001 — sweep must report, not die
        rec = {"arch": arch, "shape": shape_name, "variant": variant,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        if verbose:
            print(f"[FAIL] {tag}: {rec['error'][:200]}")
    else:
        if verbose:
            r = rec["roofline"]
            per_dev = (rec["memory"]["argument_bytes"]
                       + rec["memory"]["temp_bytes"]) / rec["n_chips"]
            print(f"[ ok ] {tag}: compile={rec['compile_s']:.0f}s "
                  f"flops={rec['flops']:.3g} "
                  f"compute={r['compute_s']:.2e}s "
                  f"memory={r['memory_s']:.2e}s "
                  f"collective={r['collective_s']:.2e}s "
                  f"bound={r['bound']}")
    with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=VARIANTS)
    args = ap.parse_args()

    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    if args.all:
        archs = list(load_all().keys())
    else:
        archs = [args.arch]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([SHAPES[args.shape]] if args.shape
                  else applicable_shapes(cfg))
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape.name, mp, variant=args.variant)
                failures += 1 if "error" in rec else 0
    print(f"dry-run complete: failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
