"""Training driver: sharded train step + checkpoint/restart + elasticity.

``make_train_step`` builds the jitted step used both by the real driver
(``main`` below, runnable on CPU with reduced configs) and by the dry-run
(lowered against ShapeDtypeStructs on the production mesh).
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, load_all
from ..data.pipeline import SyntheticTokenPipeline
from ..models import build_model
from ..models.sharding import Shardings
from ..optim.compression import compress_gradients, compression_init
from ..optim.optimizer import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, make_schedule)
from ..runtime.fault_tolerance import StragglerDetector
from .mesh import make_host_mesh


def make_train_step(model, opt_cfg: AdamWConfig,
                    schedule: Callable[[jax.Array], jax.Array],
                    use_compression: bool = False):
    """Returns step(params, opt_state, [comp_state,] batch) -> updated."""

    def train_step(params, opt_state, batch, comp_state=None):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_comp = comp_state
        if use_compression and comp_state is not None:
            grads, new_comp = compress_gradients(grads, comp_state)
        lr = schedule(opt_state.step.astype(jnp.float32))
        new_params, new_opt = adamw_update(opt_cfg, grads, opt_state,
                                           params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, new_comp, metrics

    return train_step


def train_loop(arch: str, steps: int = 50, batch: int = 8, seq: int = 128,
               ckpt_dir: Optional[str] = None, save_every: int = 20,
               use_compression: bool = False, reduced: bool = True,
               log_every: int = 10) -> Dict[str, float]:
    """End-to-end training on the local device(s); returns final metrics."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    sh = Shardings(mesh=mesh, cfg=cfg, batch=batch)
    model = build_model(cfg, sh=None)        # single-device: no constraints
    params = model.init(jax.random.PRNGKey(0))
    quantized = cfg.opt_state_dtype == "int8"
    opt_state = adamw_init(params, quantized=quantized)
    comp_state = compression_init(params) if use_compression else None
    opt_cfg = AdamWConfig(quantized=quantized)
    schedule = make_schedule("wsd" if cfg.wsd_schedule else "cosine",
                             peak_lr=3e-4, warmup=max(steps // 10, 1),
                             total=steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, schedule,
                                      use_compression))

    from jax.sharding import PartitionSpec as P
    pipe = SyntheticTokenPipeline(cfg=cfg, mesh=mesh, batch_spec=P(None),
                                  global_batch=batch, seq_len=seq)
    mgr = CheckpointManager(ckpt_dir, save_every=save_every) \
        if ckpt_dir else None
    start = 0
    if mgr is not None:
        resumed, state = mgr.resume({"params": params, "opt": opt_state})
        if resumed is not None:
            start = resumed
            params, opt_state = state["params"], state["opt"]
    straggle = StragglerDetector()
    history = []
    for step in range(start, steps):
        t0 = time.monotonic()
        b = pipe.batch_at(step)
        params, opt_state, comp_state, metrics = step_fn(
            params, opt_state, b, comp_state)
        loss = float(metrics["loss"])
        straggle.record(jax.process_index(), time.monotonic() - t0)
        history.append(loss)
        if mgr is not None:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if step % log_every == 0:
            print(f"step {step:5d}  loss {loss:8.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    if mgr is not None:
        mgr.wait()
    return {"first_loss": history[0], "final_loss": history[-1],
            "steps": len(history)}


def main() -> None:
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compression", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full config (not reduced) — needs real hardware")
    args = ap.parse_args()
    out = train_loop(args.arch, steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=args.ckpt_dir,
                     use_compression=args.compression,
                     reduced=not args.full)
    print(f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
