"""Serving driver: batched prefill + decode with a continuous request queue.

CPU-runnable on reduced configs (examples/serve_decode.py); the dry-run
lowers the same ``decode_fn`` against the production mesh.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, load_all
from ..models import build_model


class BatchedServer:
    """Multi-slot decode server with slot recycling (continuous batching).

    Requests occupy slots; finished requests free their slot for queued
    ones.  Each slot owns its own batch-1 KV cache: ``decode_fn`` writes
    *every* batch row's k/v at the scalar cache index, so stepping one
    slot of a shared multi-row cache would overwrite the other slots'
    history at that position with garbage — per-slot caches keep each
    request's context isolated (and all slots share one jitted trace).
    """

    def __init__(self, arch: str, batch: int = 4, ctx: int = 128,
                 reduced: bool = True, seed: int = 0):
        cfg = get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        self.cfg = cfg
        self.batch = batch
        self.ctx = ctx
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.caches = [self.model.init_cache(1, ctx) for _ in range(batch)]
        self.positions = np.zeros(batch, np.int32)     # per-slot next pos
        self.active = np.zeros(batch, bool)
        self.outputs: Dict[int, List[int]] = {}
        self.queue: List[Dict] = []
        self._decode = jax.jit(self.model.decode_fn)
        self._next_id = 0
        self._slot_req: Dict[int, Dict] = {}

    def submit(self, prompt: List[int], max_tokens: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append({"id": rid, "prompt": prompt,
                           "remaining": max_tokens})
        self.outputs[rid] = []
        return rid

    def _admit(self):
        for slot in range(self.batch):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefill all but the last prompt token (teacher-forced); the
            # last one is fed by the first decode step, which produces the
            # first output logits — no position is ever fed twice
            for t, tok in enumerate(req["prompt"][:-1]):
                self._step_slot(slot, tok, t)
            self.positions[slot] = len(req["prompt"]) - 1
            self.active[slot] = True
            self._slot_req[slot] = req

    def _step_slot(self, slot: int, token: int, pos: int):
        toks = jnp.full((1, 1), token, jnp.int32)
        logits, self.caches[slot] = self._decode(
            self.params, {"tokens": toks}, self.caches[slot],
            jnp.int32(pos))
        self._last_logits = logits

    def step(self) -> int:
        """One decode step for all active slots; returns #finished."""
        self._admit()
        if not self.active.any():
            return 0
        finished = 0
        for slot in np.where(self.active)[0]:
            req = self._slot_req[slot]
            pos = int(self.positions[slot])
            last = self.outputs[req["id"]][-1] if self.outputs[req["id"]] \
                else req["prompt"][-1]
            self._step_slot(slot, last, pos)
            nxt = int(jnp.argmax(self._last_logits[0, 0, :self.cfg.vocab]))
            self.outputs[req["id"]].append(nxt)
            self.positions[slot] += 1
            req["remaining"] -= 1
            if req["remaining"] <= 0 or self.positions[slot] >= self.ctx - 1:
                self.active[slot] = False
                finished += 1
        return finished

    def run_until_done(self, max_steps: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not self.active.any():
                break
        return self.outputs


def main() -> None:
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=8)
    args = ap.parse_args()
    srv = BatchedServer(args.arch, batch=args.batch)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for i in range(args.requests):
        srv.submit(list(rng.integers(1, 100, 4)), args.max_tokens)
    outs = srv.run_until_done()
    dt = time.monotonic() - t0
    total = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests / {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)")
    for rid, toks in sorted(outs.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
