"""Deterministic synthetic token pipeline with host-sharded placement.

Production posture: each host materializes ONLY its addressable shard of
the global batch (make_array_from_callback), so the pipeline scales to
arbitrarily many hosts with zero cross-host data movement.  Determinism is
by (seed, step, global position) — a restart resumes the exact stream, and
an elastic re-mesh replays the same tokens onto the new layout.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


def _tokens_for(seed: int, step: int, rows: np.ndarray, seq: int,
                vocab: int) -> np.ndarray:
    """Deterministic per-(step, row) token block, independent of layout."""
    out = np.empty((len(rows), seq), np.int32)
    for i, r in enumerate(rows):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, int(r)]))
        out[i] = rng.integers(0, vocab, seq, dtype=np.int32)
    return out


def make_global_batch(mesh: Mesh, spec: P, shape, fill) -> jax.Array:
    """Build a global array from per-shard host callbacks."""
    sharding = NamedSharding(mesh, spec)

    def cb(index):
        return fill(index)

    return jax.make_array_from_callback(shape, sharding, cb)


@dataclasses.dataclass
class SyntheticTokenPipeline:
    cfg: ModelConfig
    mesh: Mesh
    batch_spec: P
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        v = self.cfg.vocab
        shape = (self.global_batch, self.seq_len)

        def fill(index):
            rows = np.arange(*index[0].indices(self.global_batch))
            cols = index[1]
            toks = _tokens_for(self.seed, step, rows, self.seq_len, v)
            return toks[:, cols]

        tokens = make_global_batch(self.mesh, self.batch_spec, shape, fill)
        batch = {"tokens": tokens, "labels": tokens}
        d = self.cfg.d_model
        if self.cfg.n_encoder_layers:
            batch["enc_embeds"] = self._embeds(step + 7919,
                                               (self.global_batch,
                                                self.seq_len, d))
        if self.cfg.prefix_len:
            batch["prefix_embeds"] = self._embeds(step + 104729,
                                                  (self.global_batch,
                                                   self.cfg.prefix_len, d))
        return batch

    def _embeds(self, salt: int, shape) -> jax.Array:
        spec = P(*(self.batch_spec + (None,) * (len(shape) - 1)))

        def fill(index):
            rows = np.arange(*index[0].indices(shape[0]))
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, salt]))
            # one deterministic pattern per row (frontend stub output)
            base = rng.normal(size=shape[1:]).astype(np.float32) * 0.02
            block = np.stack([base * (1.0 + 0.01 * (r % 7)) for r in rows])
            return block[(slice(None),) + tuple(index[1:])]

        return make_global_batch(self.mesh, spec, shape, fill)

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
