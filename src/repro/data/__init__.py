from .pipeline import SyntheticTokenPipeline, make_global_batch  # noqa: F401
