"""Optimizer substrate: AdamW, schedules, quantized moments, compression."""
from .optimizer import (AdamWState, adamw_init, adamw_update,  # noqa: F401
                        clip_by_global_norm, make_schedule)
from .compression import CompressionState, compress_gradients  # noqa: F401
