"""AdamW with optional int8 block-quantized moments + LR schedules.

Written against raw JAX (no optax dependency).  The int8 moment store is
the memory lever that fits grok-1-314b's train_4k cell on a single pod
(DESIGN.md §5): m and v live as int8 with per-block f32 absmax scales
(block = trailing 128 elements), dequantized transiently inside the update.

Schedules: linear warmup into either cosine decay or minicpm's WSD
(warmup-stable-decay) shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


# ---------------------------------------------------------------------------
# int8 block quantization for moment tensors
# ---------------------------------------------------------------------------

def quantize_moment(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-quantize along the LAST axis (blocks of 128).

    The int8 store keeps the parameter's own shape (last dim padded to a
    block multiple), so its sharding spec can mirror the parameter's — no
    resharding between the gradient and the moment update (flattening to
    (nblocks, 128) forced SPMD reshard copies on every leaf).
    """
    x = jnp.atleast_1d(x)
    last = x.shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(x.shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(xp.shape), scale[..., 0].astype(jnp.float32)


def dequantize_moment(q: jax.Array, scale: jax.Array,
                      shape: Tuple[int, ...]) -> jax.Array:
    blocks = q.reshape(q.shape[:-1] + (-1, BLOCK)).astype(jnp.float32)
    full = (blocks * scale[..., None]).reshape(q.shape)
    if not shape:
        return full.reshape(shape)
    return full[..., :shape[-1]].reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    m: Any            # pytree: f32 arrays, or (int8, scale) tuples
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantized: bool = False      # int8 moments


def adamw_init(params: Any, quantized: bool = False) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if quantized:
            return quantize_moment(z)
        return z

    zeros = jax.tree.map(zero_like, params,
                         is_leaf=lambda x: hasattr(x, "shape"))
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros)


def _moment_read(mom, shape):
    if isinstance(mom, tuple):
        return dequantize_moment(mom[0], mom[1], shape)
    return mom


def _moment_write(val, quantized):
    return quantize_moment(val) if quantized else val


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any, lr: jax.Array) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    is_q = lambda x: isinstance(x, tuple) or hasattr(x, "shape")  # noqa: E731

    def upd(p, g, m_old, v_old):
        g = g.astype(jnp.float32)
        m_prev = _moment_read(m_old, g.shape)
        v_prev = _moment_read(v_old, g.shape)
        m = cfg.b1 * m_prev + (1 - cfg.b1) * g
        v = cfg.b2 * v_prev + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, _moment_write(m, cfg.quantized), \
            _moment_write(v, cfg.quantized)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(
        state.m, is_leaf=lambda x: isinstance(x, tuple))[0] \
        if cfg.quantized else jax.tree_util.tree_flatten(state.m)[0]
    flat_v = jax.tree_util.tree_flatten(
        state.v, is_leaf=lambda x: isinstance(x, tuple))[0] \
        if cfg.quantized else jax.tree_util.tree_flatten(state.v)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    mdef = jax.tree_util.tree_structure(
        state.m, is_leaf=lambda x: isinstance(x, tuple)) \
        if cfg.quantized else tdef
    new_m = jax.tree_util.tree_unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(mdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def make_schedule(kind: str, peak_lr: float, warmup: int, total: int,
                  decay_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    """kind: "cosine" | "wsd" (minicpm warmup-stable-decay)."""

    def cosine(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return peak_lr * w * 0.5 * (1.0 + jnp.cos(jnp.pi * t))

    def wsd(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        decay_start = total * (1.0 - decay_frac)
        in_decay = step > decay_start
        decay = jnp.clip((step - decay_start) / (total - decay_start),
                         0.0, 1.0)
        stable = peak_lr * w
        return jnp.where(in_decay, peak_lr * (1.0 - decay), stable)

    return {"cosine": cosine, "wsd": wsd}[kind]
