"""int8 error-feedback gradient compression (distributed-optimization trick).

At 1000+ node scale the DP gradient all-reduce dominates the step's
collective bytes.  Compressing gradients to int8 with error feedback
(residual carried to the next step) cuts that volume 4x vs f32 / 2x vs
bf16 with negligible quality loss.  In the pjit programming model the
all-reduce is implicit, so the compression is expressed as
quantize -> dequantize around the gradient (XLA's all-reduce then carries
the int8-rank values; on real fleets this pairs with a reduce-scatter /
all-gather decomposition of the psum).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .optimizer import BLOCK, dequantize_moment, quantize_moment


class CompressionState(NamedTuple):
    residual: Any          # pytree of f32 error-feedback residuals


def compression_init(params: Any) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_gradients(grads: Any, state: CompressionState,
                       ) -> Tuple[Any, CompressionState]:
    """Returns (dequantized int8-rank grads, new residual state)."""

    def comp(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_moment(g32)
        deq = dequantize_moment(q, scale, g32.shape)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(state.residual)[0]
    out = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_r = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_g, CompressionState(residual=new_r)
