"""Layer descriptors for CNN tensor-product workloads (paper Section II).

Every CNN layer that performs tensor products is reduced to a ``LayerSpec``
that captures exactly the quantities the paper's mapping and simulator need:

* ``dkv_size``  S = K·K·D     (Eq. 1-region; the flattened kernel length)
* ``n_entities``              kernels that hold *distinct* weights
                              (F for SC/PC/FC, D for DC — a depthwise layer
                              has one 2-D kernel per channel)
* ``shares_div``              True when all entities consume the *same* DIV
                              stream (SC/PC/FC); False for DC, where kernel c
                              only ever sees channel c's patches
* ``n_positions``             output spatial points per entity (H_out·W_out)
* ``macs``                    exact pointwise-multiply count (Eqs. 2, 4, 5)
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Iterable, List


class ConvKind(str, enum.Enum):
    SC = "SC"    # standard convolution
    DC = "DC"    # depthwise convolution
    PC = "PC"    # pointwise (1x1) convolution
    FC = "FC"    # fully connected


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One tensor-product layer, already reduced to VDP quantities."""
    name: str
    kind: ConvKind
    k: int            # spatial kernel size K
    d: int            # input channels D (per-kernel depth; 1 for DC kernels)
    f: int            # number of kernel tensors F (output channels / units)
    h_out: int        # output height
    w_out: int        # output width

    def canonical(self) -> "LayerSpec":
        """Shape identity: this spec with the name dropped.

        Two layers with equal (kind, K, D, F, H_out, W_out) map and
        schedule identically; the mapping/simulator memo caches key on the
        canonical spec so e.g. Xception's 8 identical middle-flow blocks
        share one entry.
        """
        return _canonical_spec(self)

    @property
    def dkv_size(self) -> int:
        """S = K·K·D (paper Table III)."""
        return self.k * self.k * self.d

    @property
    def n_entities(self) -> int:
        """Distinct weight vectors to schedule (DC: one per channel)."""
        return self.f

    @property
    def shares_div(self) -> bool:
        """All entities consume the same DIV stream? (False for DC)."""
        return self.kind is not ConvKind.DC

    @property
    def n_positions(self) -> int:
        return self.h_out * self.w_out

    @property
    def n_vdps(self) -> int:
        """Total final VDP results for the layer (batch 1)."""
        return self.f * self.n_positions

    @property
    def macs(self) -> int:
        """Pointwise multiplications (Eq. 2 for SC, Eq. 4/5 for DC/PC)."""
        return self.n_vdps * self.dkv_size

    @property
    def weight_points(self) -> int:
        """Eq. 1 / Eq. 3 weight memory footprint in points."""
        return self.f * self.dkv_size


@functools.lru_cache(maxsize=65536)
def _canonical_spec(spec: LayerSpec) -> LayerSpec:
    return dataclasses.replace(spec, name="")


def sc(name: str, k: int, d: int, f: int, h_out: int, w_out: int) -> LayerSpec:
    return LayerSpec(name, ConvKind.SC, k, d, f, h_out, w_out)


def dc(name: str, k: int, channels: int, h_out: int, w_out: int) -> LayerSpec:
    # one 2-D kernel per channel: S = K·K, F = channels
    return LayerSpec(name, ConvKind.DC, k, 1, channels, h_out, w_out)


def pc(name: str, d: int, f: int, h_out: int, w_out: int) -> LayerSpec:
    return LayerSpec(name, ConvKind.PC, 1, d, f, h_out, w_out)


def fc(name: str, d: int, f: int) -> LayerSpec:
    return LayerSpec(name, ConvKind.FC, 1, d, f, 1, 1)


def total_macs(layers: Iterable[LayerSpec]) -> int:
    return sum(l.macs for l in layers)


def dkv_census(layers: Iterable[LayerSpec]) -> List[tuple]:
    """Table III style census: (kind, (K,K,D), total F, S) sorted by (kind, S)."""
    from collections import defaultdict
    acc: dict = defaultdict(int)
    for l in layers:
        acc[(l.kind.value, l.k, l.d, l.dkv_size)] += l.f
    rows = [(kind, (k, k, d), f, s) for (kind, k, d, s), f in acc.items()]
    rows.sort(key=lambda r: (r[0], r[3]))
    return rows
