"""CNN model zoo — per-layer tensor-product tables (paper Section VI-A).

The paper evaluates EfficientNetB7, Xception, NASNetMobile and ShuffleNetV2
(input batch 1).  Layer tables are reconstructed from the cited Keras
Applications definitions; the EfficientNet generator is validated to
reproduce the paper's Table III DKV census for B7 *exactly*
(tests/test_cnn_models.py).  MobileNetV1 and ResNet50 are included as extras
(both are referenced in the paper's Sections I-II).

NASNetMobile note: the NASNet-A cell DAG has data-dependent concat widths;
we model each normal cell as its published separable-conv census
(2x sep5x5 + 3x sep3x3, each separable conv applied twice) plus the 1x1
filter adjusters, and each reduction cell with its sep7x7/5x5/3x3 mix.  This
captures the DKV-size mixture (S in {9,25,49} DCs + many PC sizes), which is
what the mapping study consumes; it is an approximation of the exact graph.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List

from .layers import ConvKind, LayerSpec, dc, fc, pc, sc


def _same(n: int, stride: int) -> int:
    return math.ceil(n / stride)


def _valid(n: int, k: int, stride: int) -> int:
    return (n - k) // stride + 1


# ---------------------------------------------------------------------------
# EfficientNet (B0..B7) — exact Keras Applications reconstruction
# ---------------------------------------------------------------------------

_EFFNET_BASE_BLOCKS = [
    # (expand_ratio, channels, repeats, stride, kernel)
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
]

_EFFNET_SCALING = {  # (width, depth, resolution)
    "B0": (1.0, 1.0, 224), "B1": (1.0, 1.1, 240), "B2": (1.1, 1.2, 260),
    "B3": (1.2, 1.4, 300), "B4": (1.4, 1.8, 380), "B5": (1.6, 2.2, 456),
    "B6": (1.8, 2.6, 528), "B7": (2.0, 3.1, 600),
}


def _round_filters(filters: int, width: float, divisor: int = 8) -> int:
    filters *= width
    new = max(divisor, int(filters + divisor / 2) // divisor * divisor)
    if new < 0.9 * filters:
        new += divisor
    return int(new)


def _round_repeats(repeats: int, depth: float) -> int:
    return int(math.ceil(depth * repeats))


def efficientnet(variant: str = "B7", num_classes: int = 1000) -> List[LayerSpec]:
    width, depth, res = _EFFNET_SCALING[variant]
    layers: List[LayerSpec] = []
    hw = _same(res, 2)
    stem = _round_filters(32, width)
    layers.append(sc("stem", 3, 3, stem, hw, hw))
    c_in = stem
    for bi, (e, c, r, s, k) in enumerate(_EFFNET_BASE_BLOCKS):
        c_out = _round_filters(c, width)
        for ri in range(_round_repeats(r, depth)):
            stride = s if ri == 0 else 1
            name = f"block{bi + 1}{chr(ord('a') + ri)}"
            expanded = c_in * e
            if e != 1:
                layers.append(pc(f"{name}_expand", c_in, expanded, hw, hw))
            hw = _same(hw, stride)
            layers.append(dc(f"{name}_dwconv", k, expanded, hw, hw))
            se = max(1, int(c_in * 0.25))
            layers.append(pc(f"{name}_se_reduce", expanded, se, 1, 1))
            layers.append(pc(f"{name}_se_expand", se, expanded, 1, 1))
            layers.append(pc(f"{name}_project", expanded, c_out, hw, hw))
            c_in = c_out
    head = _round_filters(1280, width)
    layers.append(pc("top_conv", c_in, head, hw, hw))
    layers.append(fc("predictions", head, num_classes))
    return layers


# ---------------------------------------------------------------------------
# Xception (299x299)
# ---------------------------------------------------------------------------

def xception(num_classes: int = 1000) -> List[LayerSpec]:
    L: List[LayerSpec] = []
    hw = _valid(299, 3, 2)                      # 149
    L.append(sc("block1_conv1", 3, 3, 32, hw, hw))
    hw = _valid(hw, 3, 1)                       # 147
    L.append(sc("block1_conv2", 3, 32, 64, hw, hw))

    def sepconv(name: str, cin: int, cout: int, h: int) -> None:
        L.append(dc(f"{name}_dw", 3, cin, h, h))
        L.append(pc(f"{name}_pw", cin, cout, h, h))

    # entry flow: three residual blocks with maxpool stride 2
    c = 64
    for bi, cout in enumerate((128, 256, 728), start=2):
        sepconv(f"block{bi}_sepconv1", c, cout, hw)
        sepconv(f"block{bi}_sepconv2", cout, cout, hw)
        hw2 = _same(hw, 2)
        L.append(pc(f"block{bi}_residual", c, cout, hw2, hw2))
        hw, c = hw2, cout                        # 74 -> 37 -> 19
    # middle flow: 8 blocks x 3 sepconvs at 19x19, 728 channels
    for bi in range(5, 13):
        for si in range(1, 4):
            sepconv(f"block{bi}_sepconv{si}", 728, 728, hw)
    # exit flow
    sepconv("block13_sepconv1", 728, 728, hw)
    sepconv("block13_sepconv2", 728, 1024, hw)
    hw2 = _same(hw, 2)                           # 10
    L.append(pc("block13_residual", 728, 1024, hw2, hw2))
    hw = hw2
    sepconv("block14_sepconv1", 1024, 1536, hw)
    sepconv("block14_sepconv2", 1536, 2048, hw)
    L.append(fc("predictions", 2048, num_classes))
    return L


# ---------------------------------------------------------------------------
# ShuffleNetV2 1.0x (224x224)
# ---------------------------------------------------------------------------

def shufflenet_v2(num_classes: int = 1000) -> List[LayerSpec]:
    L: List[LayerSpec] = []
    hw = _same(224, 2)                           # 112
    L.append(sc("conv1", 3, 3, 24, hw, hw))
    hw = _same(hw, 2)                            # 56 (maxpool)
    c_in = 24
    stages = [(116, 4), (232, 8), (464, 4)]
    for si, (c_out, units) in enumerate(stages, start=2):
        half = c_out // 2
        for ui in range(units):
            name = f"stage{si}_unit{ui + 1}"
            if ui == 0:  # stride-2 unit: both branches convolved
                hw2 = _same(hw, 2)
                # branch 1 (shortcut): dw s2 + pw
                L.append(dc(f"{name}_b1_dw", 3, c_in, hw2, hw2))
                L.append(pc(f"{name}_b1_pw", c_in, half, hw2, hw2))
                # branch 2: pw, dw s2, pw
                L.append(pc(f"{name}_b2_pw1", c_in, half, hw, hw))
                L.append(dc(f"{name}_b2_dw", 3, half, hw2, hw2))
                L.append(pc(f"{name}_b2_pw2", half, half, hw2, hw2))
                hw = hw2
            else:        # stride-1 unit: channel split, one branch convolved
                L.append(pc(f"{name}_pw1", half, half, hw, hw))
                L.append(dc(f"{name}_dw", 3, half, hw, hw))
                L.append(pc(f"{name}_pw2", half, half, hw, hw))
            c_in = c_out
    L.append(pc("conv5", 464, 1024, hw, hw))
    L.append(fc("predictions", 1024, num_classes))
    return L


# ---------------------------------------------------------------------------
# NASNetMobile (NASNet-A 4@1056, 224x224) — cell census model (see module doc)
# ---------------------------------------------------------------------------

def nasnet_mobile(num_classes: int = 1000) -> List[LayerSpec]:
    L: List[LayerSpec] = []
    hw = _valid(224, 3, 2)                       # 111
    L.append(sc("stem_conv1", 3, 3, 32, hw, hw))

    def sep(name: str, k: int, cin: int, cout: int, h: int, stride: int = 1) -> None:
        """NASNet separable conv: applied twice (dw+pw, then dw+pw again)."""
        h2 = _same(h, stride)
        L.append(dc(f"{name}_dw1", k, cin, h2, h2))
        L.append(pc(f"{name}_pw1", cin, cout, h2, h2))
        L.append(dc(f"{name}_dw2", k, cout, h2, h2))
        L.append(pc(f"{name}_pw2", cout, cout, h2, h2))

    def normal_cell(name: str, c_prev: int, f: int, h: int) -> None:
        L.append(pc(f"{name}_adjust_prev", c_prev, f, h, h))
        L.append(pc(f"{name}_adjust_cur", c_prev, f, h, h))
        for i, k in enumerate((5, 5, 3, 3, 3)):
            sep(f"{name}_sep{i}", k, f, f, h)

    def reduction_cell(name: str, c_prev: int, f: int, h: int) -> int:
        h2 = _same(h, 2)
        L.append(pc(f"{name}_adjust_prev", c_prev, f, h, h))
        L.append(pc(f"{name}_adjust_cur", c_prev, f, h, h))
        for i, k in enumerate((7, 5, 5, 3, 3)):
            sep(f"{name}_sep{i}", k, f, f, h, stride=2 if i < 3 else 1)
        return h2

    filters = 1056 // 24                          # 44
    # stem reductions at filters/4 and filters/2
    c_prev = 32
    hw = reduction_cell("stem_red1", c_prev, filters // 4, hw)   # -> 56
    c_prev = filters // 4 * 6
    hw = reduction_cell("stem_red2", c_prev, filters // 2, hw)   # -> 28
    c_prev = filters // 2 * 6
    for stage, mult in enumerate((1, 2, 4)):
        f = filters * mult
        for ci in range(4):
            normal_cell(f"stage{stage}_cell{ci}", c_prev, f, hw)
            c_prev = f * 6                        # 5 blocks + skip concat
        if stage < 2:
            hw = reduction_cell(f"stage{stage}_red", c_prev, f * 2, hw)
    L.append(fc("predictions", c_prev, num_classes))
    return L


# ---------------------------------------------------------------------------
# Extras: MobileNetV1 and ResNet50 (referenced in paper Sections I-II)
# ---------------------------------------------------------------------------

def mobilenet_v1(num_classes: int = 1000) -> List[LayerSpec]:
    L: List[LayerSpec] = []
    hw = _same(224, 2)
    L.append(sc("conv1", 3, 3, 32, hw, hw))
    cfg = [(32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
           (256, 256, 1), (256, 512, 2)] + [(512, 512, 1)] * 5 + \
          [(512, 1024, 2), (1024, 1024, 1)]
    for i, (cin, cout, s) in enumerate(cfg, start=1):
        hw = _same(hw, s)
        L.append(dc(f"dw{i}", 3, cin, hw, hw))
        L.append(pc(f"pw{i}", cin, cout, hw, hw))
    L.append(fc("predictions", 1024, num_classes))
    return L


def resnet50(num_classes: int = 1000) -> List[LayerSpec]:
    L: List[LayerSpec] = []
    hw = _same(224, 2)                            # 112
    L.append(sc("conv1", 7, 3, 64, hw, hw))
    hw = _same(hw, 2)                             # 56 (maxpool)
    c_in = 64
    stages = [(64, 256, 3, 1), (128, 512, 4, 2),
              (256, 1024, 6, 2), (512, 2048, 3, 2)]
    for si, (mid, cout, blocks, stride) in enumerate(stages, start=2):
        for bi in range(blocks):
            s = stride if bi == 0 else 1
            name = f"conv{si}_block{bi + 1}"
            hw2 = _same(hw, s)
            L.append(pc(f"{name}_1", c_in, mid, hw2, hw2))
            L.append(sc(f"{name}_2", 3, mid, mid, hw2, hw2))
            L.append(pc(f"{name}_3", mid, cout, hw2, hw2))
            if bi == 0:
                L.append(pc(f"{name}_0", c_in, cout, hw2, hw2))  # shortcut
            c_in, hw = cout, hw2
    L.append(fc("predictions", 2048, num_classes))
    return L


MODEL_ZOO: Dict[str, Callable[[], List[LayerSpec]]] = {
    "efficientnet_b7": lambda: efficientnet("B7"),
    "xception": xception,
    "nasnet_mobile": nasnet_mobile,
    "shufflenet_v2": shufflenet_v2,
    "mobilenet_v1": mobilenet_v1,
    "resnet50": resnet50,
}

#: The four CNNs evaluated in the paper (Figs. 10-11).
PAPER_CNNS = ("efficientnet_b7", "xception", "nasnet_mobile", "shufflenet_v2")


def build_model(name: str) -> List[LayerSpec]:
    return MODEL_ZOO[name]()
