"""CNN workload substrate: layer descriptors + model zoo (paper Section VI-A)."""
from .layers import ConvKind, LayerSpec  # noqa: F401
from .models import MODEL_ZOO, build_model  # noqa: F401
