"""Mamba-2 SSD (state-space duality) block: chunked train scan + decode step.

Implements the SSD formulation of arXiv:2405.21060: per head h with state
size N and head dim P,

    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t  x_t^T          (P x N state)
    y_t = C_t h_t^T + D x_t

Training uses the chunked algorithm (intra-chunk quadratic term + inter-
chunk state carry, lax.scan over chunks); decode is the single-step
recurrence.  A causal depthwise conv (width 4) precedes the SSD as in the
reference model; its tail is carried as decode state.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from .layers import rms_norm


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    n_groups: int
    d_state: int
    head_dim: int
    conv_dim: int          # d_inner + 2 * n_groups * d_state
    conv_width: int


def ssm_dims(cfg: ModelConfig) -> SSMDims:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return SSMDims(d_inner, n_heads, s.n_groups, s.d_state, s.head_dim,
                   conv_dim, s.conv_width)


def ssm_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dm = ssm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    in_dim = 2 * dm.d_inner + 2 * dm.n_groups * dm.d_state + dm.n_heads
    return {
        "in_proj": (jax.random.normal(ks[0], (d, in_dim)) * s_in
                    ).astype(cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (dm.conv_width, dm.conv_dim))
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((dm.conv_dim,), cfg.dtype),
        "a_log": jnp.zeros((dm.n_heads,), jnp.float32),
        "d_skip": jnp.ones((dm.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((dm.n_heads,), jnp.float32),
        "norm": jnp.zeros((dm.d_inner,), cfg.dtype),
        "out_proj": (jax.random.normal(ks[2], (dm.d_inner, d))
                     * (1.0 / math.sqrt(dm.d_inner))).astype(cfg.dtype),
    }


def _split_in(proj: jax.Array, dm: SSMDims):
    """Split in_proj output into (z, x, B, C, dt)."""
    gn = dm.n_groups * dm.d_state
    z, x, b, c, dt = jnp.split(
        proj, [dm.d_inner, 2 * dm.d_inner, 2 * dm.d_inner + gn,
               2 * dm.d_inner + 2 * gn], axis=-1)
    return z, x, b, c, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along S. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b)


def _chunk_scan(x: jax.Array, dt: jax.Array, a: jax.Array,
                bmat: jax.Array, cmat: jax.Array, dm: SSMDims,
                chunk: int, intra_dtype=jnp.float32,
                sh=None) -> jax.Array:
    """Chunked SSD. x:(B,S,H,P) dt:(B,S,H) a:(H,) b/c:(B,S,G,N)."""
    bsz, s, h, p = x.shape
    n = dm.d_state
    reps = h // dm.n_groups
    nq = s // chunk
    # expand groups to heads
    bh = jnp.repeat(bmat, reps, axis=2)               # (B,S,H,N)
    ch = jnp.repeat(cmat, reps, axis=2)

    def resh(t, extra):
        return t.reshape((bsz, nq, chunk) + extra)

    def cstr_q(t):
        """Shard the chunk dim of the O(L^2) intra tensors over 'model'."""
        if sh is None or nq % sh.model_size:
            return t
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(sh.batch_spec, "model", *([None] * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(sh.mesh, spec))

    xq = resh(x, (h, p))
    dtq = resh(dt, (h,))
    bq = resh(bh, (h, n))
    cq = resh(ch, (h, n))
    adt = dtq * a[None, None, None, :]                # (B,Q,L,H)
    cum = jnp.cumsum(adt, axis=2)                     # within-chunk cumsum

    # intra-chunk: Y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,Q,Li,Lj,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(li),
                      0.0).astype(intra_dtype)
    cb = jnp.einsum("bqihn,bqjhn->bqijh", cq.astype(intra_dtype),
                    bq.astype(intra_dtype),
                    preferred_element_type=intra_dtype)
    att = cstr_q(cb * decay * dtq[:, :, None, :, :].astype(intra_dtype))
    y_intra = jnp.einsum("bqijh,bqjhp->bqihp", att,
                         xq.astype(intra_dtype),
                         preferred_element_type=jnp.float32)

    # inter-chunk state carry
    chunk_decay = jnp.exp(cum[:, :, -1, :])           # (B,Q,H)
    # state contribution of each chunk: sum_j exp(cum_L - cum_j) dt_j B_j x_j
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtq        # (B,Q,L,H)
    s_chunk = jnp.einsum("bqlh,bqlhn,bqlhp->bqhpn", w, bq, xq)

    def step(h_state, inp):
        s_c, dec = inp                                 # (B,H,P,N), (B,H)
        h_next = h_state * dec[:, :, None, None] + s_c
        return h_next, h_state                         # emit state BEFORE chunk

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, h_before = jax.lax.scan(
        step, h0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)            # (B,Q,H,P,N)

    y_inter = jnp.einsum("bqlhn,bqhpn->bqlhp",
                         cq * jnp.exp(cum)[..., None], h_before)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y


def ssm_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              state: Optional[Tuple[jax.Array, jax.Array]] = None,
              sh=None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: (B, S, D) -> (B, S, D).

    Training/prefill: state None, chunked scan over the sequence.
    Decode: state = (ssd_state (B,H,P,N), conv_tail (B,W-1,conv_dim));
    S must be 1 and the updated state is returned.
    """
    dm = ssm_dims(cfg)
    bsz, s, _ = x.shape
    proj = x @ params["in_proj"]
    z, xs, bmat, cmat, dt = _split_in(proj, dm)
    a = -jnp.exp(params["a_log"])                      # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])

    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)
    if state is None:
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        new_state = None
    else:
        ssd_state, conv_tail = state
        full = jnp.concatenate([conv_tail.astype(xbc.dtype), xbc], axis=1)
        xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                           tail=conv_tail)
        new_tail = full[:, -(dm.conv_width - 1):, :]
    xs = xbc[..., :dm.d_inner]
    gn = dm.n_groups * dm.d_state
    bmat = xbc[..., dm.d_inner:dm.d_inner + gn]
    cmat = xbc[..., dm.d_inner + gn:]

    xh = xs.reshape(bsz, s, dm.n_heads, dm.head_dim).astype(jnp.float32)
    bg = bmat.reshape(bsz, s, dm.n_groups, dm.d_state).astype(jnp.float32)
    cg = cmat.reshape(bsz, s, dm.n_groups, dm.d_state).astype(jnp.float32)

    if state is None:
        chunk = min(cfg.ssm.chunk, s)
        if s % chunk:                                  # pad to chunk multiple
            pad = chunk - s % chunk
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
        intra = (jnp.bfloat16 if cfg.ssm_intra_dtype == "bfloat16"
                 else jnp.float32)
        y = _chunk_scan(xh, dt, a, bg, cg, dm, chunk,
                        intra_dtype=intra, sh=sh)[:, :s]
    else:
        # single-step recurrence
        reps = dm.n_heads // dm.n_groups
        bh = jnp.repeat(bg[:, 0], reps, axis=1)        # (B,H,N)
        chh = jnp.repeat(cg[:, 0], reps, axis=1)
        dt0 = dt[:, 0]                                 # (B,H)
        dec = jnp.exp(dt0 * a[None, :])
        upd = (dt0[:, :, None, None] * xh[:, 0][..., None]
               * bh[:, :, None, :])
        ssd_state = ssd_state * dec[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", ssd_state, chh)[:, None]
        new_state = (ssd_state, new_tail)

    y = y + params["d_skip"][None, None, :, None] * xh[:, :s]
    y = y.reshape(bsz, s, dm.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"])
    return y @ params["out_proj"], new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                   ) -> Tuple[jax.Array, jax.Array]:
    dm = ssm_dims(cfg)
    return (jnp.zeros((batch, dm.n_heads, dm.head_dim, dm.d_state), dtype),
            jnp.zeros((batch, dm.conv_width - 1, dm.conv_dim), dtype))
