"""Unified LM substrate for the 10 assigned architectures."""
from .transformer import Model, build_model  # noqa: F401
from .sharding import Shardings  # noqa: F401
