"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

GShard/Switch-style einsum dispatch (shardable under pjit without ragged
ops): tokens pick top-k experts; each expert serves at most
C = ceil(k * S * capacity_factor / E) tokens per batch row; overflow drops
(standard).  Expert FFN weights carry an explicit E axis sharded per
DESIGN.md §5 (d_model over "data", d_ff over "model" — TP within expert;
the E axis stays replicated because 8 experts do not divide the 16-way
model axis; EP arrives through the d_ff shards).

The paper connection (DESIGN.md §4): per-expert token batches are
mixed-size tensors; the Mode-2 packed kernel (kernels/vdpe_gemm.py)
demonstrates the block-diagonal packing path for small expert batches on
real TPU; the pjit path below is the production dispatch.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import mlp_apply


def _constrain_expert_acts(t: jax.Array, sh) -> jax.Array:
    """Keep (E, B, C, D) expert activations D-FULL (batch-sharded only).

    Without this, GSPMD matches xe's D to the FSDP weight sharding and
    all-gathers the multi-GB activation instead of the ~58 MB weight shard
    (measured 6.25 GiB f32 gathers per mixtral layer — §Perf)."""
    if sh is None:
        return t
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(sh.mesh, P(None, sh.batch_spec, None, None)))


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, ff)) * s_in).astype(cfg.dtype),
        "w2": (jax.random.normal(ks[2], (e, ff, d)) * s_out).astype(cfg.dtype),
    }
    if cfg.mlp_gated:
        p["w3"] = (jax.random.normal(ks[3], (e, d, ff)) * s_in).astype(cfg.dtype)
    return p


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              sh=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> ((B, S, D), aux_loss)."""
    mc = cfg.moe
    b, s, d = x.shape
    e, k = mc.n_experts, mc.top_k
    cap = max(1, int(math.ceil(k * s * mc.capacity_factor / e)))

    logits = (x.astype(jnp.float32) @ params["router"])        # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (B,S,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                           # (E,)
    assign1 = jax.nn.one_hot(gate_idx[..., 0], e)
    ce = jnp.mean(assign1, axis=(0, 1))
    aux = e * jnp.sum(me * ce) * mc.aux_loss_weight

    # position of each (token, choice) within its expert's capacity buffer
    combine = jnp.zeros((b, s, e, cap), jnp.float32)
    for choice in range(k):
        idx = gate_idx[..., choice]                             # (B,S)
        onehot = jax.nn.one_hot(idx, e)                         # (B,S,E)
        pos = (jnp.cumsum(onehot, axis=1) - 1.0) * onehot       # (B,S,E)
        # account for slots taken by earlier choices
        if choice == 1:
            prev = jax.nn.one_hot(gate_idx[..., 0], e)
            pos = pos + jnp.sum(prev, axis=1, keepdims=True) * onehot
        keep = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap) * keep[..., None]
        combine = combine + gate_vals[..., choice][..., None, None] * pos_oh

    dispatch = (combine > 0).astype(x.dtype)                    # (B,S,E,C)
    # dispatch tokens -> expert buffers
    xe = jnp.einsum("bsec,bsd->ebcd", dispatch, x)              # (E,B,C,D)
    xe = _constrain_expert_acts(xe, sh)
    # expert FFN
    h1 = jnp.einsum("ebcd,edf->ebcf", xe, params["w1"])
    act = jax.nn.silu if cfg.mlp_act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    if cfg.mlp_gated:
        h3 = jnp.einsum("ebcd,edf->ebcf", xe, params["w3"])
        h = act(h1) * h3
    else:
        h = act(h1)
    ye = jnp.einsum("ebcf,efd->ebcd", h, params["w2"])          # (E,B,C,D)
    ye = _constrain_expert_acts(ye, sh)
    # combine back with gate weights
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(ye.dtype), ye)
    return y.astype(x.dtype), aux
