"""Transformer primitives: RMSNorm, RoPE, gated MLP, embeddings, softcap."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mlp_apply(params: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    a = jax.nn.silu if act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    if gated:
        return (a(x @ params["w1"]) * (x @ params["w3"])) @ params["w2"]
    return a(x @ params["w1"]) @ params["w2"]


def mlp_init(key: jax.Array, d: int, ff: int, gated: bool,
             dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    p = {
        "w1": (jax.random.normal(k1, (d, ff)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (ff, d)) * s_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (d, ff)) * s_in).astype(dtype)
    return p


def embed_init(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) / math.sqrt(d)).astype(dtype)


@jax.custom_vjp
def grad_cast_bf16(x: jax.Array) -> jax.Array:
    """Identity whose COTANGENT is cast to bf16.

    The f32 loss/logits boundary otherwise propagates f32 cotangents
    through the entire layer scan (double activation-gradient bytes and
    f32 collectives — measured 2x collective volume on mixtral-train,
    EXPERIMENTS.md §Perf)."""
    return x


def _gc_fwd(x):
    return x, None


def _gc_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype)
            if g.dtype == jnp.float32 else g,)


grad_cast_bf16.defvjp(_gc_fwd, _gc_bwd)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab: int) -> jax.Array:
    """Mean next-token loss; labels < 0 are masked (padding)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
