"""Unified model stack: dense / MoE / SSM / hybrid / enc-dec / VLM-audio.

One block implementation per family, stacked with lax.scan over layers
(keeps HLO size O(1) in depth — essential for the 95-layer dry-run cells)
under jax.checkpoint so only per-layer boundaries are saved; boundary
activations are sharded (d_model over "model") so the saved-carry footprint
divides across the mesh (DESIGN.md §5).

Public API (build_model):
    init(key)                      -> params (small configs only)
    loss_fn(params, batch)         -> scalar loss      (train shapes)
    prefill_fn(params, batch)      -> (logits, cache)  (prefill shapes)
    decode_fn(params, batch, cache, index) -> (logits, cache)  (decode)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (cross_entropy_loss, embed_init, mlp_apply, mlp_init,
                     rms_norm, softcap)
from .sharding import Shardings

#: "infinite" window sentinel for global-attention layers in scanned stacks.
GLOBAL_WINDOW = jnp.int32(2 ** 30)


# ---------------------------------------------------------------------------
# per-layer parameter init
# ---------------------------------------------------------------------------

def _layer_init(key: jax.Array, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), cfg.dtype)}
    if kind in ("dense", "moe", "hybrid", "encdec_dec", "encdec_enc"):
        p["attn"] = attn_mod.attn_init(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), cfg.dtype)
    if kind in ("dense", "encdec_enc", "encdec_dec"):
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_gated, cfg.dtype)
    if kind == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
    if kind == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(ks[2], cfg)
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_gated, cfg.dtype)
    if kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[2], cfg)
    if kind == "encdec_dec":
        p["ln_cross"] = jnp.zeros((d,), cfg.dtype)
        p["cross"] = attn_mod.attn_init(ks[3], cfg)
    return p


def _block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "audio": "dense",
            "moe": "moe", "ssm": "ssm", "hybrid": "hybrid",
            "encdec": "encdec_dec"}[cfg.family]


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """(L,) per-layer attention window (GLOBAL_WINDOW = full causal)."""
    if cfg.local_global_period:
        idx = jnp.arange(cfg.n_layers)
        local = (idx % cfg.local_global_period) == 0
        return jnp.where(local, jnp.int32(cfg.sliding_window), GLOBAL_WINDOW)
    if cfg.sliding_window:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    return jnp.full((cfg.n_layers,), GLOBAL_WINDOW, jnp.int32)


# ---------------------------------------------------------------------------
# block forward (one layer)
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, kind: str, sh: Optional[Shardings],
           params: Dict, x: jax.Array, positions: jax.Array,
           window: jax.Array,
           cache: Optional[Dict] = None, cache_index=None,
           enc_kv=None, mask=None,
           ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x_out, updated_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    if kind in ("dense", "moe", "hybrid", "encdec_dec", "encdec_enc"):
        h = rms_norm(x, params["ln1"])
        kv = (cache["k"], cache["v"]) if cache is not None else None
        a_out, new_kv = attn_mod.attention(
            params["attn"], h, positions, cfg, kv_cache=kv,
            cache_index=cache_index,
            window=None if mask is not None else window, mask=mask,
            bidirectional=(kind == "encdec_enc"), sh=sh)
        if kind == "hybrid":
            s_state = ((cache["ssm"], cache["conv"])
                       if cache is not None else None)
            s_out, new_state = ssm_mod.ssm_apply(params["ssm"], h, cfg,
                                                 state=s_state, sh=sh)
            a_out = (a_out + s_out) * 0.5        # parallel heads (hymba)
            if new_state is not None:
                new_cache["ssm"], new_cache["conv"] = new_state
        x = x + a_out
        if new_kv is not None:
            new_cache["k"], new_cache["v"] = new_kv
        if kind == "encdec_dec" and enc_kv is not None:
            h = rms_norm(x, params["ln_cross"])
            x = x + attn_mod.cross_attention(params["cross"], h, enc_kv, cfg)
        h = rms_norm(x, params["ln2"])
        if kind == "moe":
            m_out, aux = moe_mod.moe_apply(params["moe"], h, cfg, sh=sh)
        else:
            m_out = mlp_apply(params["mlp"], h, cfg.mlp_act, cfg.mlp_gated)
        x = x + m_out
    else:                                        # pure SSM (mamba2)
        h = rms_norm(x, params["ln1"])
        s_state = ((cache["ssm"], cache["conv"])
                   if cache is not None else None)
        s_out, new_state = ssm_mod.ssm_apply(params["ssm"], h, cfg,
                                             state=s_state, sh=sh)
        if new_state is not None:
            new_cache["ssm"], new_cache["conv"] = new_state
        x = x + s_out
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _constrain_act(x: jax.Array, sh: Optional[Shardings]) -> jax.Array:
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(sh.mesh, sh.activations()))


def _uses_windows(cfg: ModelConfig) -> bool:
    return (cfg.sliding_window is not None
            or cfg.local_global_period is not None)


def _scan_stack(cfg: ModelConfig, kind: str, sh, layers_params, x,
                positions, windows, caches=None, cache_index=None,
                enc_kv=None, mask=None):
    """lax.scan over the L stacked layers with rematerialization."""

    def body(carry, xs):
        x, aux_sum = carry
        if caches is not None and enc_kv is not None:
            lp, w, cache, ekv = xs
        elif caches is not None:
            lp, w, cache = xs
            ekv = None
        elif enc_kv is not None:
            lp, w, ekv = xs
            cache = None
        else:
            lp, w = xs
            cache, ekv = None, None
        x = _constrain_act(x, sh)
        x, new_cache, aux = _block(cfg, kind, sh, lp, x, positions, w,
                                   cache=cache, cache_index=cache_index,
                                   enc_kv=ekv, mask=mask)
        return (x, aux_sum + aux), new_cache

    xs: Tuple = (layers_params, windows)
    if caches is not None:
        xs = xs + (caches,)
    if enc_kv is not None:
        xs = xs + (enc_kv,)
    body_fn = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_caches = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), xs,
                                        unroll=True if cfg.scan_unroll
                                        else 1)
    return _constrain_act(x, sh), aux, (new_caches if caches is not None
                                        else None)


# ---------------------------------------------------------------------------
# model assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    sh: Optional[Shardings] = None

    # -- init --------------------------------------------------------------
    def init(self, key: jax.Array) -> Dict:
        cfg = self.cfg
        kind = _block_kind(cfg)
        k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, cfg.vocab_padded, cfg.d_model,
                                cfg.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
            "layers": jax.vmap(
                lambda k: _layer_init(k, cfg, kind))(layer_keys),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.vocab_padded,
                                           cfg.d_model, cfg.dtype)
        if cfg.n_encoder_layers:
            enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
            params["enc_layers"] = jax.vmap(
                lambda k: _layer_init(k, cfg, "encdec_enc"))(enc_keys)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        return params

    # -- helpers -----------------------------------------------------------
    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = params["embed"][jnp.clip(tokens, 0, cfg.vocab_padded - 1)]
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"])
        head = params.get("lm_head", params["embed"])
        logits = x @ head.T.astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32),
                         cfg.final_logit_softcap)
        if self.sh is not None:
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(self.sh.mesh, self.sh.logits()))
        return logits

    def _encode(self, params, enc_embeds):
        """Encoder stack over precomputed frame embeddings (audio stub)."""
        cfg = self.cfg
        b, t, _ = enc_embeds.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (b, t))
        windows = jnp.full((cfg.n_encoder_layers,), GLOBAL_WINDOW)
        mask = jnp.ones((b, t, t), bool)
        x, _, _ = _scan_stack(cfg, "encdec_enc", self.sh,
                              params["enc_layers"],
                              enc_embeds.astype(cfg.dtype), positions,
                              windows, mask=mask)
        return rms_norm(x, params["enc_norm"])

    def _cross_kv(self, params, enc_out):
        cfg = self.cfg

        def per_layer(lp):
            return attn_mod.project_enc_kv(lp["cross"], enc_out, cfg)

        return jax.vmap(per_layer, in_axes=0)(params["layers"])

    # -- training ----------------------------------------------------------
    def loss_fn(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        prefix = batch.get("prefix_embeds")
        x = self._embed(params, tokens, prefix)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        windows = layer_windows(cfg)
        mask = None
        if not _uses_windows(cfg):        # one causal mask for all layers
            mask = (jnp.arange(s, dtype=jnp.int32)[None, None, :]
                    <= positions[:, :, None])
        enc_kv = None
        if cfg.n_encoder_layers:
            enc_out = self._encode(params, batch["enc_embeds"])
            enc_kv = self._cross_kv(params, enc_out)
        x, aux, _ = _scan_stack(cfg, _block_kind(cfg), self.sh,
                                params["layers"], x, positions, windows,
                                enc_kv=enc_kv, mask=mask)
        logits = self._logits(params, x)
        if prefix is not None:
            logits = logits[:, prefix.shape[1]:]
        return cross_entropy_loss(logits[:, :-1], labels[:, 1:],
                                  cfg.vocab_padded) + aux

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, ctx_len: int) -> Dict:
        """Abstract/zero decode cache for the whole stack."""
        cfg = self.cfg
        kind = _block_kind(cfg)
        hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
        cache: Dict[str, Any] = {}
        if kind in ("dense", "moe", "hybrid", "encdec_dec"):
            shape = (cfg.n_layers, batch, ctx_len, nkv, hd)
            cache["k"] = jnp.zeros(shape, cfg.dtype)
            cache["v"] = jnp.zeros(shape, cfg.dtype)
        if kind in ("ssm", "hybrid"):
            dm = ssm_mod.ssm_dims(cfg)
            cache["ssm"] = jnp.zeros(
                (cfg.n_layers, batch, dm.n_heads, dm.head_dim, dm.d_state),
                jnp.float32)
            cache["conv"] = jnp.zeros(
                (cfg.n_layers, batch, dm.conv_width - 1, dm.conv_dim),
                jnp.float32)
        return cache

    def decode_fn(self, params, batch, cache, index) -> Tuple[jax.Array, Dict]:
        """One-token decode step against a populated cache.

        batch: {"tokens": (B, 1)}; index: scalar int32 cache write slot
        (== current absolute position).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        b = x.shape[0]
        positions = jnp.full((b, 1), index, jnp.int32)
        windows = layer_windows(cfg)
        enc_kv = None
        if cfg.n_encoder_layers:
            enc_kv = (batch["cross_k"], batch["cross_v"])
        x, _, new_cache = _scan_stack(cfg, _block_kind(cfg), self.sh,
                                      params["layers"], x, positions,
                                      windows, caches=cache,
                                      cache_index=index, enc_kv=enc_kv)
        return self._logits(params, x), new_cache

    def prefill_fn(self, params, batch) -> jax.Array:
        """Full-sequence forward returning last-position logits.

        (The dry-run prefill cell measures the forward pass; cache
        population reuses the same compute graph.)
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        prefix = batch.get("prefix_embeds")
        x = self._embed(params, tokens, prefix)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
        windows = layer_windows(cfg)
        mask = None
        if not _uses_windows(cfg):
            mask = (jnp.arange(s, dtype=jnp.int32)[None, None, :]
                    <= positions[:, :, None])
        enc_kv = None
        if cfg.n_encoder_layers:
            enc_out = self._encode(params, batch["enc_embeds"])
            enc_kv = self._cross_kv(params, enc_out)
        x, _, _ = _scan_stack(cfg, _block_kind(cfg), self.sh,
                              params["layers"], x, positions, windows,
                              enc_kv=enc_kv, mask=mask)
        return self._logits(params, x[:, -1:])


def build_model(cfg: ModelConfig, sh: Optional[Shardings] = None) -> Model:
    return Model(cfg=cfg, sh=sh)
