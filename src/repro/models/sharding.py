"""Partition rules: logical tensor roles -> mesh PartitionSpecs.

Mesh axes (launch/mesh.py): ("data", "model") single-pod or
("pod", "data", "model") multi-pod.  Strategy (DESIGN.md §5):

* weights:      d_model dim  -> "data"   (FSDP-style; XLA all-gathers
                                          per-layer inside the scan)
                d_ff / heads -> "model"  (tensor parallel)
                vocab        -> "model"
* activations:  batch        -> ("pod", "data")
                d_model      -> "model"  (saved scan carries stay sharded;
                                          blocks gather what they need)
* KV cache:     batch        -> dp axes when batch >= dp size,
                else sequence -> "data"  (long-context decode, batch 1)
* heads:        -> "model" when divisible, else shard head_dim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shardings:
    mesh: Mesh
    cfg: ModelConfig
    batch: int
    #: FSDP weight sharding (d_model over "data"). Train cells want it for
    #: optimizer-state capacity; decode cells pay a per-token weight gather
    #: for it (see EXPERIMENTS.md §Perf) — TP-only inference disables it.
    fsdp: bool = True
    #: Expert-parallel over the "pod" axis (multi-pod MoE variant): experts
    #: shard over pods, batch keeps to "data" so the axes don't collide.
    ep_pod: bool = False
    #: Shard the KV-cache CONTEXT dim over "model" instead of kv-heads/hd.
    #: With n_kv < model-axis size, head_dim sharding forces a per-step
    #: cache re-layout (~GB/layer); context sharding makes the score einsum
    #: fully local and reduces the PV psum to (B, H, hd) — see §Perf.
    kv_ctx: bool = False

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        if self.ep_pod:
            return ("data",) if "data" in self.mesh.axis_names else ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def dp_size(self) -> int:
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return size

    @property
    def model_size(self) -> int:
        return self.mesh.shape["model"]

    def _div(self, n: int, axis: str = "model") -> bool:
        return n % self.mesh.shape[axis] == 0

    @property
    def batch_spec(self):
        """Batch axis sharding — None when batch < dp size (e.g. long_500k)."""
        return self.dp_axes if self.batch % max(self.dp_size, 1) == 0 else None

    # ---- data ----
    def tokens(self) -> P:
        return P(self.batch_spec, None)

    def activations(self) -> P:
        d_ok = self._div(self.cfg.d_model)
        return P(self.batch_spec, None, "model" if d_ok else None)

    def logits(self) -> P:
        return P(self.batch_spec, None, "model")

    def _fsdp_axis(self):
        ok = self.fsdp and self._div(self.cfg.d_model, "data")
        return "data" if ok else None

    # ---- weights ----
    def w_in(self) -> P:          # (d_model, out): mlp w1/w3, wq/wk/wv
        return P(self._fsdp_axis(), "model")

    def w_out(self) -> P:         # (in, d_model): mlp w2, wo
        return P("model", self._fsdp_axis())

    def _expert_axis(self):
        if (self.ep_pod and "pod" in self.mesh.axis_names
                and self.cfg.moe
                and self.cfg.moe.n_experts % self.mesh.shape["pod"] == 0):
            return "pod"
        return None

    def w_expert_in(self) -> P:   # (E, d_model, d_ff)
        return P(self._expert_axis(), self._fsdp_axis(), "model")

    def w_expert_out(self) -> P:  # (E, d_ff, d_model)
        return P(self._expert_axis(), "model", self._fsdp_axis())

    def embedding(self) -> P:     # (V, d_model)
        return P("model", self._fsdp_axis())

    def scalar(self) -> P:        # norms, biases, A/D ssm params
        return P(None)

    # ---- attention internals ----
    def heads(self, n_heads: int, head_dim: int) -> P:
        """(B, S, H, hd) activation sharding."""
        if self._div(n_heads):
            return P(self.batch_spec, None, "model", None)
        if self._div(head_dim):
            return P(self.batch_spec, None, None, "model")
        return P(self.batch_spec, None, None, None)

    def kv_cache(self, n_kv: int, head_dim: int) -> P:
        """(L, B, S_ctx, n_kv, hd) cache sharding."""
        if self.batch_spec is not None:
            seq = None
            b = self.batch_spec
        else:                      # batch 1: shard the context instead
            seq = "data"
            b = None
        if self.kv_ctx and seq is None:
            return P(None, b, "model", None, None)
        if self._div(n_kv):
            return P(None, b, seq, "model", None)
        if self._div(head_dim):
            return P(None, b, seq, None, "model")
        return P(None, b, seq, None, None)

    def ssm_state(self, n_ssm_heads: int) -> P:
        """(L, B, H_ssm, head_dim, d_state) decode state."""
        h = "model" if self._div(n_ssm_heads) else None
        return P(None, self.batch_spec, h, None, None)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# parameter / optimizer-state spec trees
# ---------------------------------------------------------------------------

#: leaf-name -> (axes for the trailing dims); layer stacks get a leading None.
_IN_NAMES = ("wq", "wk", "wv", "w1", "w3", "in_proj")
_OUT_NAMES = ("wo", "w2", "out_proj")


def _fit(shape, axes, mesh) -> P:
    """Drop sharding on any dim the mesh axis does not divide."""
    spec = []
    for dim, ax in zip(shape, axes):
        if ax is None:
            spec.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        spec.append(ax if dim % size == 0 else None)
    return P(*spec)


def param_specs(shapes_tree, sh: "Shardings"):
    """PartitionSpec tree mirroring an eval_shape'd parameter tree.

    Consults ``sh.fsdp`` (weight d_model over "data") and ``sh.ep_pod``
    (MoE expert axis over "pod") so sharding variants flow through to the
    argument specs.
    """
    mesh = sh.mesh
    fsdp = sh._fsdp_axis()
    e_ax = sh._expert_axis()

    def rule(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p)))
                 for p in path]
        name = names[-1]
        nd = len(leaf.shape)
        stacked = "layers" in names or "enc_layers" in names
        lead = (None,) if stacked else ()
        body = leaf.shape[1:] if stacked else leaf.shape
        if name in ("embed", "lm_head"):
            return _fit(leaf.shape, ("model", fsdp), mesh)
        if name == "router":
            return _fit(leaf.shape, lead + (fsdp, None), mesh)
        if name in _IN_NAMES:
            if len(body) == 3:         # MoE experts (E, d, ff)
                return _fit(leaf.shape, lead + (e_ax, fsdp, "model"), mesh)
            return _fit(leaf.shape, lead + (fsdp, "model"), mesh)
        if name in _OUT_NAMES:
            if len(body) == 3:
                return _fit(leaf.shape, lead + (e_ax, "model", fsdp), mesh)
            return _fit(leaf.shape, lead + ("model", fsdp), mesh)
        if name in ("bq", "bk", "bv"):
            return _fit(leaf.shape, lead + ("model",), mesh)
        if name == "conv_w":
            return _fit(leaf.shape, lead + (None, "model"), mesh)
        if name in ("conv_b", "norm"):
            return _fit(leaf.shape, lead + ("model",), mesh)
        return P(*((None,) * nd))      # norms, scalars, A/D/dt_bias

    return jax.tree_util.tree_map_with_path(rule, shapes_tree)


def opt_state_specs(opt_shapes, param_spec_tree, sh: "Shardings"):
    """Specs for AdamWState: moments mirror their parameters.

    Quantized moments keep the parameter's shape (int8 store, last dim
    padded to the 128 block; scale drops the last dim to n_blocks), so the
    parameter's own spec applies — the moment update then needs NO
    resharding against the gradient.
    """
    mesh = sh.mesh
    flat_p, _ = jax.tree_util.tree_flatten(param_spec_tree)

    def _refit(spec: P, shape) -> P:
        """Param spec re-checked against a (possibly padded) shape."""
        axes = tuple(spec) + (None,) * (len(shape) - len(spec))
        return _fit(shape, axes, mesh)

    def moments(tree):
        # a moment tree mirrors the param tree: one leaf (or one (q, scale)
        # tuple) per parameter, in identical flatten order
        leaves, tdef = jax.tree_util.tree_flatten(
            tree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and hasattr(x[0], "shape"))
        assert len(leaves) == len(flat_p), (len(leaves), len(flat_p))
        out = []
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, tuple):    # (q ~param shape, scale -1 dim)
                q_spec = _refit(flat_p[i], leaf[0].shape)
                s_spec = _refit(P(*tuple(flat_p[i])[:-1]), leaf[1].shape)
                out.append((q_spec, s_spec))
            else:
                out.append(flat_p[i])
        return jax.tree_util.tree_unflatten(tdef, out)

    from ..optim.optimizer import AdamWState
    return AdamWState(step=P(), m=moments(opt_shapes.m),
                      v=moments(opt_shapes.v))
