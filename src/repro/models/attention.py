"""GQA attention: full / sliding-window / local-global, softcap, KV cache.

One implementation serves training (causal prefix), prefill, and
single-token decode against a cache.  Masks are built from absolute
positions, so the same code path handles SWA ring semantics and gemma2's
alternating local/global layers (the per-layer window is a scanned input).

Sharding: when a ``Shardings`` object is provided, the (B, n_kv, groups,
S, T) score tensor is constrained to shard its query-sequence dim over
"model" (softmax stays local); for single-token decode the key dim shards
instead when the context length divides.  This bounds the per-chip score
footprint for the 4k-train cells (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import apply_rope, softcap
from .sharding import Shardings


def attn_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * s).astype(cfg.dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (nq * hd, d))
               * (1.0 / math.sqrt(nq * hd))).astype(cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((nkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((nkv * hd,), cfg.dtype)
    return p


# NOTE (§Perf iteration log): two attention re-sharding strategies were
# tried for heads % model != 0 archs and REFUTED by measurement:
#   (a) constraining the score tensor directly -> involuntary full SPMD
#       rematerialization (mixtral collective term 5.2x worse);
#   (b) sequence-sharding q with replicated k/v -> backward re-shards blew
#       the llava collective term up 109s -> 297s.
# The adopted fix is head PADDING (pad_heads variant): round n_heads up to
# the model-axis multiple with zero-output dummy heads, giving conflict-
# free Megatron head sharding at ~14% extra attention compute.


def _constrain_decode_scores(scores: jax.Array,
                             sh: Optional[Shardings]) -> jax.Array:
    """Single-token decode: shard the key/context dim of the scores."""
    if sh is None or scores.shape[-1] % sh.model_size:
        return scores
    spec = P(sh.batch_spec, None, None, None, "model")
    return jax.lax.with_sharding_constraint(
        scores, NamedSharding(sh.mesh, spec))


def attention(params: dict, x: jax.Array, positions: jax.Array,
              cfg: ModelConfig, *,
              kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_index: Optional[jax.Array] = None,
              window: Optional[jax.Array] = None,
              mask: Optional[jax.Array] = None,
              bidirectional: bool = False,
              sh: Optional[Shardings] = None,
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: (B, S, D), positions: (B, S) -> ((B, S, D), updated kv cache).

    Training/prefill: ``kv_cache`` None — keys are this call's tokens.
    Decode: ``kv_cache = (k, v)`` each (B, S_ctx, n_kv, hd); this call's
    k/v are written at ``cache_index`` and attention runs over the whole
    cache with position masking (stale slots have positions > q, masked).
    """
    b, s, d = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.astype(ck.dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.astype(cv.dtype), cache_index, axis=1)
        k_use, v_use = ck, cv
        k_pos = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=positions.dtype)[None, :],
            (b, ck.shape[1]))
        new_cache = (ck, cv)
    else:
        k_use, v_use, k_pos = k, v, positions
        new_cache = None

    groups = nq // nkv
    qg = q.reshape(b, s, nkv, groups, hd)
    sdt = (jnp.bfloat16 if cfg.attn_scores_dtype == "bfloat16"
           else jnp.float32)
    scores = jnp.einsum("bsngh,btnh->bngst", qg.astype(sdt),
                        k_use.astype(sdt),
                        preferred_element_type=sdt) / math.sqrt(hd)
    scores = softcap(scores, cfg.attn_logit_softcap)
    if kv_cache is not None:
        scores = _constrain_decode_scores(scores, sh)
    if mask is None:
        # fallback: per-call mask (precomputing it once outside the layer
        # scan saves ~2 (B,S,T) int32 broadcasts per layer — see §Perf)
        if bidirectional:
            mask = jnp.ones((b, s, k_pos.shape[1]), bool)   # encoder
        else:
            mask = k_pos[:, None, :] <= positions[:, :, None]   # causal
            if window is not None:
                mask &= k_pos[:, None, :] > (positions[:, :, None] - window)
    neg = jnp.asarray(-1e30 if sdt == jnp.float32 else -3e38, sdt)
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1)      # f32 path keeps exactness;
    probs = probs.astype(sdt)                    # bf16 path trades 8 mantissa
    # contract in score layout, then reorder the (100x smaller) output —
    # asking the einsum for 'bsngh' directly makes XLA transpose the
    # (B,n,g,S,T) operand instead (§Perf: 609 GiB of layout copies on
    # llava-train before this change).
    out = jnp.einsum("bngst,btnh->bngsh", probs, v_use.astype(sdt),
                     preferred_element_type=jnp.float32)
    out = jnp.moveaxis(out, 3, 1)                # (b, s, n, g, h)
    out = out.reshape(b, s, nq * hd).astype(x.dtype)
    return out @ params["wo"], new_cache


def cross_attention(params: dict, x: jax.Array,
                    enc_kv: Tuple[jax.Array, jax.Array],
                    cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention over pre-projected encoder keys/values."""
    b, s, _ = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, s, nq, hd)
    k, v = enc_kv                                   # (B, T, n_kv, hd)
    groups = nq // nkv
    qg = q.reshape(b, s, nkv, groups, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v.astype(jnp.float32))
    return out.reshape(b, s, nq * hd).astype(x.dtype) @ params["wo"]


def project_enc_kv(params: dict, enc_out: jax.Array,
                   cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    b, t, _ = enc_out.shape
    hd, nkv = cfg.resolved_head_dim, cfg.n_kv_heads
    k = (enc_out @ params["wk"]).reshape(b, t, nkv, hd)
    v = (enc_out @ params["wv"]).reshape(b, t, nkv, hd)
    return k, v
