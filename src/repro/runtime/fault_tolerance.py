"""Fault tolerance & elasticity: heartbeats, stragglers, restart, re-mesh.

1000+-node posture (DESIGN.md §5), reused by the serving fleet
(serve/dispatch.py quarantine loop):

* HeartbeatMonitor — every worker appends (host, t) beats; the controller
  flags hosts silent for > timeout as suspected-dead.  The clock is a
  single injectable ``time_fn`` (matching ``CNNServer.time_fn``): beats
  and deadness checks read the SAME clock, so virtual-clock tests and
  trace replays are deterministic — there is no hidden
  ``time.monotonic()`` mixed with caller-supplied timestamps.
* StragglerDetector — per-step wall-time EMA; a host whose step time
  exceeds median x threshold is flagged so the controller can hot-swap it
  (on TPU pods, slow HBM / thermal throttle shows up exactly this way;
  on a photonic fleet, thermal drift re-locks do).
* run_with_restarts — wraps the train loop: on failure, back off
  exponentially (capped), restore from the newest checkpoint and
  continue; when the retry budget is exhausted the final exception is
  raised chained from the previous one, so the post-mortem sees the
  whole failure sequence instead of a bare retry-count overflow.
* plan_elastic_remesh — on permanent node loss, shrink the data axis to
  the largest feasible size, keep the model axis intact (TP topology is
  wiring-constrained; DP is not), and return the re-layout plan; the
  deterministic data pipeline replays the same stream onto the new mesh.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, Hashable, List, Optional, Tuple


class HeartbeatMonitor:
    """Liveness by silence: hosts with no beat for > timeout are suspect.

    One clock, injected: ``time_fn`` stamps beats AND measures silence.
    Tests drive a virtual clock by injecting their own callable; the
    default is wall ``time.monotonic``.
    """

    def __init__(self, timeout_s: float = 60.0,
                 time_fn: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._time = time_fn
        self.beats: Dict[Hashable, float] = {}

    def beat(self, host: Hashable) -> None:
        self.beats[host] = self._time()

    def dead_hosts(self) -> List[Hashable]:
        t = self._time()
        return [h for h, last in self.beats.items()
                if t - last > self.timeout_s]


class StragglerDetector:
    """Flags hosts whose step time exceeds median x threshold."""

    def __init__(self, threshold: float = 2.0, window: int = 20):
        self.threshold = threshold
        self.window = window
        self.times: Dict[Hashable, List[float]] = {}

    def record(self, host: Hashable, step_time_s: float) -> None:
        self.times.setdefault(host, []).append(step_time_s)
        self.times[host] = self.times[host][-self.window:]

    def stragglers(self) -> List[Hashable]:
        if len(self.times) < 2:
            return []
        medians = {h: statistics.median(v) for h, v in self.times.items()}
        fleet = statistics.median(medians.values())
        return [h for h, m in medians.items()
                if m > self.threshold * fleet]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_hosts: Tuple[int, ...]
    global_batch_scale: float      # keep per-chip batch constant


def plan_elastic_remesh(axes: Tuple[str, ...], shape: Tuple[int, ...],
                        healthy_chips: int) -> ElasticPlan:
    """Shrink the data axis to the largest size that fits healthy chips.

    The model (and pod) axes are preserved: tensor-parallel sharding is
    ICI-topology-bound, while the data axis only carries gradient
    all-reduces, so dropping DP replicas is the cheap direction.
    """
    shape = tuple(shape)
    data_ix = axes.index("data")
    other = 1
    for i, s in enumerate(shape):
        if i != data_ix:
            other *= s
    new_data = max(1, healthy_chips // other)
    # keep power-of-two DP groups for clean psum radix
    while new_data & (new_data - 1):
        new_data -= 1
    new_shape = tuple(new_data if i == data_ix else s
                      for i, s in enumerate(shape))
    return ElasticPlan(
        old_shape=shape, new_shape=new_shape, axes=axes,
        dropped_hosts=(),
        global_batch_scale=new_data / shape[data_ix])


def run_with_restarts(step_fn: Callable[[int], None], start_step: int,
                      num_steps: int,
                      restore_fn: Callable[[], int],
                      max_restarts: int = 3,
                      backoff_base_s: float = 0.05,
                      backoff_cap_s: float = 2.0,
                      sleep_fn: Callable[[float], None] = time.sleep,
                      ) -> int:
    """Drive step_fn with restore-on-failure. Returns last completed step.

    Each failure backs off exponentially (``backoff_base_s * 2**k``,
    capped at ``backoff_cap_s``) before restoring — a crash loop must not
    hammer the checkpoint store.  When ``max_restarts`` is exhausted the
    final exception is raised chained from the *previous* recorded
    failure (``raise exc from last_exc``), so nothing about the failure
    history is swallowed between retries.
    """
    restarts = 0
    step = start_step
    last_exc: Optional[BaseException] = None
    while step < num_steps:
        try:
            step_fn(step)
            step += 1
        except Exception as exc:
            restarts += 1
            if restarts > max_restarts:
                if last_exc is not None:
                    raise exc from last_exc
                raise
            sleep_fn(min(backoff_base_s * (2 ** (restarts - 1)),
                         backoff_cap_s))
            last_exc = exc
            step = restore_fn()
    return step
