from .fault_tolerance import (ElasticPlan, HeartbeatMonitor,  # noqa: F401
                              StragglerDetector, plan_elastic_remesh,
                              run_with_restarts)
