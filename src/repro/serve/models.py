"""Serving model zoo: executable mini variants of the paper CNNs.

The analytic model zoo (cnn/models.py) describes the paper's CNNs as flat
LayerSpec *censuses* — residual branches, SE blocks and concats appear as
standalone rows — which is exactly what the mapping study and simulator
need, but such a table is not a sequentially executable network.  For the
functional serving path each paper CNN therefore gets a small *sequential*
stand-in here that preserves its architectural signature (EfficientNet's
expand/depthwise/SE-ish/project MBConv shape, Xception's separable-conv
chains, ShuffleNetV2's pointwise/depthwise/pointwise units), spans both
paper GEMM modes (Mode-2 small-S contractions AND Mode-1 dense ones) plus
the depthwise VPU path, and is cheap enough to run through the Pallas
kernels in interpret mode on a CPU host.

Weight factories are deterministic in (model, seed): the registry can
evict a plan and re-imprint bit-identical DKVs later.

Hardware-time telemetry does NOT use these minis: the simulator costs the
*paper-scale* layer tables (PAPER_SCALE_SPECS — the full EfficientNetB7 /
Xception / ShuffleNetV2 censuses), modeling the real CNN the mini stands
in for.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..cnn.layers import ConvKind, LayerSpec
from ..cnn.models import MODEL_ZOO
from ..engine import LayerDef, defs_to_specs


def _w(rng: np.random.Generator, shape: Tuple[int, ...]) -> jnp.ndarray:
    return jnp.asarray(rng.normal(size=shape) * 0.5, jnp.float32)


def _b(rng: np.random.Generator, n: int) -> jnp.ndarray:
    return jnp.asarray(rng.normal(size=(n,)) * 0.1, jnp.float32)


def efficientnet_mini(seed: int = 0) -> List[LayerDef]:
    """MBConv-shaped stand-in: stem SC, expand PC, DC, project PC, head, FC."""
    rng = np.random.default_rng((seed, 0xEFF))
    return [
        LayerDef("stem", ConvKind.SC, _w(rng, (8, 3, 3, 3)),
                 act="relu", stride=2),
        LayerDef("expand", ConvKind.PC, _w(rng, (24, 1, 1, 8)),
                 bias=_b(rng, 24), act="relu6"),
        LayerDef("dwconv", ConvKind.DC, _w(rng, (24, 3, 3)),
                 act="relu6", stride=2),
        LayerDef("project", ConvKind.PC, _w(rng, (16, 1, 1, 24))),
        LayerDef("head", ConvKind.PC, _w(rng, (32, 1, 1, 16)),
                 bias=_b(rng, 32), act="relu"),
        LayerDef("predictions", ConvKind.FC, _w(rng, (10, 4 * 4 * 32))),
    ]


def xception_mini(seed: int = 0) -> List[LayerDef]:
    """Separable-conv-chain stand-in: entry SC then two dw+pw sepconvs."""
    rng = np.random.default_rng((seed, 0xCEB))
    return [
        LayerDef("conv1", ConvKind.SC, _w(rng, (16, 3, 3, 3)),
                 act="relu", stride=2),
        LayerDef("sep1_dw", ConvKind.DC, _w(rng, (16, 3, 3)), act="relu"),
        LayerDef("sep1_pw", ConvKind.PC, _w(rng, (32, 1, 1, 16)),
                 bias=_b(rng, 32), act="relu"),
        LayerDef("sep2_dw", ConvKind.DC, _w(rng, (32, 3, 3)),
                 act="relu", stride=2),
        # S = 32 rides Mode 2; the exit 1x1 below (S = 48) needs Mode 1
        LayerDef("sep2_pw", ConvKind.PC, _w(rng, (48, 1, 1, 32)), act="relu"),
        LayerDef("exit_pw", ConvKind.PC, _w(rng, (64, 1, 1, 48)), act="relu"),
        LayerDef("predictions", ConvKind.FC, _w(rng, (10, 4 * 4 * 64))),
    ]


def shufflenet_mini(seed: int = 0) -> List[LayerDef]:
    """ShuffleNetV2-unit stand-in: stem SC, pw/dw/pw unit, conv5, FC."""
    rng = np.random.default_rng((seed, 0x5F7))
    return [
        LayerDef("conv1", ConvKind.SC, _w(rng, (12, 3, 3, 3)),
                 act="relu", stride=2),
        LayerDef("unit_pw1", ConvKind.PC, _w(rng, (24, 1, 1, 12)),
                 act="relu"),
        LayerDef("unit_dw", ConvKind.DC, _w(rng, (24, 3, 3)), stride=2),
        LayerDef("unit_pw2", ConvKind.PC, _w(rng, (24, 1, 1, 24)),
                 bias=_b(rng, 24), act="relu"),
        LayerDef("conv5", ConvKind.PC, _w(rng, (48, 1, 1, 24)), act="relu"),
        LayerDef("predictions", ConvKind.FC, _w(rng, (10, 4 * 4 * 48))),
    ]


#: name -> (weight factory, input shape HWC, paper-scale simulator table)
SERVING_MODELS: Dict[str, Tuple[Callable[[int], List[LayerDef]],
                                Tuple[int, int, int], str]] = {
    "efficientnet_mini": (efficientnet_mini, (16, 16, 3), "efficientnet_b7"),
    "xception_mini": (xception_mini, (16, 16, 3), "xception"),
    "shufflenet_mini": (shufflenet_mini, (16, 16, 3), "shufflenet_v2"),
}


def serving_defs(name: str, seed: int = 0) -> List[LayerDef]:
    return SERVING_MODELS[name][0](seed)


def serving_input_shape(name: str) -> Tuple[int, int, int]:
    return SERVING_MODELS[name][1]


def paper_scale_specs(name: str) -> List[LayerSpec]:
    """The full paper-CNN layer table this serving model stands in for."""
    return MODEL_ZOO[SERVING_MODELS[name][2]]()


def specs_for_defs(defs: Sequence[LayerDef],
                   input_shape: Tuple[int, int, int]) -> List[LayerSpec]:
    """Derive the analytic LayerSpec table of an executable LayerDef chain.

    Delegates to ``engine.defs_to_specs`` (the planner scores the same
    walk), so ``simulate(acc, specs_for_defs(defs, shape), batch)`` models
    precisely the tensor products the engine will run.
    """
    return list(defs_to_specs(defs, input_shape))
