"""Photonic fault injection + the serving stack's typed failure domain.

Real MRR accelerators fail in characteristic ways: thermal drift detunes
rings until the comb must re-lock (HEANA, arxiv 2402.03247, models the
tuning cost), a comb-switch can stick mid-reconfiguration (the switching
latencies of arxiv 2402.03149), a control host can hang or die outright —
and, scariest of all for a serving system, a detuned analog datapath can
keep *completing* while returning plausible-but-wrong integers.  A fleet
has to keep producing correct results at degraded throughput through all
of them — which is only testable if the failures themselves are
injectable and replayable.

``FaultInjector`` is that layer: a deterministic schedule of
``FaultEvent``s keyed by each instance's *dispatch count* (not wall time),
so a chaos run replays bit-identically — the Nth shard sent to ``acc1``
always hits the same fault regardless of host speed.  The dispatcher
consults the injector once per shard dispatch (and once per quarantine
probe or canary — a probe IS a dispatch attempt, which is how
finite-duration faults expire and instances earn readmission).

The fault taxonomy splits into two classes with distinct ``severity``
semantics:

**Availability-class** (``AVAILABILITY_KINDS`` — the PR-6 domain; every
one of these either delays a shard or fails it outright, but a completed
shard is always *correct*):

* ``CRASH``          — the instance is gone: the shard raises
                       ``InstanceCrashed``; permanent unless ``duration``
                       bounds it.  ``severity`` is ignored.
* ``STUCK_RECONFIG`` — the comb-switch is stuck: the shard raises
                       ``ReconfigStuck``; typically transient (the
                       controller re-locks after ``duration`` attempts).
                       ``severity`` is ignored.
* ``STRAGGLE``       — the host hangs: ``severity`` is the injected delay
                       in *seconds* before executing, tripping the
                       dispatcher's per-shard deadline.
* ``THERMAL_DRIFT``  — rings drifted off resonance: every dispatch pays
                       ``severity`` *seconds* of re-lock/retune delay but
                       still completes correctly (degradation, not
                       failure).

**Integrity-class** (``INTEGRITY_KINDS`` — silent data corruption; the
shard completes on time and returns *wrong int32 accumulators* unless the
ABFT/guard layer catches it):

* ``ANALOG_NOISE``   — Eq. 9/10 photodetector noise above the design
                       floor: ``severity`` is the Gaussian sigma in
                       integer *LSBs* added to every accumulator element
                       (schedule builders derive it from
                       ``photonics.integer_noise_sigma_lsb``).
* ``THERMAL_DETUNE`` — rings detuned but still resolving: ``severity`` is
                       the fractional *gain drift* g; accumulators see
                       ``round(acc * g + bias)`` with a proportional bias
                       drift (``DETUNE_BIAS_LSB_PER_DRIFT`` LSBs per unit
                       g).
* ``STUCK_MRR``      — weight ring(s) stuck at full transmission: the
                       resident DKV imprint itself is wrong.  ``severity``
                       is the (rounded) *count* of stuck weight elements.
* ``ADC_BITFLIP``    — marginal ADC sampling: ``severity`` is the
                       per-element *probability* of a random low-order
                       bit flipping in the digitized accumulator.

Corruption is deterministic and seed-replayable: each corrupted dispatch
derives its RNG seed from (injector seed, instance name CRC, dispatch
index), so the same schedule against the same dispatch sequence corrupts
the same elements the same way — which is what lets the recovery tests
assert *bitwise* identity with the fault-free run after re-execution.

The typed errors double as the public failure vocabulary of the whole
serve package (``AdmissionRejected`` is what SLO shedding raises;
``OutputCorrupted`` is what the ABFT/guard layer raises when a shard's
integer outputs fail verification).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import photonics as ph
from ..obs.tracer import NOOP_TRACER


# ---------------------------------------------------------------------------
# typed failure domain
# ---------------------------------------------------------------------------

class ServingFault(RuntimeError):
    """Base of every typed serving failure."""


class InstanceCrashed(ServingFault):
    """A fleet instance died while (or before) executing a shard."""

    def __init__(self, instance: str):
        super().__init__(f"instance {instance!r} crashed")
        self.instance = instance


class ReconfigStuck(ServingFault):
    """The instance's comb-switch stuck mid-reconfiguration (transient)."""

    def __init__(self, instance: str):
        super().__init__(
            f"instance {instance!r}: comb-switch reconfiguration stuck")
        self.instance = instance


class ShardDeadlineExceeded(ServingFault):
    """A shard missed its per-shard deadline (straggler/hang)."""

    def __init__(self, instance: str, deadline_s: float):
        super().__init__(f"instance {instance!r} missed the "
                         f"{deadline_s * 1e3:.0f}ms shard deadline")
        self.instance = instance
        self.deadline_s = deadline_s


class OutputCorrupted(ServingFault):
    """A shard's integer outputs failed integrity verification (SDC).

    Raised by the dispatcher when the guarded execution path's ABFT
    checksums, range guards, weight-imprint checksums, or a canary probe
    flag a shard — the detection that turns *silent* data corruption into
    a typed, recoverable fault.  Carries the first flagged layer index and
    the detector names that fired so chaos harnesses (and operators) can
    attribute the catch.
    """

    def __init__(self, instance: str, layer: int = -1,
                 detectors: Tuple[str, ...] = ()):
        det = ", ".join(detectors) if detectors else "canary"
        super().__init__(
            f"instance {instance!r} returned corrupted outputs "
            f"(layer {layer}, detected by {det})")
        self.instance = instance
        self.layer = layer
        self.detectors = tuple(detectors)


class NoHealthyInstances(ServingFault):
    """Every instance is quarantined/dead; the batch cannot be served."""


class RetriesExhausted(ServingFault):
    """A batch kept failing past the dispatcher's retry budget."""


class AdmissionRejected(ServingFault):
    """SLO admission control shed this request (typed, catchable).

    Raised at ``submit`` time when the surviving fleet cannot plausibly
    serve the request inside the SLO deadline; carries the estimate that
    justified the rejection so clients can back off intelligently.
    """

    def __init__(self, model: str, est_s: float, deadline_s: float,
                 healthy_fraction: float):
        super().__init__(
            f"request for {model!r} shed: estimated completion "
            f"{est_s * 1e3:.0f}ms exceeds the {deadline_s * 1e3:.0f}ms SLO "
            f"(healthy fleet fraction {healthy_fraction:.2f})")
        self.model = model
        self.est_s = est_s
        self.deadline_s = deadline_s
        self.healthy_fraction = healthy_fraction


class RequestExpired(ServingFault):
    """A queued request's per-request deadline passed before dispatch.

    The continuous batcher's cancellation path: ``submit(deadline_s=...)``
    arms an absolute expiry on the server clock, and the server's expiry
    sweep (start of every ``step``) removes dead requests from the queue
    and records this fault in ``CNNServer.failures`` instead of ever
    serving a result the requester has stopped waiting for.
    """

    def __init__(self, model: str, rid: int, deadline_s: float,
                 waited_s: float):
        super().__init__(
            f"request {rid} for {model!r} expired in queue: waited "
            f"{waited_s * 1e3:.0f}ms past its "
            f"{deadline_s * 1e3:.0f}ms deadline")
        self.model = model
        self.rid = rid
        self.deadline_s = deadline_s
        self.waited_s = waited_s


class QueueOverflow(ServingFault):
    """A bounded per-model queue was full; the request was never queued.

    Backpressure for the batch class: unlike ``AdmissionRejected`` (an
    SLO estimate) this is a hard structural bound — under overload the
    queue bound is what keeps memory and drain time finite.
    """

    def __init__(self, model: str, depth: int, max_queue: int):
        super().__init__(
            f"request for {model!r} rejected: queue full "
            f"({depth}/{max_queue})")
        self.model = model
        self.depth = depth
        self.max_queue = max_queue


class BrownoutShed(ServingFault):
    """The brownout ladder is shedding this priority class at the door.

    Raised at ``submit`` time while the controller sits on a rung with
    ``admit_batch=False``: batch-class work is refused so the interactive
    class keeps its SLO — the explicit, typed form of "degrade the batch
    tier first".
    """

    def __init__(self, model: str, rung: str):
        super().__init__(
            f"batch-class request for {model!r} shed by brownout rung "
            f"{rung!r}")
        self.model = model
        self.rung = rung


class CorruptionBudgetExceeded(ServingFault):
    """Integrity SLO shedding: the corrupted-frame rate blew its budget.

    The integrity twin of ``AdmissionRejected``: raised at ``submit`` time
    when the EMA of detected-corruption frames per served frame exceeds
    ``ServeSLO.max_corrupted_frame_rate`` — a fleet detecting this much
    SDC should stop admitting until quarantine/recovery bring the rate
    back down (the EMA decays under clean traffic, so admission resumes).
    """

    def __init__(self, model: str, rate: float, budget: float):
        super().__init__(
            f"request for {model!r} shed: corrupted-frame rate "
            f"{rate:.3f} exceeds the {budget:.3f} integrity SLO budget")
        self.model = model
        self.rate = rate
        self.budget = budget


# ---------------------------------------------------------------------------
# fault schedule
# ---------------------------------------------------------------------------

class FaultKind(enum.Enum):
    # availability class (PR-6): delay or fail a shard; results stay correct
    CRASH = "crash"
    STUCK_RECONFIG = "stuck_reconfig"
    STRAGGLE = "straggle"
    THERMAL_DRIFT = "thermal_drift"
    # integrity class: the shard completes with corrupted int32 accumulators
    ANALOG_NOISE = "analog_noise"
    THERMAL_DETUNE = "thermal_detune"
    STUCK_MRR = "stuck_mrr"
    ADC_BITFLIP = "adc_bitflip"


#: kinds that fail the shard outright (vs merely delaying it)
FAILING_KINDS = (FaultKind.CRASH, FaultKind.STUCK_RECONFIG)

#: the PR-6 fault domain: timing/availability only — a completed shard is
#: always correct.  ``severity`` is a delay in seconds (or ignored for the
#: failing kinds).
AVAILABILITY_KINDS = (FaultKind.CRASH, FaultKind.STUCK_RECONFIG,
                      FaultKind.STRAGGLE, FaultKind.THERMAL_DRIFT)

#: value-corrupting kinds: the shard completes but its integer outputs are
#: wrong.  ``severity`` is kind-specific (module docstring): sigma in LSBs
#: (ANALOG_NOISE), fractional gain drift (THERMAL_DETUNE), stuck-element
#: count (STUCK_MRR), per-element flip probability (ADC_BITFLIP).
INTEGRITY_KINDS = (FaultKind.ANALOG_NOISE, FaultKind.THERMAL_DETUNE,
                   FaultKind.STUCK_MRR, FaultKind.ADC_BITFLIP)

#: bias drift accompanying a THERMAL_DETUNE gain drift: LSBs of additive
#: offset per unit of fractional gain error (a detuned ring shifts its
#: operating point, not just its slope).
DETUNE_BIAS_LSB_PER_DRIFT = 8.0


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on one instance.

    Activation is by the instance's dispatch count: the fault is live for
    dispatch indices ``start <= n < start + duration`` (``duration=None``
    means forever).  ``severity`` semantics depend on the kind's class —
    seconds of delay for the availability delay kinds, ignored for the
    failing kinds, and the kind-specific corruption magnitude for the
    integrity kinds (module docstring).
    """
    instance: str
    kind: FaultKind
    start: int
    duration: Optional[int] = None
    severity: float = 0.0

    def __post_init__(self) -> None:
        if self.start < 0:
            raise ValueError(f"start must be >= 0, got {self.start}")
        if self.duration is not None and self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if self.severity < 0:
            raise ValueError(f"severity must be >= 0, got {self.severity}")

    def active_at(self, n: int) -> bool:
        if n < self.start:
            return False
        return self.duration is None or n < self.start + self.duration


@dataclasses.dataclass(frozen=True)
class CorruptionSpec:
    """The value-corruption a dispatch must apply (all integrity faults
    live on the instance for this dispatch, folded together).

    The engine's guarded execution path turns this into traced corruption
    of the int32 accumulators (engine/executor.corrupt_accumulators) and,
    for ``stuck_rings``, host-side corruption of the packed weight params
    (engine/pipeline.corrupted_layer_params).  ``seed`` is derived
    deterministically from (injector seed, instance, dispatch index) so
    replay is bitwise.
    """
    seed: int = 0
    sigma_lsb: float = 0.0     # ANALOG_NOISE: Gaussian sigma in LSBs
    gain: float = 1.0          # THERMAL_DETUNE: multiplicative drift
    bias_lsb: float = 0.0      # THERMAL_DETUNE: additive drift in LSBs
    flip_prob: float = 0.0     # ADC_BITFLIP: per-element flip probability
    stuck_rings: int = 0       # STUCK_MRR: corrupted weight elements

    @property
    def active(self) -> bool:
        return (self.sigma_lsb > 0 or self.gain != 1.0
                or self.bias_lsb != 0 or self.flip_prob > 0
                or self.stuck_rings > 0)


@dataclasses.dataclass(frozen=True)
class DispatchEffects:
    """What the injector does to one dispatch: delay, corrupt, maybe fail."""
    delay_s: float = 0.0
    fault: Optional[FaultKind] = None     # a FAILING_KINDS member, or None
    corruption: Optional[CorruptionSpec] = None   # live integrity faults


class FaultInjector:
    """Deterministic, replayable fault schedule over a fleet.

    Stateful only in per-instance dispatch counters; two injectors built
    from the same schedule (and seed) replay identically against the same
    dispatch sequence — corruption RNG included.  ``trips`` counts every
    fault activation by kind (the chaos harness's ground truth for "the
    faults actually fired") and ``corrupted_dispatches`` counts dispatches
    that returned an active ``CorruptionSpec`` (the denominator of the SDC
    detection rate).
    """

    def __init__(self, schedule: Sequence[FaultEvent] = (), seed: int = 0):
        self.schedule: Tuple[FaultEvent, ...] = tuple(schedule)
        self.seed = seed
        self.dispatches: Dict[str, int] = {}
        self.trips: Dict[str, int] = {k.value: 0 for k in FaultKind}
        self.corrupted_dispatches = 0
        # shard workers dispatch concurrently; counters must not tear
        self._lock = threading.Lock()
        #: span tracer; every fault activation becomes a ``fault.<kind>``
        #: instant on the instance's track (the dispatcher wires this)
        self.tracer = NOOP_TRACER

    def events_for(self, instance: str) -> List[FaultEvent]:
        return [e for e in self.schedule if e.instance == instance]

    def peek(self, instance: str) -> List[FaultEvent]:
        """Faults that WOULD be live for the instance's next dispatch."""
        n = self.dispatches.get(instance, 0)
        return [e for e in self.events_for(instance) if e.active_at(n)]

    def _corruption_seed(self, instance: str, n: int) -> int:
        """Deterministic per-dispatch corruption seed.

        (injector seed, CRC32 of the instance name, dispatch index) through
        numpy's SeedSequence — stable across processes and Python hash
        randomization, so a replayed schedule corrupts identically.
        """
        ss = np.random.SeedSequence(
            [self.seed, zlib.crc32(instance.encode()), n])
        return int(ss.generate_state(1)[0])

    def on_dispatch(self, instance: str,
                    probe: bool = False) -> DispatchEffects:
        """Advance the instance's dispatch counter and report effects.

        Delays accumulate across simultaneously-live delay faults; a
        failing fault (crash/stuck-reconfig) wins over delays AND over
        corruption — the shard never executes.  Live integrity faults fold
        into one ``CorruptionSpec`` (sigmas add, gains multiply, flip
        probabilities combine independently, stuck counts add).

        ``probe=True`` marks a readmission health check: it burns down the
        instance's fault windows like any dispatch but is excluded from
        ``corrupted_dispatches`` (the SDC detection-rate denominator counts
        shard executions, not health checks).
        """
        fired: List[FaultEvent] = []
        with self._lock:
            n = self.dispatches.get(instance, 0)
            self.dispatches[instance] = n + 1
            delay = 0.0
            failing: Optional[FaultKind] = None
            integrity: List[FaultEvent] = []
            for e in self.events_for(instance):
                if not e.active_at(n):
                    continue
                self.trips[e.kind.value] += 1
                fired.append(e)
                if e.kind in FAILING_KINDS:
                    failing = failing or e.kind
                elif e.kind in INTEGRITY_KINDS:
                    integrity.append(e)
                else:
                    delay += e.severity
            corruption: Optional[CorruptionSpec] = None
            if integrity and failing is None:
                sigma, gain, bias, flip, stuck = 0.0, 1.0, 0.0, 0.0, 0
                for e in integrity:
                    if e.kind is FaultKind.ANALOG_NOISE:
                        sigma += e.severity
                    elif e.kind is FaultKind.THERMAL_DETUNE:
                        gain *= 1.0 + e.severity
                        bias += DETUNE_BIAS_LSB_PER_DRIFT * e.severity
                    elif e.kind is FaultKind.ADC_BITFLIP:
                        flip = 1.0 - (1.0 - flip) * (1.0 - e.severity)
                    elif e.kind is FaultKind.STUCK_MRR:
                        stuck += max(1, int(round(e.severity)))
                corruption = CorruptionSpec(
                    seed=self._corruption_seed(instance, n),
                    sigma_lsb=sigma, gain=gain, bias_lsb=bias,
                    flip_prob=flip, stuck_rings=stuck)
                if corruption.active:
                    if not probe:
                        self.corrupted_dispatches += 1
                else:
                    corruption = None
        for e in fired:      # outside the lock: the tracer locks its ring
            self.tracer.instant(f"fault.{e.kind.value}", cat="fault",
                                tid=instance, instance=instance,
                                dispatch_index=n, severity=e.severity)
        return DispatchEffects(delay_s=delay, fault=failing,
                               corruption=corruption)

    @staticmethod
    def raise_for(fault: FaultKind, instance: str) -> None:
        if fault is FaultKind.CRASH:
            raise InstanceCrashed(instance)
        if fault is FaultKind.STUCK_RECONFIG:
            raise ReconfigStuck(instance)
        raise ValueError(f"{fault} is not a failing fault kind")


# memo of the Eq. 9/10 design-floor sigma at the paper's default operating
# point (4-bit, 1 Gbps) — the base magnitude ANALOG_NOISE severities are
# scaled from in random schedules
_BASE_SIGMA_MEMO: Dict[Tuple[int, float], float] = {}


def _design_floor_sigma_lsb(bits: int = 4, br_hz: float = 1e9) -> float:
    key = (bits, br_hz)
    sigma = _BASE_SIGMA_MEMO.get(key)
    if sigma is None:
        sigma = ph.integer_noise_sigma_lsb(ph.PhotonicParams(), bits, br_hz)
        _BASE_SIGMA_MEMO[key] = sigma
    return sigma


def integrity_severity(kind: FaultKind, u: float,
                       bits: int = 4, br_hz: float = 1e9) -> float:
    """Map one uniform draw u in [0, 1) to a kind-appropriate severity.

    ANALOG_NOISE severities are SNR-derived: 1-4x the Eq. 9/10 integer
    sigma at the design point, so an injected noise fault is "the analog
    floor got worse", not an arbitrary number.  THERMAL_DETUNE spans
    2-20% gain drift, ADC_BITFLIP 1e-4..1e-2 flip probability, STUCK_MRR
    1-3 stuck weight elements.
    """
    if kind is FaultKind.ANALOG_NOISE:
        return _design_floor_sigma_lsb(bits, br_hz) * (1.0 + 3.0 * u)
    if kind is FaultKind.THERMAL_DETUNE:
        return 0.02 + 0.18 * u
    if kind is FaultKind.ADC_BITFLIP:
        return 10.0 ** (-4.0 + 2.0 * u)
    if kind is FaultKind.STUCK_MRR:
        return float(1 + int(3.0 * u))
    raise ValueError(f"{kind} is not an integrity fault kind")


def random_schedule(seed: int, instances: Sequence[str], n_events: int = 3,
                    max_start: int = 8, max_duration: int = 4,
                    kinds: Sequence[FaultKind] = AVAILABILITY_KINDS,
                    max_severity_s: float = 0.05,
                    ) -> Tuple[FaultEvent, ...]:
    """A seeded chaos schedule: same seed -> same faults, replayable.

    Defaults to the availability-class kinds (the PR-6 domain), which
    keeps historical (seed, kinds-defaulted) schedules bit-identical.
    Pass ``kinds=INTEGRITY_KINDS`` (or a mix, or ``tuple(FaultKind)``) to
    schedule value-corrupting faults; their severities are drawn through
    ``integrity_severity`` (kind-appropriate, SNR-derived for noise)
    instead of the seconds-of-delay range.
    """
    if not instances:
        raise ValueError("need at least one instance to schedule faults on")
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(n_events):
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind in FAILING_KINDS:
            severity = 0.0
        elif kind in INTEGRITY_KINDS:
            severity = integrity_severity(kind, float(rng.uniform()))
        else:
            severity = float(rng.uniform(0.0, max_severity_s))
        events.append(FaultEvent(
            instance=instances[int(rng.integers(len(instances)))],
            kind=kind,
            start=int(rng.integers(max_start)),
            duration=int(rng.integers(1, max_duration + 1)),
            severity=severity))
    return tuple(events)
