"""Photonic fault injection + the serving stack's typed failure domain.

Real MRR accelerators fail in characteristic ways: thermal drift detunes
rings until the comb must re-lock (HEANA, arxiv 2402.03247, models the
tuning cost), a comb-switch can stick mid-reconfiguration (the switching
latencies of arxiv 2402.03149), a control host can hang or die outright.
A serving fleet has to keep producing *correct* results at degraded
throughput through all of them — which is only testable if the failures
themselves are injectable and replayable.

``FaultInjector`` is that layer: a deterministic schedule of
``FaultEvent``s keyed by each instance's *dispatch count* (not wall time),
so a chaos run replays bit-identically — the Nth shard sent to ``acc1``
always hits the same fault regardless of host speed.  The dispatcher
consults the injector once per shard dispatch (and once per quarantine
probe — a probe IS a dispatch attempt, which is how finite-duration
faults expire and instances earn readmission).

Fault modes and their serving semantics:

* ``CRASH``          — the instance is gone: the shard raises
                       ``InstanceCrashed``; permanent unless ``duration``
                       bounds it.
* ``STUCK_RECONFIG`` — the comb-switch is stuck: the shard raises
                       ``ReconfigStuck``; typically transient (the
                       controller re-locks after ``duration`` attempts).
* ``STRAGGLE``       — the host hangs: the shard sleeps ``severity``
                       seconds before executing, tripping the
                       dispatcher's per-shard deadline.
* ``THERMAL_DRIFT``  — rings drifted off resonance: every dispatch pays
                       ``severity`` seconds of re-lock/retune delay but
                       still completes correctly (degradation, not
                       failure).

The typed errors double as the public failure vocabulary of the whole
serve package (``AdmissionRejected`` is what SLO shedding raises).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.tracer import NOOP_TRACER


# ---------------------------------------------------------------------------
# typed failure domain
# ---------------------------------------------------------------------------

class ServingFault(RuntimeError):
    """Base of every typed serving failure."""


class InstanceCrashed(ServingFault):
    """A fleet instance died while (or before) executing a shard."""

    def __init__(self, instance: str):
        super().__init__(f"instance {instance!r} crashed")
        self.instance = instance


class ReconfigStuck(ServingFault):
    """The instance's comb-switch stuck mid-reconfiguration (transient)."""

    def __init__(self, instance: str):
        super().__init__(
            f"instance {instance!r}: comb-switch reconfiguration stuck")
        self.instance = instance


class ShardDeadlineExceeded(ServingFault):
    """A shard missed its per-shard deadline (straggler/hang)."""

    def __init__(self, instance: str, deadline_s: float):
        super().__init__(f"instance {instance!r} missed the "
                         f"{deadline_s * 1e3:.0f}ms shard deadline")
        self.instance = instance
        self.deadline_s = deadline_s


class NoHealthyInstances(ServingFault):
    """Every instance is quarantined/dead; the batch cannot be served."""


class RetriesExhausted(ServingFault):
    """A batch kept failing past the dispatcher's retry budget."""


class AdmissionRejected(ServingFault):
    """SLO admission control shed this request (typed, catchable).

    Raised at ``submit`` time when the surviving fleet cannot plausibly
    serve the request inside the SLO deadline; carries the estimate that
    justified the rejection so clients can back off intelligently.
    """

    def __init__(self, model: str, est_s: float, deadline_s: float,
                 healthy_fraction: float):
        super().__init__(
            f"request for {model!r} shed: estimated completion "
            f"{est_s * 1e3:.0f}ms exceeds the {deadline_s * 1e3:.0f}ms SLO "
            f"(healthy fleet fraction {healthy_fraction:.2f})")
        self.model = model
        self.est_s = est_s
        self.deadline_s = deadline_s
        self.healthy_fraction = healthy_fraction


# ---------------------------------------------------------------------------
# fault schedule
# ---------------------------------------------------------------------------

class FaultKind(enum.Enum):
    CRASH = "crash"
    STUCK_RECONFIG = "stuck_reconfig"
    STRAGGLE = "straggle"
    THERMAL_DRIFT = "thermal_drift"


#: kinds that fail the shard outright (vs merely delaying it)
FAILING_KINDS = (FaultKind.CRASH, FaultKind.STUCK_RECONFIG)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on one instance.

    Activation is by the instance's dispatch count: the fault is live for
    dispatch indices ``start <= n < start + duration`` (``duration=None``
    means forever).  ``severity`` is the injected delay in seconds for
    STRAGGLE / THERMAL_DRIFT and ignored for the failing kinds.
    """
    instance: str
    kind: FaultKind
    start: int
    duration: Optional[int] = None
    severity: float = 0.0

    def __post_init__(self) -> None:
        if self.start < 0:
            raise ValueError(f"start must be >= 0, got {self.start}")
        if self.duration is not None and self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if self.severity < 0:
            raise ValueError(f"severity must be >= 0, got {self.severity}")

    def active_at(self, n: int) -> bool:
        if n < self.start:
            return False
        return self.duration is None or n < self.start + self.duration


@dataclasses.dataclass(frozen=True)
class DispatchEffects:
    """What the injector does to one dispatch: delay, then maybe fail."""
    delay_s: float = 0.0
    fault: Optional[FaultKind] = None     # a FAILING_KINDS member, or None


class FaultInjector:
    """Deterministic, replayable fault schedule over a fleet.

    Stateful only in per-instance dispatch counters; two injectors built
    from the same schedule replay identically against the same dispatch
    sequence.  ``trips`` counts every fault activation by kind (the chaos
    harness's ground truth for "the faults actually fired").
    """

    def __init__(self, schedule: Sequence[FaultEvent] = ()):
        self.schedule: Tuple[FaultEvent, ...] = tuple(schedule)
        self.dispatches: Dict[str, int] = {}
        self.trips: Dict[str, int] = {k.value: 0 for k in FaultKind}
        # shard workers dispatch concurrently; counters must not tear
        self._lock = threading.Lock()
        #: span tracer; every fault activation becomes a ``fault.<kind>``
        #: instant on the instance's track (the dispatcher wires this)
        self.tracer = NOOP_TRACER

    def events_for(self, instance: str) -> List[FaultEvent]:
        return [e for e in self.schedule if e.instance == instance]

    def peek(self, instance: str) -> List[FaultEvent]:
        """Faults that WOULD be live for the instance's next dispatch."""
        n = self.dispatches.get(instance, 0)
        return [e for e in self.events_for(instance) if e.active_at(n)]

    def on_dispatch(self, instance: str) -> DispatchEffects:
        """Advance the instance's dispatch counter and report effects.

        Delays accumulate across simultaneously-live delay faults; a
        failing fault (crash/stuck-reconfig) wins over delays — the shard
        never executes.
        """
        fired: List[FaultEvent] = []
        with self._lock:
            n = self.dispatches.get(instance, 0)
            self.dispatches[instance] = n + 1
            delay = 0.0
            failing: Optional[FaultKind] = None
            for e in self.events_for(instance):
                if not e.active_at(n):
                    continue
                self.trips[e.kind.value] += 1
                fired.append(e)
                if e.kind in FAILING_KINDS:
                    failing = failing or e.kind
                else:
                    delay += e.severity
        for e in fired:      # outside the lock: the tracer locks its ring
            self.tracer.instant(f"fault.{e.kind.value}", cat="fault",
                                tid=instance, instance=instance,
                                dispatch_index=n, severity=e.severity)
        return DispatchEffects(delay_s=delay, fault=failing)

    @staticmethod
    def raise_for(fault: FaultKind, instance: str) -> None:
        if fault is FaultKind.CRASH:
            raise InstanceCrashed(instance)
        if fault is FaultKind.STUCK_RECONFIG:
            raise ReconfigStuck(instance)
        raise ValueError(f"{fault} is not a failing fault kind")


def random_schedule(seed: int, instances: Sequence[str], n_events: int = 3,
                    max_start: int = 8, max_duration: int = 4,
                    kinds: Sequence[FaultKind] = tuple(FaultKind),
                    max_severity_s: float = 0.05,
                    ) -> Tuple[FaultEvent, ...]:
    """A seeded chaos schedule: same seed -> same faults, replayable."""
    if not instances:
        raise ValueError("need at least one instance to schedule faults on")
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(n_events):
        kind = kinds[int(rng.integers(len(kinds)))]
        events.append(FaultEvent(
            instance=instances[int(rng.integers(len(instances)))],
            kind=kind,
            start=int(rng.integers(max_start)),
            duration=int(rng.integers(1, max_duration + 1)),
            severity=(0.0 if kind in FAILING_KINDS
                      else float(rng.uniform(0.0, max_severity_s)))))
    return tuple(events)
