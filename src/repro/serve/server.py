"""CNNServer: the serving loop tying registry + batcher + engine together.

One `step()` forms at most one batch (dynamic batcher policy), fetches the
model's resident plan (registry, LRU), stacks the requests into an NHWC
batch, runs it through the whole-model jitted pipeline
(engine.forward_jit) — the entire layer chain against the resident DKV
imprint in ONE XLA dispatch — and splits the outputs back to their
requests.  With a ``dispatcher`` (serve/dispatch.py) the batch is instead
sharded *concurrently* across the fleet's simulated accelerator
instances, bitwise-identically — surviving injected crashes, stragglers
and stuck reconfigurations via the dispatcher's retry/quarantine loop.
Wall-clock and modeled-hardware telemetry is recorded per batch — per
shard and instance operating point when sharded (telemetry.py); pipeline
compile stalls are counted per (plan, batch bucket) in
``pipeline_compiles``; fleet health and admission counters surface in
``telemetry.summary()["fleet"]``.

SLO-aware admission control (``slo=ServeSLO(...)``): every ``submit``
estimates time-to-completion from the queue depth ahead, the measured
per-frame service rate (EMA over served batches), and the *surviving*
fleet capacity; a request the degraded fleet cannot plausibly serve
inside the deadline is shed at the door with a typed
``AdmissionRejected`` instead of being queued to blow the p99.  When
quarantined instances probe back in, the capacity estimate recovers and
admission resumes — graceful degradation, then graceful recovery.

The clock is injectable (``time_fn``) so tests and trace replays can drive
a virtual clock; by default everything is wall time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import engine
from ..obs.tracer import NOOP_TRACER, Tracer
from .batcher import (BATCH, ContinuousBatcher, DynamicBatcher,
                      INTERACTIVE)
from .brownout import BrownoutController, RungTransition
from .dispatch import ShardedDispatcher
from .faults import (AdmissionRejected, BrownoutShed,
                     CorruptionBudgetExceeded, QueueOverflow,
                     RequestExpired, ServingFault)
from .registry import PlanRegistry
from ..core.operating_point import OperatingPoint
from .telemetry import DEFAULT_HW_POINTS, TelemetryLog


@dataclasses.dataclass(frozen=True)
class ServeSLO:
    """The serving contract admission control defends.

    ``deadline_s``   — target submit-to-result completion time.
    ``flush_fraction`` — force-dispatch a queue once its oldest request
                       has burned this fraction of the deadline waiting
                       (don't let batching eat the whole budget).
    ``min_observations`` — batches to observe before shedding anything
                       (the rate estimate needs data; admit until then).
    ``max_corrupted_frame_rate`` — integrity budget: the tolerated EMA of
                       detected-corrupted frames per served frame.  While
                       the fleet's corruption rate exceeds it, ``submit``
                       sheds with ``CorruptionBudgetExceeded``; the EMA
                       decays as clean batches are served, so admission
                       resumes once the datapath heals.  ``None`` (the
                       default) disables integrity shedding.
    ``corruption_halflife_s`` — the corrupted-frame-rate EMA also ages on
                       the server clock with this half-life, so integrity
                       shedding is a circuit breaker, not a latch: once
                       the corrupting instance is quarantined, admission
                       resumes even if no traffic is being served to
                       decay the rate.
    """
    deadline_s: float
    flush_fraction: float = 0.5
    min_observations: int = 1
    max_corrupted_frame_rate: Optional[float] = None
    corruption_halflife_s: float = 0.5

    def __post_init__(self) -> None:
        if self.deadline_s <= 0:
            raise ValueError(
                f"deadline_s must be > 0, got {self.deadline_s}")
        if not 0 < self.flush_fraction <= 1:
            raise ValueError(
                f"flush_fraction must be in (0, 1], got "
                f"{self.flush_fraction}")
        if (self.max_corrupted_frame_rate is not None
                and not 0 < self.max_corrupted_frame_rate <= 1):
            raise ValueError(
                f"max_corrupted_frame_rate must be in (0, 1], got "
                f"{self.max_corrupted_frame_rate}")
        if self.corruption_halflife_s <= 0:
            raise ValueError(
                f"corruption_halflife_s must be > 0, got "
                f"{self.corruption_halflife_s}")


class CNNServer:
    def __init__(self, registry: PlanRegistry, max_batch: int = 8,
                 max_wait_s: float = 0.005,
                 hw_points: Sequence[OperatingPoint] = DEFAULT_HW_POINTS,
                 interpret: Optional[bool] = None,
                 time_fn: Callable[[], float] = time.monotonic,
                 dispatcher: Optional[ShardedDispatcher] = None,
                 slo: Optional[ServeSLO] = None,
                 tracer: Optional[Tracer] = None,
                 continuous: bool = False,
                 max_queue: Optional[int] = None,
                 age_promote_s: Optional[float] = None,
                 brownout: Optional[BrownoutController] = None,
                 service_model: Optional[Callable[[str, int, OperatingPoint],
                                                  float]] = None):
        self.registry = registry
        batcher_cls = ContinuousBatcher if continuous else DynamicBatcher
        self.batcher = batcher_cls(max_batch=max_batch,
                                   max_wait_s=max_wait_s,
                                   max_queue=max_queue,
                                   age_promote_s=age_promote_s)
        self.telemetry = TelemetryLog(hw_points)
        self.interpret = interpret
        self.dispatcher = dispatcher
        self.slo = slo
        #: span tracer (obs.Tracer); defaults to the free no-op path, and
        #: is propagated to the dispatcher (and its fault injector) so
        #: request, batch, shard and fault events land in one ring
        self.tracer = tracer if tracer is not None else NOOP_TRACER
        self.batcher.metrics = self.telemetry.metrics
        if dispatcher is not None:
            # one scrape registry for the whole stack: batcher depth,
            # request latencies AND the dispatcher's SDC detection
            # latencies land in telemetry.metrics
            dispatcher.metrics = self.telemetry.metrics
        if dispatcher is not None and tracer is not None:
            dispatcher.tracer = self.tracer
        self._time = time_fn
        #: modeled service time, ``(model, batch_size, serving_point) ->
        #: seconds``; when set, the service-rate EMA, request latencies
        #: and telemetry exec_s all run in *modeled* time on the server's
        #: injectable clock — the virtual-clock determinism the overload
        #: harness replays on (wall time otherwise)
        self.service_model = service_model
        #: brownout ladder controller; observed at the top of every step,
        #: applied transitions stretch the batching window, gate
        #: batch-class admission, and downshift the operating point
        self.brownout = brownout
        self._base_max_wait_s = max_wait_s
        #: the operating point the device is currently retuned to; starts
        #: at the primary telemetry point and moves with brownout rungs
        #: (``set_operating_point``)
        self.serving_point: OperatingPoint = self.telemetry.points[0]
        self._base_point: OperatingPoint = self.serving_point
        self.results: Dict[int, np.ndarray] = {}
        #: typed per-request failures (rid -> ServingFault): expired
        #: requests land here instead of ``results``
        self.failures: Dict[int, ServingFault] = {}
        #: pipeline trace+compile stalls paid inside step() so far — one
        #: per (plan, batch-size bucket), like the registry's plan misses
        self.pipeline_compiles = 0
        #: admission-control state: shed/admitted counters + the EMA of
        #: measured per-frame service time the estimator runs on
        self.admission = {"admitted": 0, "shed": 0, "integrity_shed": 0,
                          "queue_shed": 0, "brownout_shed": 0, "expired": 0}
        self._frame_s_ema: Optional[float] = None
        self._observed_batches = 0
        #: EMA of detected-corrupted frames per served frame — the
        #: corrupted-frame-rate SLO (``slo.max_corrupted_frame_rate``)
        #: sheds against this; decays toward 0 over clean batches AND on
        #: the server clock (corruption_halflife_s), so shedding lifts
        #: after the corrupting instance is quarantined
        self._corruption_ema = 0.0
        self._corruption_t: Optional[float] = None
        if dispatcher is not None or slo is not None or brownout is not None:
            self.telemetry.attach_fleet(self._fleet_report)

    # -- fleet / admission reporting -------------------------------------

    def _fleet_report(self) -> Dict:
        """summary()["fleet"]: dispatcher health + admission counters."""
        out = (self.dispatcher.fleet_health()
               if self.dispatcher is not None else {})
        out["admission"] = dict(
            self.admission,
            slo_deadline_s=(self.slo.deadline_s if self.slo else None),
            est_frame_s=self._frame_s_ema)
        out["sdc"] = {
            "corrupted_frame_rate_ema": self._corruption_ema,
            "budget": (self.slo.max_corrupted_frame_rate
                       if self.slo else None),
        }
        if self.brownout is not None:
            out["brownout"] = self.brownout.report()
        return out

    def _now(self, now: Optional[float]) -> float:
        return self._time() if now is None else now

    def _decay_corruption(self, now: float) -> None:
        """Age the corrupted-frame-rate EMA on the server clock."""
        if self._corruption_t is not None and now > self._corruption_t:
            half = (self.slo.corruption_halflife_s
                    if self.slo is not None else 0.5)
            self._corruption_ema *= 0.5 ** (
                (now - self._corruption_t) / half)
        self._corruption_t = now

    # -- admission control ------------------------------------------------

    def _healthy_fraction(self) -> float:
        if self.dispatcher is None:
            return 1.0
        return self.dispatcher.healthy_capacity_fraction()

    def estimated_completion_s(self, priority: Optional[str] = None,
                               now: Optional[float] = None,
                               ) -> Optional[float]:
        """Expected submit-to-result time for a request arriving now.

        Queue depth ahead (plus this request) times the measured
        per-frame service time, inflated by the surviving fleet capacity
        — a 2-of-3 instance loss means a third of the throughput, three
        times the drain time.  ``None`` until enough batches have been
        observed to trust the rate.

        The depth is class-aware: an *interactive* arrival queues behind
        only the promoted backlog (selection orders promoted work first),
        so a deep batch-class backlog must not shed interactive traffic
        the priority system would in fact serve in time.  With
        ``priority`` omitted (or batch-class), the full depth counts.
        """
        if (self._frame_s_ema is None or self.slo is None
                or self._observed_batches < self.slo.min_observations):
            return None
        frac = self._healthy_fraction()
        if frac <= 0:
            return float("inf")
        if priority == INTERACTIVE:
            frames_ahead = self.batcher.pending_promoted(self._now(now)) + 1
        else:
            frames_ahead = self.batcher.pending() + 1
        return frames_ahead * self._frame_s_ema / frac

    def submit(self, model: str, x: Any,
               now: Optional[float] = None,
               priority: str = INTERACTIVE,
               deadline_s: Optional[float] = None) -> int:
        """Queue one image for ``model``; returns the request id.

        Shape is validated here, at the door: a malformed image must not
        reach a formed batch, where it would fail the whole batch's stack
        after its requests have already left the queue.  An unregistered
        model raises ``KeyError`` here too — never deep inside ``step()``
        after the request is already queued.  Under an SLO, admission
        control runs here as well: a request the surviving fleet cannot
        serve inside the deadline is shed with ``AdmissionRejected`` and
        nothing is queued.

        ``priority`` picks the class: interactive requests get
        completion-estimate admission control against ``deadline_s`` (or
        the SLO deadline); batch-class requests skip the estimate check
        unless they carry an explicit ``deadline_s`` — their backpressure
        is the bounded queue (typed ``QueueOverflow``) and, under
        brownout, door shedding (typed ``BrownoutShed``).  A request with
        ``deadline_s`` that is still queued when the deadline passes is
        cancelled by the next step's expiry sweep (typed
        ``RequestExpired`` in ``failures``).
        """
        if model not in self.registry.registered:
            raise KeyError(f"model {model!r} not registered "
                           f"(registered: {sorted(self.registry.registered)})")
        expect = self.registry.input_shape(model)
        got = np.shape(x)
        if got != expect:
            raise ValueError(f"model {model!r} expects input shape "
                             f"{expect}, got {got}")
        now = self._now(now)
        if (self.brownout is not None and priority == BATCH
                and not self.brownout.rung.admit_batch):
            self.admission["brownout_shed"] += 1
            rung = self.brownout.rung.name
            self.tracer.instant("admission.brownout_shed", cat="admission",
                                model=model, rung=rung)
            self.telemetry.metrics.counter(
                "serve_brownout_sheds_total",
                "batch-class requests shed by the brownout ladder",
                model=model).inc()
            raise BrownoutShed(model=model, rung=rung)
        if self.slo is not None and self.slo.max_corrupted_frame_rate:
            self._decay_corruption(now)
        if (self.slo is not None
                and self.slo.max_corrupted_frame_rate is not None
                and self._corruption_ema > self.slo.max_corrupted_frame_rate):
            self.admission["integrity_shed"] += 1
            self.tracer.instant(
                "admission.integrity_shed", cat="admission", model=model,
                rate=self._corruption_ema,
                budget=self.slo.max_corrupted_frame_rate)
            raise CorruptionBudgetExceeded(
                model=model, rate=self._corruption_ema,
                budget=self.slo.max_corrupted_frame_rate)
        # completion-estimate admission: always for interactive traffic,
        # for batch traffic only when it carries its own deadline (its
        # default backpressure is the queue bound, not an SLO estimate)
        checked_deadline = (deadline_s if deadline_s is not None
                            else (self.slo.deadline_s
                                  if self.slo is not None else None))
        if (checked_deadline is not None and self.slo is not None
                and (priority == INTERACTIVE or deadline_s is not None)):
            est = self.estimated_completion_s(priority=priority, now=now)
            if est is not None and est > checked_deadline:
                self.admission["shed"] += 1
                self.tracer.instant(
                    "admission.shed", cat="admission", model=model,
                    est_s=est, deadline_s=checked_deadline)
                raise AdmissionRejected(
                    model=model, est_s=est, deadline_s=checked_deadline,
                    healthy_fraction=self._healthy_fraction())
        try:
            rid = self.batcher.submit(model, x, now, priority=priority,
                                      deadline_s=deadline_s)
        except QueueOverflow:
            self.admission["queue_shed"] += 1
            self.tracer.instant("admission.queue_shed", cat="admission",
                                model=model)
            raise
        self.admission["admitted"] += 1
        self.tracer.async_begin("request", aid=rid, model=model)
        return rid

    def pending(self) -> int:
        return self.batcher.pending()

    def reset(self) -> None:
        """Drop the trace's accumulated state and release held resources.

        ``results``, ``failures`` and the telemetry records otherwise
        grow for the server's lifetime — callers running multiple traces
        against one server (or consuming results incrementally) should
        reset between traces, after harvesting what they need.  Admission
        counters are cleared with them (they are per-trace tallies), the
        dispatcher's lazily-created shard thread pool is shut down (it is
        recreated on the next sharded dispatch — no pool leaks across
        traces), and only the service-rate EMA survives: it describes the
        hardware, not the trace.
        """
        if self.batcher.pending():
            raise RuntimeError(
                f"{self.batcher.pending()} requests still queued; drain "
                f"before resetting")
        if self.dispatcher is not None:
            self.dispatcher.close()
        self.results.clear()
        self.failures.clear()
        for key in self.admission:
            self.admission[key] = 0
        self.telemetry.reset()

    # -- brownout / operating point ---------------------------------------

    def set_operating_point(self, point: OperatingPoint) -> None:
        """Retune the serving device to ``point`` (and replan if needed).

        The registry's planner recompiles resident plans against the new
        accelerator on their next fetch — bitwise-identical outputs, only
        packing geometry moves (``engine.plan_model``'s contract) — so a
        brownout downshift never changes what requesters receive.
        """
        if point == self.serving_point:
            return
        prev = self.serving_point
        self.serving_point = point
        self.registry.set_accelerator(point)
        self.telemetry.metrics.counter(
            "serve_point_switches_total",
            "serving operating-point retunes").inc()
        self.tracer.instant("serve.point_switch", cat="brownout",
                            src=prev.label, dst=point.label)

    def _apply_rung(self, tr: RungTransition) -> None:
        """Apply one ladder transition to the live serving policy."""
        rung = self.brownout.rung
        self.batcher.max_wait_s = self._base_max_wait_s * rung.max_wait_scale
        self.set_operating_point(rung.point if rung.point is not None
                                 else self._base_point)
        m = self.telemetry.metrics
        m.gauge("serve_brownout_rung",
                "current brownout ladder rung").set(self.brownout.rung_index)
        m.counter("serve_brownout_transitions_total",
                  "brownout rung transitions",
                  direction=tr.direction).inc()
        self.tracer.instant(
            "brownout.rung", cat="brownout", direction=tr.direction,
            src=self.brownout.rungs[tr.src].name, dst=rung.name,
            pressure=tr.pressure)

    def _observe_brownout(self, now: float) -> None:
        power = None
        if self.dispatcher is not None:
            health = self.dispatcher.fleet_health()
            power = health.get("admitted_power_w")
        tr = self.brownout.observe(
            now, depth=self.batcher.pending(),
            est_completion_s=self.estimated_completion_s(),
            deadline_s=(self.slo.deadline_s if self.slo is not None
                        else None),
            power_w=power)
        if tr is not None:
            self._apply_rung(tr)

    def _sweep_expired(self, now: float) -> None:
        """Cancel queued requests whose deadline passed (typed failures)."""
        for req in self.batcher.expire(now):
            fault = RequestExpired(
                model=req.model, rid=req.rid,
                deadline_s=req.deadline - req.t_submit,
                waited_s=now - req.t_submit)
            self.failures[req.rid] = fault
            self.admission["expired"] += 1
            self.telemetry.metrics.counter(
                "serve_requests_expired_total",
                "queued requests cancelled at their deadline",
                model=req.model).inc()
            self.tracer.async_end("request", aid=req.rid, model=req.model,
                                  expired=True)
            self.tracer.instant("request.expired", cat="admission",
                                model=req.model, rid=req.rid,
                                waited_s=fault.waited_s)

    def _slo_flush_due(self, now: float) -> bool:
        """Dispatch early once queue wait eats into the SLO deadline."""
        if self.slo is None:
            return False
        oldest = self.batcher.oldest_wait_s(now)
        return (oldest is not None
                and oldest >= self.slo.flush_fraction * self.slo.deadline_s)

    def step(self, now: Optional[float] = None, force: bool = False) -> int:
        """Serve at most one batch; returns the number of requests served.

        The batch runs through the whole-model jitted pipeline
        (``engine.forward_jit``): one XLA dispatch for the entire layer
        chain, batch size bucketed to the next power of two.  The recorded
        per-batch ``exec_s`` is full service time: plan fetch (a registry
        miss pays compile/LRU-reload here, where the requester actually
        waits), batch stacking, kernel execution — including any fault
        retries/re-apportionment when dispatched across a fleet — and,
        for the first batch in a (plan, bucket), the pipeline
        trace+compile stall, which ``pipeline_compiles`` counts.  Request
        latencies are taken on the server's own clock (``time_fn``), so a
        virtual-clock replay stays in one unit system; on the default
        wall clock they include the compile stall too.
        """
        now = self._now(now)
        self._sweep_expired(now)
        if self.brownout is not None:
            self._observe_brownout(now)
        fb = self.batcher.pop_batch(now,
                                    force=force or self._slo_flush_due(now))
        if fb is None:
            return 0
        tr = self.tracer
        with tr.span("batch", cat="batch", model=fb.model, size=fb.size,
                     bucket=engine.batch_bucket(fb.size)) as bsp:
            t0 = time.perf_counter()
            with tr.span("plan.fetch", cat="batch", model=fb.model):
                entry = self.registry.get(fb.model)
            with tr.span("stack", cat="batch"):
                xb = jnp.stack([jnp.asarray(r.x, jnp.float32)
                                for r in fb.requests])
            compiles_before = engine.pipeline_cache_info()["compiles"]
            sdc_before = (self.dispatcher.counters["sdc_detections"]
                          if self.dispatcher is not None else 0)
            shard_info = ()
            with tr.span("exec", cat="batch", model=fb.model):
                if self.dispatcher is None:
                    out = engine.forward_jit(entry.plan, xb,
                                             interpret=self.interpret)
                    out = jax.block_until_ready(out)
                else:
                    # shard the batch across the fleet; outputs keep
                    # request order (sim_specs lets a hardware-paced fleet
                    # floor each shard at its instance's modeled device
                    # time)
                    out, runs = self.dispatcher.run(
                        entry.plan, xb, interpret=self.interpret,
                        sim_specs=entry.sim_specs)
                    shard_info = [(r.instance.name, r.batch_size,
                                   r.instance.hw, r.exec_s) for r in runs]
            compiled = (engine.pipeline_cache_info()["compiles"]
                        - compiles_before)
            self.pipeline_compiles += compiled
            if self.service_model is not None:
                # modeled service time on the injectable clock: the EMA,
                # latencies and telemetry all stay in one (virtual) unit
                # system, deterministic across hosts
                exec_s = self.service_model(fb.model, fb.size,
                                            self.serving_point)
            else:
                exec_s = time.perf_counter() - t0
            # service-rate EMA feeds admission control; fault retries
            # inflate exec_s, which is exactly the backpressure the
            # estimator needs
            per_frame = exec_s / fb.size
            self._frame_s_ema = (per_frame if self._frame_s_ema is None
                                 else 0.3 * per_frame
                                 + 0.7 * self._frame_s_ema)
            self._observed_batches += 1
            # corrupted-frame-rate EMA: detections this batch (integrity
            # checks flagged a shard; it was re-executed bitwise-clean)
            # attributed to the batch's frames pro-rata by shard count.
            # Clean batches decay the EMA, so integrity shedding lifts
            # once the datapath heals.
            detections = ((self.dispatcher.counters["sdc_detections"]
                           - sdc_before)
                          if self.dispatcher is not None else 0)
            corrupted_frames = 0
            if detections:
                shards = max(1, len(shard_info))
                corrupted_frames = min(
                    fb.size,
                    int(np.ceil(detections * fb.size / shards)))
            done = (now + exec_s if self.service_model is not None
                    else self._now(None))
            self._decay_corruption(done)
            rate = corrupted_frames / fb.size
            self._corruption_ema = (0.3 * rate
                                    + 0.7 * self._corruption_ema)
            if detections:
                self.telemetry.record_sdc(fb.model, detections,
                                          corrupted_frames)
            with tr.span("epilogue", cat="batch"):
                out_np = np.asarray(out)
                lats = []
                for i, req in enumerate(fb.requests):
                    self.results[req.rid] = out_np[i]
                    lat = done - req.t_submit
                    lats.append(lat)
                    tr.async_end("request", aid=req.rid, model=fb.model,
                                 latency_s=lat)
                self.telemetry.record_batch(
                    model=fb.model, sim_specs=entry.sim_specs,
                    batch_size=fb.size, t_formed=now, exec_s=exec_s,
                    queue_waits_s=fb.queue_waits(), latencies_s=lats,
                    shards=shard_info, exec_specs=entry.exec_specs,
                    op_points=entry.plan.layer_points,
                    reconfig_switches=entry.plan.reconfig_switches,
                    priorities=fb.priorities())
            bsp.set(compiles=compiled, exec_s=exec_s)
            if self.dispatcher is None:
                # unsharded: the whole batch's modeled device time lands
                # on one "local" hardware track (sharded batches annotate
                # per-shard hardware time in the dispatcher instead)
                primary = self.telemetry.points[0]
                cost = self.telemetry._hw_cost(
                    fb.model, entry.sim_specs, fb.size, primary)
                bsp.hw("local", cost.frame_latency_s * fb.size)
        return fb.size

    def run_until_drained(self, max_steps: int = 100_000,
                          ) -> Dict[int, np.ndarray]:
        """Serve everything queued (force-flushing ragged final batches).

        Returns ``self.results`` — the server's *cumulative* rid->output
        map, including requests served before this call; use ``reset()``
        between traces for per-trace results.
        """
        for _ in range(max_steps):
            if self.step(force=True) == 0 and self.batcher.pending() == 0:
                break
        return self.results
