"""CNNServer: the serving loop tying registry + batcher + engine together.

One `step()` forms at most one batch (dynamic batcher policy), fetches the
model's resident plan (registry, LRU), stacks the requests into an NHWC
batch, runs it through the whole-model jitted pipeline
(engine.forward_jit) — the entire layer chain against the resident DKV
imprint in ONE XLA dispatch — and splits the outputs back to their
requests.  With a ``dispatcher`` (serve/dispatch.py) the batch is instead
sharded across the fleet's simulated accelerator instances,
bitwise-identically.  Wall-clock and modeled-hardware telemetry is
recorded per batch — per shard and instance operating point when sharded
(telemetry.py); pipeline compile stalls are counted per
(plan, batch bucket) in ``pipeline_compiles``.

The clock is injectable (``time_fn``) so tests and trace replays can drive
a virtual clock; by default everything is wall time.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import engine
from .batcher import DynamicBatcher
from .dispatch import ShardedDispatcher
from .registry import PlanRegistry
from .telemetry import DEFAULT_HW_POINTS, HardwarePoint, TelemetryLog


class CNNServer:
    def __init__(self, registry: PlanRegistry, max_batch: int = 8,
                 max_wait_s: float = 0.005,
                 hw_points: Sequence[HardwarePoint] = DEFAULT_HW_POINTS,
                 interpret: Optional[bool] = None,
                 time_fn: Callable[[], float] = time.monotonic,
                 dispatcher: Optional[ShardedDispatcher] = None):
        self.registry = registry
        self.batcher = DynamicBatcher(max_batch=max_batch,
                                      max_wait_s=max_wait_s)
        self.telemetry = TelemetryLog(hw_points)
        self.interpret = interpret
        self.dispatcher = dispatcher
        self._time = time_fn
        self.results: Dict[int, np.ndarray] = {}
        #: pipeline trace+compile stalls paid inside step() so far — one
        #: per (plan, batch-size bucket), like the registry's plan misses
        self.pipeline_compiles = 0

    def _now(self, now: Optional[float]) -> float:
        return self._time() if now is None else now

    def submit(self, model: str, x: Any,
               now: Optional[float] = None) -> int:
        """Queue one image for ``model``; returns the request id.

        Shape is validated here, at the door: a malformed image must not
        reach a formed batch, where it would fail the whole batch's stack
        after its requests have already left the queue.
        """
        if model not in self.registry.registered:
            raise KeyError(f"model {model!r} not registered "
                           f"(registered: {sorted(self.registry.registered)})")
        expect = self.registry.input_shape(model)
        got = np.shape(x)
        if got != expect:
            raise ValueError(f"model {model!r} expects input shape "
                             f"{expect}, got {got}")
        return self.batcher.submit(model, x, self._now(now))

    def pending(self) -> int:
        return self.batcher.pending()

    def reset(self) -> None:
        """Drop accumulated results and telemetry (start a fresh trace).

        ``results`` and the telemetry records otherwise grow for the
        server's lifetime — callers running multiple traces against one
        server (or consuming results incrementally) should reset between
        traces, after harvesting what they need.
        """
        if self.batcher.pending():
            raise RuntimeError(
                f"{self.batcher.pending()} requests still queued; drain "
                f"before resetting")
        self.results.clear()
        self.telemetry.records.clear()

    def step(self, now: Optional[float] = None, force: bool = False) -> int:
        """Serve at most one batch; returns the number of requests served.

        The batch runs through the whole-model jitted pipeline
        (``engine.forward_jit``): one XLA dispatch for the entire layer
        chain, batch size bucketed to the next power of two.  The recorded
        per-batch ``exec_s`` is full service time: plan fetch (a registry
        miss pays compile/LRU-reload here, where the requester actually
        waits), batch stacking, kernel execution, and — for the first
        batch in a (plan, bucket) — the pipeline trace+compile stall,
        which ``pipeline_compiles`` counts.  Request latencies are taken
        on the server's own clock (``time_fn``), so a virtual-clock replay
        stays in one unit system; on the default wall clock they include
        the compile stall too.
        """
        now = self._now(now)
        fb = self.batcher.pop_batch(now, force=force)
        if fb is None:
            return 0
        t0 = time.perf_counter()
        entry = self.registry.get(fb.model)
        xb = jnp.stack([jnp.asarray(r.x, jnp.float32) for r in fb.requests])
        compiles_before = engine.pipeline_cache_info()["compiles"]
        shard_info = ()
        if self.dispatcher is None:
            out = engine.forward_jit(entry.plan, xb,
                                     interpret=self.interpret)
            out = jax.block_until_ready(out)
        else:
            # shard the batch across the fleet; outputs keep request order
            out, runs = self.dispatcher.run(entry.plan, xb,
                                            interpret=self.interpret)
            shard_info = [(r.instance.name, r.batch_size, r.instance.hw,
                           r.exec_s) for r in runs]
        self.pipeline_compiles += (engine.pipeline_cache_info()["compiles"]
                                   - compiles_before)
        exec_s = time.perf_counter() - t0
        done = self._now(None)
        out_np = np.asarray(out)
        lats = []
        for i, req in enumerate(fb.requests):
            self.results[req.rid] = out_np[i]
            lats.append(done - req.t_submit)
        self.telemetry.record_batch(
            model=fb.model, sim_specs=entry.sim_specs, batch_size=fb.size,
            t_formed=now, exec_s=exec_s, queue_waits_s=fb.queue_waits(),
            latencies_s=lats, shards=shard_info,
            exec_specs=entry.exec_specs)
        return fb.size

    def run_until_drained(self, max_steps: int = 100_000,
                          ) -> Dict[int, np.ndarray]:
        """Serve everything queued (force-flushing ragged final batches).

        Returns ``self.results`` — the server's *cumulative* rid->output
        map, including requests served before this call; use ``reset()``
        between traces for per-trace results.
        """
        for _ in range(max_steps):
            if self.step(force=True) == 0 and self.batcher.pending() == 0:
                break
        return self.results
