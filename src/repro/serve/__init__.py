"""CNN inference serving runtime on the weight-stationary engine.

The paper's economics are throughput economics: one DKV imprint amortized
over a stream of frames (Section VI-A), evaluated as sustained FPS and
FPS/W (Figs. 10-11).  This package is the request-serving subsystem that
realizes that stream:

* registry.py  — multi-model plan registry: compile-once ModelPlans with
                 LRU eviction and per-model weight factories
* batcher.py   — dynamic + continuous batchers: per-model queues,
                 max-batch + max-wait admission, two priority classes
                 with starvation-free aging, bounded queues, per-request
                 deadlines, mixed-model round-robin dispatch
* brownout.py  — hysteretic overload ladder: stretch the batching window,
                 shed the batch class, downshift the comb-switch
                 operating point (planner replan, bitwise) — then recover
                 rung-by-rung with cooldown
* server.py    — CNNServer: forms batches, runs them through the batched
                 engine forward (engine/executor.py), splits results;
                 SLO-aware admission control sheds load the surviving
                 fleet cannot serve inside the deadline (ServeSLO)
* dispatch.py  — concurrent multi-accelerator sharded dispatch: batches
                 split across K simulated accelerator instances (possibly
                 heterogeneous operating points) on a thread pool with
                 per-shard deadlines, retry/backoff re-apportionment and
                 quarantine/probe health — bitwise-equal to
                 single-accelerator no matter which instances ran; with
                 an IntegrityConfig, every shard's integer accumulators
                 are ABFT/range/weight-checksum verified and flagged
                 shards re-execute bitwise-identically on healthy
                 instances (SDC defense)
* faults.py    — photonic fault injection on deterministic seeded
                 schedules: availability-class faults (crash, straggle,
                 thermal drift, stuck reconfiguration) AND
                 integrity-class value corruption (analog noise, thermal
                 detune, stuck MRR weights, ADC bit flips), plus the
                 typed serving-failure vocabulary
* telemetry.py — hardware-time telemetry: every served batch is also
                 costed through core/simulator.simulate, so the server
                 reports wall-clock images/s AND modeled photonic FPS and
                 FPS/W per accelerator operating point — plus fleet
                 health/retry/shed counters when dispatched
* models.py    — serving model zoo: executable mini variants of the paper
                 CNNs plus their paper-scale simulator layer tables

Closed-loop benchmark: benchmarks/serve_bench.py.  Chaos harness
(fault-injection scenarios, §fault_tolerance of BENCH_serve.json):
benchmarks/chaos_bench.py.
"""
from .batcher import (BATCH, ContinuousBatcher, DynamicBatcher,  # noqa: F401
                      FormedBatch, INTERACTIVE, PRIORITIES, Request)
from .brownout import (BrownoutController, BrownoutRung,  # noqa: F401
                       DEFAULT_LADDER, RungTransition)
from .dispatch import (AcceleratorInstance, InstanceHealth,  # noqa: F401
                       IntegrityConfig, ShardedDispatcher, ShardRun,
                       default_fleet)
from .faults import (AVAILABILITY_KINDS, AdmissionRejected,  # noqa: F401
                     BrownoutShed, CorruptionBudgetExceeded, CorruptionSpec,
                     DispatchEffects, FaultEvent, FaultInjector, FaultKind,
                     INTEGRITY_KINDS, InstanceCrashed, NoHealthyInstances,
                     OutputCorrupted, QueueOverflow, ReconfigStuck,
                     RequestExpired, RetriesExhausted, ServingFault,
                     ShardDeadlineExceeded, random_schedule)
from .models import (SERVING_MODELS, serving_defs,  # noqa: F401
                     serving_input_shape, specs_for_defs)
from .registry import PlanRegistry, ServingModel, paper_cnn_registry  # noqa: F401
from .server import CNNServer, ServeSLO  # noqa: F401
from ..core.operating_point import OperatingPoint  # noqa: F401
from .telemetry import (DEFAULT_HW_POINTS, BatchRecord,  # noqa: F401
                        HardwarePoint, ShardCost, TelemetryLog)
