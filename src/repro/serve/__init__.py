"""CNN inference serving runtime on the weight-stationary engine.

The paper's economics are throughput economics: one DKV imprint amortized
over a stream of frames (Section VI-A), evaluated as sustained FPS and
FPS/W (Figs. 10-11).  This package is the request-serving subsystem that
realizes that stream:

* registry.py  — multi-model plan registry: compile-once ModelPlans with
                 LRU eviction and per-model weight factories
* batcher.py   — dynamic batcher: per-model queues, max-batch + max-wait
                 admission, mixed-model round-robin dispatch
* server.py    — CNNServer: forms batches, runs them through the batched
                 engine forward (engine/executor.py), splits results
* dispatch.py  — multi-accelerator sharded dispatch: batches split across
                 K simulated accelerator instances (possibly heterogeneous
                 operating points), bitwise-equal to single-accelerator
* telemetry.py — hardware-time telemetry: every served batch is also
                 costed through core/simulator.simulate, so the server
                 reports wall-clock images/s AND modeled photonic FPS and
                 FPS/W per accelerator operating point
* models.py    — serving model zoo: executable mini variants of the paper
                 CNNs plus their paper-scale simulator layer tables

Closed-loop benchmark: benchmarks/serve_bench.py.
"""
from .batcher import DynamicBatcher, FormedBatch, Request  # noqa: F401
from .dispatch import (AcceleratorInstance, ShardedDispatcher,  # noqa: F401
                       ShardRun, default_fleet)
from .models import (SERVING_MODELS, serving_defs,  # noqa: F401
                     serving_input_shape, specs_for_defs)
from .registry import PlanRegistry, ServingModel, paper_cnn_registry  # noqa: F401
from .server import CNNServer  # noqa: F401
from .telemetry import (DEFAULT_HW_POINTS, BatchRecord,  # noqa: F401
                        HardwarePoint, ShardCost, TelemetryLog)
