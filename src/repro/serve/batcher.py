"""Dynamic batcher: per-model queues, max-batch/max-wait, round-robin.

Requests for the same model queue together (a batch must share one DKV
imprint); a queue becomes dispatchable when it can fill ``max_batch``
frames or its oldest request has waited ``max_wait_s`` — the standard
latency/throughput knob of serving batchers.  Across models, dispatch is
round-robin over dispatchable queues so one hot model cannot starve the
others' imprints.

Fairness is *deterministic by construction*: the rotation order is the
explicit ``_rr`` list (models in first-submission order), never an
iteration over the queue dict — so the pop order of a given submit trace
is reproducible regardless of dict-ordering behavior across Python
versions/implementations, and two models submitting interleaved traffic
alternate batches exactly (regression-tested in tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    model: str
    x: Any                  # (H, W, D) input image
    t_submit: float


@dataclasses.dataclass(frozen=True)
class FormedBatch:
    model: str
    requests: tuple          # Tuple[Request, ...]
    t_formed: float

    @property
    def size(self) -> int:
        return len(self.requests)

    def queue_waits(self) -> List[float]:
        return [self.t_formed - r.t_submit for r in self.requests]


class DynamicBatcher:
    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.005):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queues: Dict[str, Deque[Request]] = {}
        self._rr: List[str] = []     # model rotation, first-submission order
        self._rr_next = 0
        self._next_rid = 0
        #: optional obs.MetricsRegistry; when set (the server wires its
        #: telemetry registry in), the batcher keeps a queue-depth gauge
        #: and a batches-formed counter current
        self.metrics = None

    def submit(self, model: str, x: Any, now: float) -> int:
        rid = self._next_rid
        self._next_rid += 1
        if model not in self._queues:
            self._queues[model] = deque()
            self._rr.append(model)
        self._queues[model].append(Request(rid, model, x, now))
        if self.metrics is not None:
            self.metrics.gauge("serve_queue_depth",
                               "queued requests").set(self.pending())
        return rid

    @property
    def rotation(self) -> List[str]:
        """The deterministic round-robin order (first-submission order)."""
        return list(self._rr)

    def pending(self, model: Optional[str] = None) -> int:
        if model is not None:
            return len(self._queues.get(model, ()))
        return sum(len(self._queues[m]) for m in self._rr)

    def oldest_wait_s(self, now: float,
                      model: Optional[str] = None) -> Optional[float]:
        """How long the oldest queued request has waited (None if empty).

        The SLO flush signal: a server defending a completion deadline
        dispatches a queue early once its head request has burned a
        fraction of the budget waiting for batch-mates.
        """
        heads = [self._queues[m][0].t_submit
                 for m in ([model] if model is not None else self._rr)
                 if self._queues.get(m)]
        if not heads:
            return None
        return now - min(heads)

    def _dispatchable(self, model: str, now: float, force: bool) -> bool:
        q = self._queues[model]
        if not q:
            return False
        return (force or len(q) >= self.max_batch
                or now - q[0].t_submit >= self.max_wait_s)

    def pop_batch(self, now: float, force: bool = False,
                  ) -> Optional[FormedBatch]:
        """Form the next batch, or None if no queue is dispatchable.

        ``force`` admits any non-empty queue regardless of fill/wait —
        the drain path at end of trace (ragged final batches).

        Candidates are scanned in rotation order starting after the last
        dispatched model (``_rr``/``_rr_next`` — never the queue dict's
        iteration order), so ties between simultaneously dispatchable
        models resolve identically on every Python implementation.
        """
        n = len(self._rr)
        for i in range(n):
            model = self._rr[(self._rr_next + i) % n]
            if not self._dispatchable(model, now, force):
                continue
            q = self._queues[model]
            reqs = tuple(q.popleft()
                         for _ in range(min(self.max_batch, len(q))))
            self._rr_next = (self._rr_next + i + 1) % n
            if self.metrics is not None:
                self.metrics.counter("serve_batches_formed_total",
                                     "batches formed", model=model).inc()
                self.metrics.gauge("serve_queue_depth",
                                   "queued requests").set(self.pending())
            return FormedBatch(model=model, requests=reqs, t_formed=now)
        return None
