"""Dynamic + continuous batchers: per-model queues, priorities, deadlines.

Requests for the same model queue together (a batch must share one DKV
imprint); a queue becomes dispatchable when it can fill ``max_batch``
frames or its oldest request has waited ``max_wait_s`` — the standard
latency/throughput knob of serving batchers.  Across models, dispatch is
round-robin over dispatchable queues so one hot model cannot starve the
others' imprints.

Overload semantics (PR 10) layer on top of that base policy:

* two priority classes — ``INTERACTIVE`` requests are latency-sensitive,
  ``BATCH`` requests are throughput traffic that may wait (and, under
  brownout, be shed first).  Within a formed batch, promoted requests are
  selected before un-promoted ones, oldest first.
* starvation-free aging: a batch-class request older than
  ``age_promote_s`` is *promoted* — it competes as interactive from then
  on, so a steady interactive stream cannot starve the batch tier
  forever.
* bounded queues: with ``max_queue`` set, a full per-model queue rejects
  further submits with the typed :class:`~repro.serve.faults.QueueOverflow`
  — the hard backpressure bound that keeps drain time finite under
  overload.
* per-request deadlines: a request carrying an absolute ``deadline`` is
  *dead* once the clock passes it — the ``expire()`` sweep removes dead
  requests (the server turns them into typed ``RequestExpired``
  failures), and no dead request is ever counted toward dispatchability
  or selected into a batch.

The flush-deadline signal (``oldest_wait_s``) is computed over the *live*
requests only — never ``q[0]`` blindly.  An expired-but-unswept head must
not drive SLO flushes or max-wait dispatch: the queue head can be dead
while younger live requests behind it are nowhere near their budget, and
a head-only peek would either force-flush forever on a corpse or batch it
into a dispatch (regression-tested with a virtual clock in
tests/test_overload.py).

:class:`ContinuousBatcher` keeps the same queues but is *work-conserving*
for the interactive class: any live promoted request makes its queue
dispatchable immediately — no max-wait stall — while batch-class traffic
still aggregates toward full power-of-two buckets.  Formed batches of any
size reuse ``engine/pipeline.py``'s per-bucket compiled dispatches
(``batch_bucket`` rounds up to the next power of two), so continuous
ragged fills never pay a fresh XLA compile after warmup.

Fairness is *deterministic by construction*: the rotation order is the
explicit ``_rr`` list (models in first-submission order), never an
iteration over the queue dict — so the pop order of a given submit trace
is reproducible regardless of dict-ordering behavior across Python
versions/implementations, and two models submitting interleaved traffic
alternate batches exactly (regression-tested in tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from .faults import QueueOverflow

#: latency-sensitive traffic: admission defends the SLO deadline, the
#: continuous batcher dispatches it work-conservingly
INTERACTIVE = "interactive"
#: throughput traffic: waits for batch fill, shed first under brownout
BATCH = "batch"
PRIORITIES = (INTERACTIVE, BATCH)


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    model: str
    x: Any                  # (H, W, D) input image
    t_submit: float
    priority: str = INTERACTIVE
    #: absolute expiry on the server clock (None = never expires)
    deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class FormedBatch:
    model: str
    requests: tuple          # Tuple[Request, ...]
    t_formed: float

    @property
    def size(self) -> int:
        return len(self.requests)

    def queue_waits(self) -> List[float]:
        return [self.t_formed - r.t_submit for r in self.requests]

    def priorities(self) -> List[str]:
        return [r.priority for r in self.requests]


class DynamicBatcher:
    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.005,
                 max_queue: Optional[int] = None,
                 age_promote_s: Optional[float] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if age_promote_s is not None and age_promote_s < 0:
            raise ValueError(
                f"age_promote_s must be >= 0, got {age_promote_s}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_queue = max_queue
        self.age_promote_s = age_promote_s
        self._queues: Dict[str, Deque[Request]] = {}
        self._rr: List[str] = []     # model rotation, first-submission order
        self._rr_next = 0
        self._next_rid = 0
        #: optional obs.MetricsRegistry; when set (the server wires its
        #: telemetry registry in), the batcher keeps a queue-depth gauge
        #: and a batches-formed counter current
        self.metrics = None

    def submit(self, model: str, x: Any, now: float,
               priority: str = INTERACTIVE,
               deadline_s: Optional[float] = None) -> int:
        if priority not in PRIORITIES:
            raise ValueError(
                f"priority must be one of {PRIORITIES}, got {priority!r}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        if model not in self._queues:
            self._queues[model] = deque()
            self._rr.append(model)
        q = self._queues[model]
        if self.max_queue is not None and len(q) >= self.max_queue:
            raise QueueOverflow(model=model, depth=len(q),
                                max_queue=self.max_queue)
        rid = self._next_rid
        self._next_rid += 1
        q.append(Request(rid, model, x, now, priority,
                         None if deadline_s is None else now + deadline_s))
        if self.metrics is not None:
            self.metrics.gauge("serve_queue_depth",
                               "queued requests").set(self.pending())
        return rid

    @property
    def rotation(self) -> List[str]:
        """The deterministic round-robin order (first-submission order)."""
        return list(self._rr)

    def pending(self, model: Optional[str] = None) -> int:
        if model is not None:
            return len(self._queues.get(model, ()))
        return sum(len(self._queues[m]) for m in self._rr)

    def pending_promoted(self, now: float) -> int:
        """Live requests with interactive precedence (class or aging).

        The backlog an arriving *interactive* request actually queues
        behind: selection orders promoted work first, so unpromoted
        batch-class requests behind it do not delay it.  This is the
        depth the server's class-aware admission estimate uses.
        """
        return sum(1 for m in self._rr for r in self._queues[m]
                   if self._live(r, now) and self._promoted(r, now))

    @staticmethod
    def _live(r: Request, now: float) -> bool:
        return r.deadline is None or now < r.deadline

    def _promoted(self, r: Request, now: float) -> bool:
        """Interactive precedence: its class, or aged past promotion."""
        return (r.priority == INTERACTIVE
                or (self.age_promote_s is not None
                    and now - r.t_submit >= self.age_promote_s))

    def expire(self, now: float) -> List[Request]:
        """Sweep dead requests (deadline passed) out of every queue.

        Returns the expired requests in rotation-then-submission order so
        the server can fail each with a typed ``RequestExpired``.  The
        sweep — not a head peek — is what keeps the flush-deadline and
        dispatchability signals honest after cancellations.
        """
        expired: List[Request] = []
        for m in self._rr:
            q = self._queues[m]
            if not q:
                continue
            keep: Deque[Request] = deque()
            for r in q:
                (keep if self._live(r, now) else expired).append(r)
            if len(keep) != len(q):
                self._queues[m] = keep
        if expired and self.metrics is not None:
            self.metrics.gauge("serve_queue_depth",
                               "queued requests").set(self.pending())
        return expired

    def oldest_wait_s(self, now: float,
                      model: Optional[str] = None) -> Optional[float]:
        """How long the oldest *live* queued request has waited.

        The SLO flush signal: a server defending a completion deadline
        dispatches a queue early once its oldest request has burned a
        fraction of the budget waiting for batch-mates.  Recomputed over
        the live requests — an expired head (cancelled work) must not
        keep forcing flushes, and ``None`` means nothing live is queued.
        """
        oldest: Optional[float] = None
        for m in ([model] if model is not None else self._rr):
            for r in self._queues.get(m, ()):
                if self._live(r, now) and (oldest is None
                                           or r.t_submit < oldest):
                    oldest = r.t_submit
        if oldest is None:
            return None
        return now - oldest

    def _dispatchable(self, model: str, now: float, force: bool) -> bool:
        live = [r for r in self._queues[model] if self._live(r, now)]
        if not live:
            return False
        if force or len(live) >= self.max_batch:
            return True
        oldest = min(r.t_submit for r in live)
        return now - oldest >= self.max_wait_s

    def _select(self, model: str, now: float) -> tuple:
        """Pick (up to max_batch) live requests: promoted first, oldest
        first — and rebuild the queue without them (order preserved)."""
        q = self._queues[model]
        live = [r for r in q if self._live(r, now)]
        ranked = sorted(live, key=lambda r: (0 if self._promoted(r, now)
                                             else 1, r.t_submit, r.rid))
        take = ranked[:min(self.max_batch, len(ranked))]
        taken = {r.rid for r in take}
        self._queues[model] = deque(r for r in q if r.rid not in taken)
        # stack order within the batch is submission order — deterministic
        # and independent of promotion timing
        return tuple(sorted(take, key=lambda r: (r.t_submit, r.rid)))

    def pop_batch(self, now: float, force: bool = False,
                  ) -> Optional[FormedBatch]:
        """Form the next batch, or None if no queue is dispatchable.

        ``force`` admits any queue with live requests regardless of
        fill/wait — the drain path at end of trace (ragged final
        batches).  Dead (expired) requests are never selected; sweep them
        with ``expire()`` to fail them explicitly.

        Candidates are scanned in rotation order starting after the last
        dispatched model (``_rr``/``_rr_next`` — never the queue dict's
        iteration order), so ties between simultaneously dispatchable
        models resolve identically on every Python implementation.
        """
        n = len(self._rr)
        for i in range(n):
            model = self._rr[(self._rr_next + i) % n]
            if not self._dispatchable(model, now, force):
                continue
            reqs = self._select(model, now)
            self._rr_next = (self._rr_next + i + 1) % n
            if self.metrics is not None:
                self.metrics.counter("serve_batches_formed_total",
                                     "batches formed", model=model).inc()
                self.metrics.gauge("serve_queue_depth",
                                   "queued requests").set(self.pending())
            return FormedBatch(model=model, requests=reqs, t_formed=now)
        return None


class ContinuousBatcher(DynamicBatcher):
    """Work-conserving for the interactive class, aggregating for batch.

    A queue holding any live *promoted* request (interactive class, or
    batch-class aged past ``age_promote_s``) is dispatchable immediately
    — interactive work never stalls behind the max-wait timer waiting for
    batch-mates.  Batch-class-only queues keep the base policy (fill
    ``max_batch`` or wait ``max_wait_s``), aggregating toward full
    power-of-two buckets so throughput traffic still amortizes its
    dispatches.  Whatever ragged size forms, the pipeline's bucketed
    compile cache serves it without a new trace.
    """

    def _dispatchable(self, model: str, now: float, force: bool) -> bool:
        live = [r for r in self._queues[model] if self._live(r, now)]
        if not live:
            return False
        if force or len(live) >= self.max_batch:
            return True
        if any(self._promoted(r, now) for r in live):
            return True
        oldest = min(r.t_submit for r in live)
        return now - oldest >= self.max_wait_s
