"""Concurrent multi-accelerator dispatch with deadlines, retries, quarantine.

A deployment that outgrows one photonic accelerator scales out: K
accelerator instances (possibly heterogeneous operating points — e.g. an
RMAM@1G next to an RMAM@5G) serve shards of every formed batch in
parallel, each against its own resident copy of the model's DKV imprint.
``ShardedDispatcher`` models that fleet end to end, failure handling
included:

* the batch is split contiguously into per-instance shards sized by each
  *healthy* instance's ``capacity`` weight (largest-remainder
  apportionment, so shard sizes are deterministic and sum to the batch);
* shards execute **concurrently** on a thread pool (the XLA runtime
  releases the GIL during execution), each watched by a per-shard
  ``deadline_s``;
* a shard that crashes, sticks, or misses its deadline quarantines its
  instance and is **retried with exponential backoff**, re-apportioned
  across the surviving healthy instances with the same largest-remainder
  split — per-image quantization makes every image's output independent
  of which instance ran it, so the concatenated outputs stay
  bitwise-identical to the healthy single-accelerator run no matter how
  the work was re-dealt (asserted in tests, ragged batches and chaos
  schedules included);
* quarantined instances are **probed back in** after a cooldown (each
  probe consults the fault injector — a finite fault expires, the
  instance readmits; a re-failed probe doubles the cooldown);
* with an ``IntegrityConfig``, shards execute through the engine's
  *guarded* path: injected value corruption (integrity-class FaultKinds)
  lands on the int32 accumulators, ABFT/range/weight-checksum detectors
  verify them, and a detection raises ``OutputCorrupted`` — handled by
  the same quarantine + re-execution machinery, so recovered outputs are
  *bitwise-identical* to the fault-free run; per-instance canary probes
  (golden-frame bitwise compare) back the detectors up at any cadence;
* ``HeartbeatMonitor`` / ``StragglerDetector`` (runtime/fault_tolerance)
  watch the fleet from the serve loop's own clock, and ``fleet_health()``
  exports per-instance state plus retry/timeout/quarantine counters for
  ``TelemetryLog.summary()["fleet"]``.

Device pacing (``pace="hardware"``): each shard's service time is floored
at the cycle-true simulator's modeled time for that shard at the
instance's operating point — the host merely *feeds* simulated
accelerators, so fleet throughput scales with fleet size exactly as K
real devices would, instead of being an artifact of host-side XLA
scheduling (on a small host, K concurrent XLA calls cannot beat one —
the compute is the same; K photonic accelerators genuinely overlap).
Raw (unpaced) mode remains the default for bit-exactness tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import engine
from ..cnn.layers import LayerSpec
from ..core import simulator as sim
from ..core.operating_point import OperatingPoint
from ..obs.tracer import NOOP_TRACER
from ..runtime.fault_tolerance import HeartbeatMonitor, StragglerDetector
from .faults import (CorruptionSpec, FaultInjector, NoHealthyInstances,
                     OutputCorrupted, RetriesExhausted, ServingFault,
                     ShardDeadlineExceeded)
from .telemetry import HardwarePoint  # noqa: F401  (backcompat re-export)


@dataclasses.dataclass(frozen=True)
class AcceleratorInstance:
    """One simulated accelerator in the fleet."""
    name: str
    hw: OperatingPoint = OperatingPoint()
    capacity: float = 1.0     # relative shard weight (throughput share)

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(
                f"instance {self.name!r} capacity must be > 0, "
                f"got {self.capacity}")


@dataclasses.dataclass(frozen=True)
class ShardRun:
    """One instance's share of a dispatched batch."""
    instance: AcceleratorInstance
    batch_size: int
    exec_s: float             # service time (paced to modeled hw if pacing)
    attempt: int = 0          # 0 = first dispatch, >0 = retry round


@dataclasses.dataclass
class InstanceHealth:
    """Mutable per-instance serving health (exported by fleet_health)."""
    state: str = "healthy"            # healthy | quarantined
    frames: int = 0
    shards: int = 0
    failures: int = 0                 # faults + deadline misses, lifetime
    consecutive_failures: int = 0
    quarantines: int = 0
    probe_after: float = 0.0          # dispatcher-clock readmission time
    cooldown_s: float = 0.0           # current quarantine window
    last_beat: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class IntegrityConfig:
    """The dispatcher's SDC defense configuration.

    With integrity on, every shard executes through the *guarded* engine
    path (engine.forward_jit_guarded — bit-identical to the plain path on
    clean dispatches) and its int32 accumulators are verified per
    ``check_every`` layers; a detection raises ``OutputCorrupted``, which
    the coordinator handles exactly like an availability fault: quarantine
    the instance and re-execute the shard on healthy ones (per-image
    quantization makes the recovered outputs bitwise-identical to the
    fault-free run).  ``canary_every=k`` additionally probes each instance
    with a golden-reference frame every k shards — defense-in-depth that
    catches persistent corruption even at ``check_every=0``.
    """
    check_every: int = 1
    abft: bool = True
    range_guard: bool = True
    weight_checksum: bool = True
    canary_every: int = 0        # 0 disables canary probes

    def __post_init__(self) -> None:
        if self.check_every < 0:
            raise ValueError(
                f"check_every must be >= 0, got {self.check_every}")
        if self.canary_every < 0:
            raise ValueError(
                f"canary_every must be >= 0, got {self.canary_every}")

    def policy(self) -> engine.IntegrityPolicy:
        return engine.IntegrityPolicy(
            abft=self.abft, range_guard=self.range_guard,
            weight_checksum=self.weight_checksum,
            check_every=self.check_every)


def default_fleet(k: int, hw: OperatingPoint = OperatingPoint(),
                  ) -> Tuple[AcceleratorInstance, ...]:
    """K homogeneous instances at one hardware operating point."""
    if k < 1:
        raise ValueError(f"fleet size must be >= 1, got {k}")
    return tuple(AcceleratorInstance(name=f"acc{i}", hw=hw)
                 for i in range(k))


class ShardedDispatcher:
    """Shard batches across a fleet of simulated accelerator instances.

    With no faults, no deadline and no pacing this degrades to the plain
    capacity-weighted sharded dispatch (now concurrent); the fault path
    activates only when an injector/deadline is configured.
    """

    def __init__(self, instances: Sequence[AcceleratorInstance],
                 fault_injector: Optional[FaultInjector] = None,
                 deadline_s: Optional[float] = None,
                 max_retries: int = 3,
                 backoff_base_s: float = 0.01,
                 backoff_cap_s: float = 0.25,
                 probe_cooldown_s: float = 0.05,
                 pace: Optional[str] = None,
                 time_fn: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 heartbeat: Optional[HeartbeatMonitor] = None,
                 straggler: Optional[StragglerDetector] = None,
                 integrity: Optional[IntegrityConfig] = None,
                 fleet_power_cap_w: Optional[float] = None):
        if not instances:
            raise ValueError("dispatcher needs at least one instance")
        names = [i.name for i in instances]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance names: {names}")
        if pace not in (None, "hardware"):
            raise ValueError(f"pace must be None or 'hardware', got {pace!r}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.instances = tuple(instances)
        self._total_capacity = sum(i.capacity for i in self.instances)
        # peak device watts per instance, from the unified point's
        # accelerator view — what the fleet power budget admits against
        self._inst_power: Dict[str, float] = {
            i.name: i.hw.to_accelerator().power_w() for i in self.instances}
        if (fleet_power_cap_w is not None
                and fleet_power_cap_w < min(self._inst_power.values())):
            raise ValueError(
                f"fleet_power_cap_w={fleet_power_cap_w} admits no instance "
                f"(cheapest draws "
                f"{min(self._inst_power.values()):.3f} W peak)")
        self.fleet_power_cap_w = fleet_power_cap_w
        self.fault_injector = fault_injector
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.probe_cooldown_s = probe_cooldown_s
        self.pace = pace
        self._time = time_fn
        self._sleep = sleep_fn
        self.heartbeat = heartbeat or HeartbeatMonitor(
            timeout_s=max(4 * (deadline_s or 0.0), 1.0), time_fn=time_fn)
        self.straggler = straggler or StragglerDetector()
        self.health: Dict[str, InstanceHealth] = {
            i.name: InstanceHealth() for i in self.instances}
        self.counters: Dict[str, int] = {
            "dispatched_shards": 0, "completed_shards": 0, "retries": 0,
            "timeouts": 0, "faults": 0, "quarantines": 0, "probes": 0,
            "probe_failures": 0, "readmissions": 0,
            "integrity_checks": 0, "sdc_detections": 0,
            "corrupted_shards": 0, "canary_probes": 0, "canary_failures": 0,
            "power_deferrals": 0}
        self.integrity = integrity
        #: metrics registry (the server wires telemetry's in); detection
        #: latencies land in serve_sdc_detection_latency_seconds
        self.metrics = None
        # shard workers update the SDC counters concurrently
        self._counter_lock = threading.Lock()
        # id(plan) -> (reference frame, golden output) for canary probes;
        # the golden is computed ONCE through the plain (un-injected)
        # engine path at first dispatch of the plan
        self._canary: Dict[int, Tuple[jax.Array, jax.Array]] = {}
        self._since_canary: Dict[str, int] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._model_memo: Dict[Tuple[str, Tuple[LayerSpec, ...], int],
                               float] = {}
        self._tracer = NOOP_TRACER
        if fault_injector is not None:
            fault_injector.tracer = self._tracer

    @property
    def tracer(self):
        """Span tracer; shard exec/retry/probe/quarantine events land here
        (the server wires its tracer in; fault instants come from the
        injector, which shares this tracer)."""
        return self._tracer

    @tracer.setter
    def tracer(self, tr) -> None:
        self._tracer = tr
        if self.fault_injector is not None:
            self.fault_injector.tracer = tr

    # -- fleet health -----------------------------------------------------

    def _probe(self, inst: AcceleratorInstance) -> bool:
        """One readmission probe: does the instance accept a dispatch?

        A probe is a real dispatch attempt against the fault injector (so
        finite-duration faults burn down under probing); with no injector
        configured a probe always passes.  An instance that would still
        *corrupt values* fails its probe too — readmitting a poisoning
        instance on a timing-only health check would hand it fresh shards.
        """
        self.counters["probes"] += 1
        if self.fault_injector is None:
            ok = True
        else:
            effects = self.fault_injector.on_dispatch(inst.name,
                                                      probe=True)
            ok = effects.fault is None and effects.corruption is None
        self._tracer.instant("probe", cat="probe", tid=inst.name,
                             instance=inst.name, ok=ok)
        return ok

    def active_instances(self) -> List[AcceleratorInstance]:
        """Healthy instances, after probing due quarantined ones back in."""
        now = self._time()
        out = []
        for inst in self.instances:
            h = self.health[inst.name]
            if h.state == "quarantined" and now >= h.probe_after:
                if self._probe(inst):
                    h.state = "healthy"
                    h.consecutive_failures = 0
                    h.cooldown_s = 0.0
                    self.counters["readmissions"] += 1
                    self._tracer.instant("readmit", cat="probe",
                                         tid=inst.name, instance=inst.name)
                else:
                    self.counters["probe_failures"] += 1
                    h.cooldown_s = min(h.cooldown_s * 2,
                                       max(self.backoff_cap_s,
                                           self.probe_cooldown_s))
                    h.probe_after = now + h.cooldown_s
            if h.state == "healthy":
                out.append(inst)
        return out

    def _quarantine(self, inst: AcceleratorInstance) -> None:
        h = self.health[inst.name]
        h.failures += 1
        h.consecutive_failures += 1
        if h.state != "quarantined":
            h.state = "quarantined"
            h.quarantines += 1
            self.counters["quarantines"] += 1
            self._tracer.instant("quarantine", cat="probe", tid=inst.name,
                                 instance=inst.name,
                                 consecutive_failures=h.consecutive_failures)
        h.cooldown_s = min(
            self.probe_cooldown_s * (2 ** (h.consecutive_failures - 1)),
            max(self.backoff_cap_s, self.probe_cooldown_s))
        h.probe_after = self._time() + h.cooldown_s

    def healthy_capacity_fraction(self) -> float:
        """Surviving capacity share (probes due instances on the way)."""
        act = self.active_instances()
        return sum(i.capacity for i in act) / self._total_capacity

    def power_admitted(self, active: Sequence[AcceleratorInstance],
                       count: bool = False) -> List[AcceleratorInstance]:
        """The subset of ``active`` the fleet power budget admits.

        Greedy prefix admission in declared fleet order: each instance is
        admitted if its peak device watts still fit under
        ``fleet_power_cap_w``, else skipped (a dispatch-time skip counts
        as a ``power_deferrals`` round when ``count`` is set) —
        deterministic, and the capacity split downstream only ever sees
        the admitted set, so a power-capped fleet never plans shards onto
        instances it cannot afford to light up.  No cap -> everything
        passes through.
        """
        if self.fleet_power_cap_w is None:
            return list(active)
        out: List[AcceleratorInstance] = []
        used = 0.0
        for inst in active:
            p = self._inst_power[inst.name]
            if used + p <= self.fleet_power_cap_w + 1e-12:
                out.append(inst)
                used += p
            elif count:
                self.counters["power_deferrals"] += 1
        return out

    def fleet_health(self) -> Dict:
        """Per-instance health + fleet counters (summary()["fleet"])."""
        now = self._time()
        stragglers = set(self.straggler.stragglers())
        per = {}
        for inst in self.instances:
            h = self.health[inst.name]
            per[inst.name] = {
                "state": h.state,
                "point": inst.hw.label,
                "capacity": inst.capacity,
                "power_w": self._inst_power[inst.name],
                "frames": h.frames,
                "shards": h.shards,
                "failures": h.failures,
                "quarantines": h.quarantines,
                "straggler": inst.name in stragglers,
                "last_beat_age_s": (None if h.last_beat is None
                                    else now - h.last_beat),
            }
        healthy = [i for i in self.instances
                   if self.health[i.name].state == "healthy"]
        return {"instances": per, "counters": dict(self.counters),
                "healthy_fraction": sum(i.capacity for i in healthy)
                / self._total_capacity,
                "power_cap_w": self.fleet_power_cap_w,
                "peak_power_w": sum(self._inst_power.values()),
                "admitted_power_w": sum(
                    self._inst_power[i.name]
                    for i in self.power_admitted(healthy)),
                "suspect_dead": list(self.heartbeat.dead_hosts())}

    # -- apportionment ----------------------------------------------------

    def shard_sizes(self, batch: int,
                    active: Optional[Sequence[AcceleratorInstance]] = None,
                    ) -> List[int]:
        """Deterministic capacity-proportional split summing to ``batch``.

        Largest-remainder apportionment over ``active`` (default: the
        whole fleet): every instance gets the floor of its proportional
        share, the leftover frames go to the largest fractional
        remainders (ties to the earlier instance).  Instances may receive
        0 frames for small batches.  Quarantine passes the reduced
        healthy set here, so a degraded fleet re-deals the same frames
        deterministically.
        """
        if batch < 0:
            raise ValueError(f"batch must be >= 0, got {batch}")
        insts = self.instances if active is None else tuple(active)
        if not insts:
            raise NoHealthyInstances("no instances to apportion over")
        total = sum(i.capacity for i in insts)
        quotas = [batch * i.capacity / total for i in insts]
        sizes = [int(q) for q in quotas]
        order = sorted(range(len(quotas)),
                       key=lambda j: (-(quotas[j] - sizes[j]), j))
        for j in order[:batch - sum(sizes)]:
            sizes[j] += 1
        return sizes

    # -- shard execution --------------------------------------------------

    def _modeled_shard_s(self, inst: AcceleratorInstance,
                         sim_specs: Optional[Tuple[LayerSpec, ...]],
                         size: int) -> float:
        """Modeled device time for a shard at the instance's point (0.0
        without sim_specs).  Feeds both the hardware pacing floor and the
        tracer's hardware-clock spans."""
        if not sim_specs:
            return 0.0
        key = (inst.hw.label, sim_specs, size)
        t = self._model_memo.get(key)
        if t is None:
            rep = sim.simulate(inst.hw.to_accelerator(), sim_specs,
                               batch=size)
            t = size / rep.fps
            self._model_memo[key] = t
        return t

    def _run_shard(self, inst: AcceleratorInstance, plan: engine.ModelPlan,
                   shard: jax.Array, interpret: Optional[bool],
                   pace_floor_s: float, modeled_s: float,
                   off: int, attempt: int) -> Tuple[jax.Array, float]:
        """Worker-thread body: inject faults, execute, pace to device time.

        Raises typed faults (InstanceCrashed / ReconfigStuck) straight out
        of the future; the coordinator turns them into retries.  The whole
        attempt — fault injection included — is one ``shard.exec`` span on
        the instance's track; a successful attempt mirrors its modeled
        device time onto the hardware clock.
        """
        with self._tracer.span("shard.exec", cat="shard", tid=inst.name,
                               instance=inst.name, point=inst.hw.label,
                               offset=off, size=int(shard.shape[0]),
                               attempt=attempt) as sp:
            t0 = time.perf_counter()
            corruption: Optional[CorruptionSpec] = None
            if self.fault_injector is not None:
                effects = self.fault_injector.on_dispatch(inst.name)
                if effects.delay_s > 0:
                    self._sleep(effects.delay_s)
                if effects.fault is not None:
                    self.fault_injector.raise_for(effects.fault, inst.name)
                corruption = effects.corruption
            if corruption is None and self.integrity is None:
                out = engine.forward_jit(plan, shard, interpret=interpret)
            else:
                out = self._run_guarded(inst, plan, shard, corruption, t0)
            out = jax.block_until_ready(out)
            exec_s = time.perf_counter() - t0
            if pace_floor_s > exec_s:
                self._sleep(pace_floor_s - exec_s)
                exec_s = pace_floor_s
            if modeled_s > 0:
                sp.hw(inst.name, modeled_s)
            return out, exec_s

    def _run_guarded(self, inst: AcceleratorInstance, plan: engine.ModelPlan,
                     shard: jax.Array, corruption: Optional[CorruptionSpec],
                     t0: float) -> jax.Array:
        """Guarded shard execution: apply injected corruption, verify.

        With integrity configured, the guarded pipeline's per-layer
        detector flags turn any corruption into a typed
        ``OutputCorrupted`` (the coordinator quarantines + re-executes);
        with integrity ``None`` but corruption active, the corrupted
        outputs pass through SILENTLY — the undefended baseline the SDC
        bench measures the defense against.
        """
        policy = (self.integrity.policy() if self.integrity is not None
                  else engine.DISABLED_POLICY)
        cargs = None
        params = None
        if corruption is not None:
            cargs = engine.corruption_args(
                seed=corruption.seed, sigma_lsb=corruption.sigma_lsb,
                gain=corruption.gain, bias_lsb=corruption.bias_lsb,
                flip_prob=corruption.flip_prob)
            if corruption.stuck_rings > 0:
                params = engine.corrupted_layer_params(
                    plan, corruption.seed, corruption.stuck_rings)
        out, flags = engine.forward_jit_guarded(plan, shard, cargs=cargs,
                                                policy=policy, params=params)
        if self.integrity is not None and policy.check_every > 0:
            with self._counter_lock:
                self.counters["integrity_checks"] += 1
            masks = np.asarray(flags)
            bad = int(np.argmax(masks != 0))
            if masks[bad]:
                detect_s = time.perf_counter() - t0
                detectors = engine.detector_names(int(masks[bad]))
                with self._counter_lock:
                    self.counters["sdc_detections"] += 1
                if self.metrics is not None:
                    self.metrics.histogram(
                        "serve_sdc_detection_latency_seconds",
                        "dispatch-to-detection latency of corrupted shards",
                        model=plan.name).record(detect_s)
                self._tracer.instant(
                    "sdc.detected", cat="fault", tid=inst.name,
                    instance=inst.name, layer=bad,
                    detectors=",".join(detectors), latency_s=detect_s)
                raise OutputCorrupted(inst.name, bad, detectors)
        return out

    # -- canary probes ----------------------------------------------------

    def _ensure_canary(self, plan: engine.ModelPlan, xb: jax.Array,
                       interpret: Optional[bool]) -> None:
        """Bootstrap the plan's golden canary from the first served batch.

        The golden runs through the plain engine path on the host — NOT
        through the fault injector — so it is the fault-free reference the
        probes compare against bitwise.
        """
        if id(plan) not in self._canary:
            xref = xb[:1]
            yref = jax.block_until_ready(
                engine.forward_jit(plan, xref, interpret=interpret))
            self._canary[id(plan)] = (xref, yref)

    def _canary_ok(self, inst: AcceleratorInstance, plan: engine.ModelPlan,
                   ) -> bool:
        """Probe an instance with the golden frame if its canary is due.

        The probe is a real dispatch against the injector (finite faults
        burn down, like quarantine probes); the probe frame executes with
        whatever corruption is live on the instance and its output is
        compared bitwise against the golden — a mismatch quarantines the
        instance, whatever the detectors would have said.  This is the
        layer that catches persistent corruption at ``check_every=0``.
        """
        cfg = self.integrity
        if (cfg is None or cfg.canary_every <= 0
                or id(plan) not in self._canary):
            return True
        if self._since_canary.get(inst.name, 0) < cfg.canary_every:
            return True
        self._since_canary[inst.name] = 0
        with self._counter_lock:
            self.counters["canary_probes"] += 1
        corruption: Optional[CorruptionSpec] = None
        if self.fault_injector is not None:
            effects = self.fault_injector.on_dispatch(inst.name)
            if effects.fault is not None:
                self._quarantine(inst)
                return False
            corruption = effects.corruption
        xref, yref = self._canary[id(plan)]
        cargs = None
        params = None
        if corruption is not None:
            cargs = engine.corruption_args(
                seed=corruption.seed, sigma_lsb=corruption.sigma_lsb,
                gain=corruption.gain, bias_lsb=corruption.bias_lsb,
                flip_prob=corruption.flip_prob)
            if corruption.stuck_rings > 0:
                params = engine.corrupted_layer_params(
                    plan, corruption.seed, corruption.stuck_rings)
        out, _ = engine.forward_jit_guarded(
            plan, xref, cargs=cargs, policy=engine.DISABLED_POLICY,
            params=params)
        ok = bool(jnp.array_equal(out, yref))
        self._tracer.instant("sdc.canary", cat="probe", tid=inst.name,
                             instance=inst.name, ok=ok)
        if not ok:
            with self._counter_lock:
                self.counters["canary_failures"] += 1
                self.counters["sdc_detections"] += 1
            self._quarantine(inst)
        return ok

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            # 2x fleet size: a shard orphaned past its deadline keeps its
            # worker until the injected hang ends; headroom keeps retry
            # rounds from queueing behind a sleeping straggler thread
            self._pool = ThreadPoolExecutor(
                max_workers=max(2 * len(self.instances), 4),
                thread_name_prefix="shard")
        return self._pool

    # -- dispatch ---------------------------------------------------------

    def run(self, plan: engine.ModelPlan, xb: jax.Array,
            interpret: Optional[bool] = None,
            sim_specs: Optional[Sequence[LayerSpec]] = None,
            ) -> Tuple[jax.Array, List[ShardRun]]:
        """Serve one batch sharded across the fleet, surviving faults.

        Returns the concatenated outputs (request order preserved) and one
        ``ShardRun`` per *successful* shard execution.  Bitwise-identical
        to ``engine.forward_jit(plan, xb)`` regardless of which instances
        ran, failed, or retried — quantization, GEMM rows and epilogue
        scales are all per image.

        ``sim_specs`` (the model's simulator layer table) enables
        hardware pacing when the dispatcher was built with
        ``pace="hardware"``.
        """
        b = xb.shape[0]
        if b == 0:
            raise ValueError("cannot dispatch an empty batch")
        specs = tuple(sim_specs) if sim_specs else None
        pool = self._ensure_pool()
        if self.integrity is not None and self.integrity.canary_every > 0:
            self._ensure_canary(plan, xb, interpret)
        segments: Dict[int, jax.Array] = {}      # offset -> shard output
        runs: List[ShardRun] = []
        work: List[Tuple[int, int]] = [(0, b)]   # (offset, size) outstanding
        attempt = 0
        last_exc: Optional[BaseException] = None
        while work:
            active = self.power_admitted(
                [inst for inst in self.active_instances()
                 if self._canary_ok(inst, plan)], count=True)
            if not active:
                # transiently empty fleet (all quarantined, or the power
                # budget admits none of the survivors): burn a retry round
                # waiting for quarantine probes to readmit someone before
                # giving up
                attempt += 1
                if attempt > self.max_retries:
                    raise NoHealthyInstances(
                        f"all {len(self.instances)} instances quarantined "
                        f"with {sum(s for _, s in work)} frames outstanding"
                    ) from last_exc
                self.counters["retries"] += 1
                self._sleep(min(self.backoff_base_s * (2 ** (attempt - 1)),
                                self.backoff_cap_s))
                continue
            # deal every outstanding range across the healthy set
            tasks: List[Tuple[int, int, AcceleratorInstance]] = []
            for off, size in work:
                start = off
                for inst, share in zip(
                        active, self.shard_sizes(size, active=active)):
                    if share == 0:
                        continue
                    tasks.append((start, share, inst))
                    start += share
            futures: Dict[Future, Tuple[int, int, AcceleratorInstance]] = {}
            for off, size, inst in tasks:
                shard = xb[off:off + size]
                modeled = self._modeled_shard_s(inst, specs, size)
                floor = modeled if self.pace == "hardware" else 0.0
                self.counters["dispatched_shards"] += 1
                self._since_canary[inst.name] = (
                    self._since_canary.get(inst.name, 0) + 1)
                futures[pool.submit(self._run_shard, inst, plan, shard,
                                    interpret, floor, modeled,
                                    off, attempt)] = (off, size, inst)
            failed: List[Tuple[int, int]] = []
            pending = set(futures)
            t_submit = time.perf_counter()
            while pending:
                timeout = None
                if self.deadline_s is not None:
                    timeout = max(
                        0.0,
                        self.deadline_s - (time.perf_counter() - t_submit))
                done, pending = futures_wait(pending, timeout=timeout,
                                             return_when=FIRST_COMPLETED)
                if not done:       # deadline expired for every pending shard
                    for fut in pending:
                        off, size, inst = futures[fut]
                        fut.cancel()   # drop if not started; else orphan it
                        exc = ShardDeadlineExceeded(inst.name,
                                                    self.deadline_s)
                        last_exc = exc
                        self.counters["timeouts"] += 1
                        self._tracer.instant(
                            "fault.deadline", cat="fault", tid=inst.name,
                            instance=inst.name, deadline_s=self.deadline_s,
                            offset=off, size=size)
                        self._quarantine(inst)
                        failed.append((off, size))
                    break
                for fut in done:
                    off, size, inst = futures[fut]
                    exc = fut.exception()
                    if exc is None:
                        out, exec_s = fut.result()
                        segments[off] = out
                        runs.append(ShardRun(instance=inst, batch_size=size,
                                             exec_s=exec_s, attempt=attempt))
                        h = self.health[inst.name]
                        h.frames += size
                        h.shards += 1
                        h.consecutive_failures = 0
                        h.last_beat = self._time()
                        self.heartbeat.beat(inst.name)
                        self.straggler.record(inst.name, exec_s)
                        self.counters["completed_shards"] += 1
                    elif isinstance(exc, ServingFault):
                        last_exc = exc
                        self.counters["faults"] += 1
                        if isinstance(exc, OutputCorrupted):
                            self.counters["corrupted_shards"] += 1
                        self._quarantine(inst)
                        failed.append((off, size))
                    else:            # programming error, not a chaos fault
                        raise exc
            if failed:
                attempt += 1
                self.counters["retries"] += 1
                self._tracer.instant(
                    "retry", cat="shard", tid="dispatcher", round=attempt,
                    frames=sum(s for _, s in failed))
                if attempt > self.max_retries:
                    raise RetriesExhausted(
                        f"{sum(s for _, s in failed)} frames still failing "
                        f"after {self.max_retries} retries") from last_exc
                self._sleep(min(self.backoff_base_s * (2 ** (attempt - 1)),
                                self.backoff_cap_s))
            work = sorted(failed)
        outs = [segments[off] for off in sorted(segments)]
        return jnp.concatenate(outs, axis=0), runs

    def close(self) -> None:
        """Shut down the shard thread pool (idempotent).

        The pool is created lazily on first sharded dispatch and
        recreated the same way after a close, so ``close`` is safe at any
        point — including mid-lifetime (``CNNServer.reset``): the next
        ``run`` simply pays pool startup again.
        """
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def __enter__(self) -> "ShardedDispatcher":
        return self

    def __exit__(self, *exc) -> bool:
        """Deterministic pool shutdown on scope exit (no pool leaks)."""
        self.close()
        return False
