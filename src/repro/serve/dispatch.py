"""Multi-accelerator sharded dispatch: one batch, K simulated accelerators.

A deployment that outgrows one photonic accelerator scales out: K
accelerator instances (possibly heterogeneous operating points — e.g. an
RMAM@1G next to an RMAM@5G) serve shards of every formed batch in
parallel, each against its own resident copy of the model's DKV imprint.
``ShardedDispatcher`` models exactly that on the execution side:

* the batch is split contiguously into per-instance shards sized by each
  instance's ``capacity`` weight (largest-remainder apportionment, so
  shard sizes are deterministic and sum to the batch);
* every non-empty shard runs through the whole-model jitted pipeline
  (``engine.forward_jit``) — per-image quantization makes each image's
  output independent of its shard, so the concatenated outputs are
  bitwise-identical to serving the unsharded batch on one accelerator
  (asserted in tests/test_dispatch.py, ragged batches included);
* each shard reports its wall execution time and its instance, and the
  telemetry layer (telemetry.record_batch ``shards=``) costs it through
  the cycle-true simulator at that instance's hardware operating point.

``CNNServer`` routes through a dispatcher when one is configured;
``PlanRegistry.warm_pipelines`` accepts the dispatcher so every
(plan, shard-bucket) executable is pre-traced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import engine
from .telemetry import HardwarePoint


@dataclasses.dataclass(frozen=True)
class AcceleratorInstance:
    """One simulated accelerator in the fleet."""
    name: str
    hw: HardwarePoint = HardwarePoint()
    capacity: float = 1.0     # relative shard weight (throughput share)

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(
                f"instance {self.name!r} capacity must be > 0, "
                f"got {self.capacity}")


@dataclasses.dataclass(frozen=True)
class ShardRun:
    """One instance's share of a dispatched batch."""
    instance: AcceleratorInstance
    batch_size: int
    exec_s: float             # wall-clock pipeline time for the shard


def default_fleet(k: int, hw: HardwarePoint = HardwarePoint(),
                  ) -> Tuple[AcceleratorInstance, ...]:
    """K homogeneous instances at one hardware operating point."""
    if k < 1:
        raise ValueError(f"fleet size must be >= 1, got {k}")
    return tuple(AcceleratorInstance(name=f"acc{i}", hw=hw)
                 for i in range(k))


class ShardedDispatcher:
    """Shard batches across a fleet of simulated accelerator instances."""

    def __init__(self, instances: Sequence[AcceleratorInstance]):
        if not instances:
            raise ValueError("dispatcher needs at least one instance")
        names = [i.name for i in instances]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate instance names: {names}")
        self.instances = tuple(instances)
        self._total_capacity = sum(i.capacity for i in self.instances)

    def shard_sizes(self, batch: int) -> List[int]:
        """Deterministic capacity-proportional split summing to ``batch``.

        Largest-remainder apportionment: every instance gets the floor of
        its proportional share, the leftover frames go to the largest
        fractional remainders (ties to the earlier instance).  Instances
        may receive 0 frames for small batches.
        """
        if batch < 0:
            raise ValueError(f"batch must be >= 0, got {batch}")
        quotas = [batch * i.capacity / self._total_capacity
                  for i in self.instances]
        sizes = [int(q) for q in quotas]
        order = sorted(range(len(quotas)),
                       key=lambda j: (-(quotas[j] - sizes[j]), j))
        for j in order[:batch - sum(sizes)]:
            sizes[j] += 1
        return sizes

    def run(self, plan: engine.ModelPlan, xb: jax.Array,
            interpret: Optional[bool] = None,
            ) -> Tuple[jax.Array, List[ShardRun]]:
        """Serve one batch sharded across the fleet.

        Returns the concatenated outputs (request order preserved) and
        one ``ShardRun`` per non-empty shard.  Bitwise-identical to
        ``engine.forward_jit(plan, xb)`` because quantization, GEMM rows
        and epilogue scales are all per image.
        """
        b = xb.shape[0]
        if b == 0:
            raise ValueError("cannot dispatch an empty batch")
        sizes = self.shard_sizes(b)
        outs: List[jax.Array] = []
        runs: List[ShardRun] = []
        start = 0
        for inst, size in zip(self.instances, sizes):
            if size == 0:
                continue
            shard = xb[start:start + size]
            start += size
            t0 = time.perf_counter()
            out = engine.forward_jit(plan, shard, interpret=interpret)
            out = jax.block_until_ready(out)
            runs.append(ShardRun(instance=inst, batch_size=size,
                                 exec_s=time.perf_counter() - t0))
            outs.append(out)
        return jnp.concatenate(outs, axis=0), runs
