"""Multi-model plan registry: compile-once DKV imprints with LRU eviction.

A deployed photonic accelerator keeps a bounded number of models' DKVs
resident (MRR imprints are the scarce resource); loading another model
evicts the least-recently-served one.  The registry mirrors that: it is
keyed like ``engine.plan.get_plan`` — (model name, EnginePoint) identifies
a compiled ``ModelPlan`` — but owns its own bounded cache so eviction
actually frees the imprint, and re-loads through the registered *weight
factory* (deterministic in (model, seed)), re-imprinting bit-identical
DKVs on demand.

Structural misuse (re-registering a name with a different architecture,
or a factory that changes shape between loads) raises ``ValueError``, the
same guard ``get_plan`` applies to its cache keys.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..cnn.layers import LayerSpec
from ..engine import (DEFAULT_POINT, EnginePoint, LayerDef, ModelPlan,
                      batch_bucket, compile_model, forward_jit,
                      pipeline_evict, plan_model, search_cache_evict)
from ..engine.plan import _defs_fingerprint
from . import models as zoo


@dataclasses.dataclass(frozen=True)
class ServingModel:
    """A loaded model: the compiled plan plus its simulator layer tables."""
    name: str
    plan: ModelPlan
    input_shape: Tuple[int, int, int]
    exec_specs: Tuple[LayerSpec, ...]   # what the engine actually runs
    sim_specs: Tuple[LayerSpec, ...]    # what the hardware model costs


@dataclasses.dataclass
class _Registration:
    factory: Callable[[], List[LayerDef]]
    input_shape: Tuple[int, int, int]
    sim_specs: Optional[Tuple[LayerSpec, ...]]
    fingerprint: Optional[tuple] = None  # set on first load


class PlanRegistry:
    """LRU-evicting registry of compiled ModelPlans, one per model name.

    ``capacity`` bounds how many plans are resident at once; every loaded
    plan shares this registry's ``EnginePoint`` (one accelerator operating
    point per registry, as on real hardware).  With ``planner=True`` the
    registry compiles through the reconfiguration-aware planner
    (``engine.plan_model``): each layer gets its modeled-best operating
    point (bitwise-identical outputs, heterogeneous packing).
    """

    def __init__(self, capacity: int = 4,
                 point: EnginePoint = DEFAULT_POINT,
                 planner: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.point = point
        self.planner = planner
        #: current accelerator operating point the planner scores against
        #: (None = the planner's default device); set_accelerator() moves
        #: it at runtime (brownout downshift) and triggers a replan
        self.accelerator = None
        self._registered: Dict[str, _Registration] = {}
        self._loaded: "OrderedDict[str, ServingModel]" = OrderedDict()
        self._stats = {"hits": 0, "misses": 0, "evictions": 0,
                       "replans": 0}

    def register(self, name: str, factory: Callable[[], List[LayerDef]],
                 input_shape: Tuple[int, int, int],
                 sim_specs: Optional[Sequence[LayerSpec]] = None) -> None:
        """Declare a servable model; compilation is lazy (first `get`)."""
        if name in self._registered:
            raise ValueError(f"model {name!r} already registered")
        self._registered[name] = _Registration(
            factory=factory, input_shape=tuple(input_shape),
            sim_specs=None if sim_specs is None else tuple(sim_specs))

    @property
    def registered(self) -> List[str]:
        return list(self._registered)

    def input_shape(self, name: str) -> Tuple[int, int, int]:
        return self._registered[name].input_shape

    @property
    def loaded(self) -> List[str]:
        """Currently resident plans, least-recently-used first."""
        return list(self._loaded)

    def stats(self) -> Dict[str, int]:
        """Cache counters plus the resident imprints' weight footprint.

        Plans store pre-quantized int8 operands (engine/plan.py), so the
        resident weight bytes run at least 2x under — in practice close to
        4x under, biases aside — the f32 streams a float-domain engine
        would keep resident; the packed/f32-equivalent pair reports that
        saving per registry.
        """
        packed = sum(m.plan.weight_bytes for m in self._loaded.values())
        f32 = sum(m.plan.weight_bytes_f32 for m in self._loaded.values())
        return dict(self._stats, resident=len(self._loaded),
                    weight_bytes_packed=packed,
                    weight_bytes_f32_equiv=f32)

    def _registration(self, name: str) -> _Registration:
        try:
            return self._registered[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} not registered "
                f"(registered: {sorted(self._registered)})") from None

    def _compile(self, name: str, reg: _Registration):
        """Run the weight factory and compile the plan (fingerprint-guarded).

        The one compile path: ``get`` and the out-of-band ``weight_report``
        both go through here, so the deterministic-factory guard applies
        to every load.
        """
        defs = reg.factory()
        fp = _defs_fingerprint(defs)
        if reg.fingerprint is None:
            reg.fingerprint = fp
        elif reg.fingerprint != fp:
            raise ValueError(
                f"weight factory for {name!r} produced a structurally "
                f"different model than its first load; factories must be "
                f"deterministic per model key")
        if self.planner:
            acc = (None if self.accelerator is None
                   else self.accelerator.to_accelerator())
            plan = plan_model(name, defs, reg.input_shape, self.point,
                              acc=acc)
        else:
            plan = compile_model(name, defs, self.point)
        return defs, plan

    def set_accelerator(self, point) -> None:
        """Retune the registry's device (``core.OperatingPoint``) and
        replan.

        With ``planner=True`` every resident plan is dropped (pipelines
        and point-search memos evicted with it) so the next ``get``
        recompiles through the planner scored against the new
        accelerator — ``cached_search`` keys include the accelerator, so
        a downshift can never hit a stale search memo, and by the
        planner's contract the replanned outputs are bitwise-identical
        (packing geometry moves, quantization never does).  Without the
        planner the engine plan does not depend on the device, so the
        point is recorded (telemetry/pacing consumers read it) and the
        resident plans stay.
        """
        if point == self.accelerator:
            return
        self.accelerator = point
        if not self.planner:
            return
        self._stats["replans"] += 1
        while self._loaded:
            evicted_name, evicted = self._loaded.popitem(last=False)
            pipeline_evict(evicted.plan)
            search_cache_evict(evicted_name)

    def weight_report(self, name: str) -> Dict[str, float]:
        """One model's imprint footprint: packed int8 vs f32-equivalent.

        Read-only observability: a resident plan is *peeked* (no LRU
        promotion); a cold model is compiled out-of-band and discarded —
        nothing is loaded into, or evicted from, the registry to answer
        a report (inspect cold models sparingly: the throwaway compile is
        the price of not disturbing the LRU).
        """
        entry = self._loaded.get(name)
        if entry is not None:
            plan = entry.plan
        else:
            _, plan = self._compile(name, self._registration(name))
        packed, f32 = plan.weight_bytes, plan.weight_bytes_f32
        return {"packed_bytes": packed, "f32_equiv_bytes": f32,
                "ratio": f32 / packed}

    def get(self, name: str) -> ServingModel:
        """Fetch a model's plan, compiling (and possibly evicting) on miss."""
        if name in self._loaded:
            self._loaded.move_to_end(name)
            self._stats["hits"] += 1
            return self._loaded[name]
        reg = self._registration(name)
        self._stats["misses"] += 1
        defs, plan = self._compile(name, reg)
        exec_specs = tuple(zoo.specs_for_defs(defs, reg.input_shape))
        entry = ServingModel(
            name=name, plan=plan, input_shape=reg.input_shape,
            exec_specs=exec_specs,
            sim_specs=(reg.sim_specs if reg.sim_specs is not None
                       else exec_specs))
        while len(self._loaded) >= self.capacity:
            evicted_name, evicted = self._loaded.popitem(last=False)
            # drop the compiled whole-model pipelines AND the planner's
            # point-search memo with the imprint — either cache would
            # otherwise pin the evicted model's state resident forever
            pipeline_evict(evicted.plan)
            search_cache_evict(evicted_name)
            self._stats["evictions"] += 1
        self._loaded[name] = entry
        return entry

    def warm_pipelines(self, name: str, max_batch: int,
                       interpret: Optional[bool] = None,
                       dispatcher=None) -> List[int]:
        """Pre-compile the whole-model jitted pipeline for every batch
        bucket up to ``max_batch``, so serving pays no compile stalls.

        Returns the bucket sizes traced.  Loads (and possibly evicts) like
        any ``get``.  With a ``ShardedDispatcher``, the buckets are those
        of every *shard* a batch up to ``max_batch`` can produce — the
        shapes the dispatcher will actually run.
        """
        entry = self.get(name)
        sizes = range(1, max_batch + 1)
        if dispatcher is None:
            buckets = sorted({batch_bucket(b) for b in sizes})
        else:
            buckets = sorted({batch_bucket(s) for b in sizes
                              for s in dispatcher.shard_sizes(b) if s > 0})
        for bucket in buckets:
            xb = jnp.zeros((bucket, *entry.input_shape), jnp.float32)
            forward_jit(entry.plan, xb, interpret=interpret)
        return buckets


def paper_cnn_registry(capacity: int = 3,
                       point: EnginePoint = DEFAULT_POINT,
                       seed: int = 0, planner: bool = False) -> PlanRegistry:
    """Registry pre-loaded with the serving zoo's paper-CNN stand-ins.

    Each mini executes functionally through the engine while its telemetry
    is costed at paper scale (the full EfficientNetB7 / Xception /
    ShuffleNetV2 layer tables from cnn/models.py).
    """
    reg = PlanRegistry(capacity=capacity, point=point, planner=planner)
    for name in zoo.SERVING_MODELS:
        reg.register(
            name,
            factory=(lambda n=name: zoo.serving_defs(n, seed)),
            input_shape=zoo.serving_input_shape(name),
            sim_specs=zoo.paper_scale_specs(name))
    return reg
