"""Hardware-time telemetry: wall clock AND modeled photonic time per batch.

Every served batch is costed twice: the wall-clock execution time of the
Pallas kernels on the host, and — through core/simulator.simulate — the
cycle-true time/energy the batch would take on each configured photonic
accelerator operating point (accelerator family x bit rate).  The paper's
headline metrics (FPS, FPS/W, Figs. 10-11) therefore fall out of serving
telemetry directly, amortization over the batch included: ``simulate``
spreads per-round overheads (retune + weight-DAC writes + TIA fill) over
the batch's frames exactly as Section VI-A describes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cnn.layers import ConvKind, LayerSpec
from ..core import simulator as sim
from ..core.tpc import AcceleratorConfig, build_accelerator


@dataclasses.dataclass(frozen=True)
class HardwarePoint:
    """One modeled operating point: accelerator family x DAC bit rate."""
    accelerator: str = "RMAM"
    bit_rate_gbps: float = 1.0

    @property
    def label(self) -> str:
        return f"{self.accelerator}@{self.bit_rate_gbps:g}G"


DEFAULT_HW_POINTS: Tuple[HardwarePoint, ...] = (
    HardwarePoint("RMAM", 1.0),
    HardwarePoint("MAM", 1.0),
)


@dataclasses.dataclass(frozen=True)
class HwCost:
    """Modeled per-frame cost of one served batch at one operating point."""
    fps: float
    fps_per_watt: float
    frame_latency_s: float
    energy_per_frame_j: float


@dataclasses.dataclass(frozen=True)
class ShardCost:
    """One dispatched shard, costed at its instance's operating point."""
    instance: str
    batch_size: int
    point: str                          # hardware point label
    exec_s: float                       # wall-clock shard time
    cost: HwCost


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    model: str
    batch_size: int
    t_formed: float
    exec_s: float                       # wall-clock kernel time
    queue_waits_s: Tuple[float, ...]    # per request
    latencies_s: Tuple[float, ...]      # submit -> results ready, per request
    hw: Dict[str, HwCost]               # point label -> modeled cost
    shards: Tuple[ShardCost, ...] = ()  # sharded dispatch (empty if single)
    #: per-batch activation-stream footprint, a *modeled* metric like the
    #: hw costs above: every DIV element the batch pushes through the
    #: engine, priced at the quantized lattice width (int8 for SC/PC/FC,
    #: int32 on the depthwise VPU path — see activation_stream_bytes) vs
    #: a float-domain engine's f32 streams.  NOT the host kernels' HBM
    #: pass count — that model lives in benchmarks/kernel_bench.
    #: (both 0 when the server didn't pass exec_specs)
    act_stream_bytes_int8: int = 0
    act_stream_bytes_f32: int = 0


def activation_stream_elements(specs: Sequence[LayerSpec]) -> int:
    """DIV-stream elements one frame pushes through a layer table.

    SC/PC/FC layers share one (P, S) DIV stream across their F kernels;
    a depthwise layer streams a separate (P, K*K) window per channel.
    The single home of the stream-element formula —
    ``activation_stream_bytes`` prices these same elements per domain.
    """
    return sum(s.n_positions * s.dkv_size * (1 if s.shares_div else s.f)
               for s in specs)


def activation_stream_bytes(specs: Sequence[LayerSpec]) -> Tuple[int, int]:
    """(quantized-domain, f32-domain) activation-stream bytes per frame.

    A *modeled* footprint in the same spirit as telemetry's simulator
    costs: each DIV element priced at the width of its quantized lattice
    — int8 (1 byte) for SC/PC/FC streams, int32 (4 bytes, no saving) on
    the depthwise VPU path — against a float-domain engine streaming
    every element as f32.  This is what a quantized-domain accelerator's
    DACs move per frame, not the host Pallas kernels' HBM pass count
    (absmax reads, raw-f32 fetches); that per-pass model lives in
    benchmarks/kernel_bench._q8_hbm_bytes.
    """
    q = f32 = 0
    for s in specs:
        n = activation_stream_elements((s,))
        q += n * (4 if s.kind is ConvKind.DC else 1)
        f32 += n * 4
    return q, f32


class TelemetryLog:
    def __init__(self, points: Sequence[HardwarePoint] = DEFAULT_HW_POINTS):
        self.points = tuple(points)
        self._acc: Dict[str, AcceleratorConfig] = {
            p.label: build_accelerator(p.accelerator, p.bit_rate_gbps)
            for p in self.points}
        self.records: List[BatchRecord] = []
        # (model, batch_size, point label) fully determines the modeled
        # cost (a model's sim_specs are fixed); memo so the serving loop
        # never re-walks a paper-scale layer table for a repeat batch shape
        self._hw_memo: Dict[Tuple[str, int, str], HwCost] = {}
        self._model_specs: Dict[str, Tuple[LayerSpec, ...]] = {}
        # live fleet-health provider (dispatcher + admission control);
        # summary() snapshots it so the report carries retry/timeout/
        # shed/quarantine counters and per-instance state
        self._fleet_source: Optional[Callable[[], Dict]] = None

    def attach_fleet(self, source: Callable[[], Dict]) -> None:
        """Register the live fleet-health provider for summary()["fleet"].

        ``source`` is called at summary time (a snapshot, not a copy), so
        the report always reflects the fleet's current quarantine state
        and cumulative retry/timeout/shed counters.
        """
        self._fleet_source = source

    def _accelerator(self, point: HardwarePoint) -> AcceleratorConfig:
        """The built accelerator for a point (fleet points added lazily)."""
        acc = self._acc.get(point.label)
        if acc is None:
            acc = build_accelerator(point.accelerator, point.bit_rate_gbps)
            self._acc[point.label] = acc
        return acc

    def _hw_cost(self, model: str, sim_specs: Sequence[LayerSpec],
                 batch_size: int, point: HardwarePoint) -> HwCost:
        specs = tuple(sim_specs)
        seen = self._model_specs.setdefault(model, specs)
        if seen != specs:
            raise ValueError(
                f"model {model!r} recorded with a different sim_specs "
                f"table than before; one spec table per model name")
        key = (model, batch_size, point.label)
        cost = self._hw_memo.get(key)
        if cost is None:
            rep = sim.simulate(self._accelerator(point), sim_specs,
                               batch=batch_size)
            cost = HwCost(fps=rep.fps, fps_per_watt=rep.fps_per_watt,
                          frame_latency_s=rep.frame_latency_s,
                          energy_per_frame_j=rep.energy_per_frame_j)
            self._hw_memo[key] = cost
        return cost

    def record_batch(self, model: str, sim_specs: Sequence[LayerSpec],
                     batch_size: int, t_formed: float, exec_s: float,
                     queue_waits_s: Sequence[float],
                     latencies_s: Sequence[float],
                     shards: Sequence[Tuple[str, int, HardwarePoint,
                                            float]] = (),
                     exec_specs: Optional[Sequence[LayerSpec]] = None,
                     ) -> BatchRecord:
        """Record one served batch (and, when sharded, each shard).

        ``shards`` rows are (instance name, shard size, the instance's
        hardware point, wall shard seconds) — each shard is costed through
        the simulator at its *own* operating point, so a heterogeneous
        fleet reports per-instance modeled FPS/FPS-per-W.

        ``exec_specs`` is the layer table the engine actually ran (not
        the paper-scale ``sim_specs``); when given, the batch's
        activation-stream bytes are recorded as int8 (what the
        quantized-domain kernels stream) vs the f32 estimate of the same
        stream, so the HBM saving shows up in ``summary()``.
        """
        hw = {p.label: self._hw_cost(model, sim_specs, batch_size, p)
              for p in self.points}
        shard_costs = tuple(
            ShardCost(instance=name, batch_size=size, point=point.label,
                      exec_s=shard_exec_s,
                      cost=self._hw_cost(model, sim_specs, size, point))
            for name, size, point, shard_exec_s in shards)
        by_q = by_f = 0
        if exec_specs is not None:
            by_q, by_f = activation_stream_bytes(exec_specs)
        rec = BatchRecord(model=model, batch_size=batch_size,
                          t_formed=t_formed, exec_s=exec_s,
                          queue_waits_s=tuple(queue_waits_s),
                          latencies_s=tuple(latencies_s), hw=dict(hw),
                          shards=shard_costs,
                          act_stream_bytes_int8=batch_size * by_q,
                          act_stream_bytes_f32=batch_size * by_f)
        self.records.append(rec)
        return rec

    # -- aggregation ------------------------------------------------------

    def _latencies(self, model: Optional[str] = None) -> List[float]:
        return [lat for r in self.records
                if model is None or r.model == model
                for lat in r.latencies_s]

    def latency_percentile(self, q: float,
                           model: Optional[str] = None) -> float:
        lats = self._latencies(model)
        if not lats:
            raise ValueError("no served requests to take a percentile of")
        return float(np.percentile(np.asarray(lats), q))

    def _hw_summary(self, records: List[BatchRecord]) -> Dict[str, Dict]:
        """Frame-weighted modeled metrics per operating point."""
        out: Dict[str, Dict] = {}
        for p in self.points:
            frames = sum(r.batch_size for r in records)
            if frames == 0:
                continue
            fps = sum(r.hw[p.label].fps * r.batch_size
                      for r in records) / frames
            fpw = sum(r.hw[p.label].fps_per_watt * r.batch_size
                      for r in records) / frames
            out[p.label] = {"modeled_fps": fps, "modeled_fps_per_watt": fpw}
        return out

    def _dispatch_summary(self, records: List[BatchRecord]) -> Dict[str, Dict]:
        """Per-instance view of sharded dispatch (empty when unsharded)."""
        out: Dict[str, Dict] = {}
        for r in records:
            for s in r.shards:
                d = out.setdefault(s.instance, {
                    "point": s.point, "frames": 0, "shards": 0,
                    "exec_s": 0.0, "_fps_frames": 0.0, "_fpw_frames": 0.0})
                d["frames"] += s.batch_size
                d["shards"] += 1
                d["exec_s"] += s.exec_s
                d["_fps_frames"] += s.cost.fps * s.batch_size
                d["_fpw_frames"] += s.cost.fps_per_watt * s.batch_size
        for d in out.values():
            d["modeled_fps"] = d.pop("_fps_frames") / d["frames"]
            d["modeled_fps_per_watt"] = d.pop("_fpw_frames") / d["frames"]
        return out

    @staticmethod
    def _act_stream_summary(records: List[BatchRecord]) -> Dict[str, float]:
        """Total activation-stream bytes served: quantized lattice vs f32.

        Records without exec_specs contribute zero to both sides; the
        ratio reports the modeled stream saving of quantized-domain
        execution (activation_stream_bytes).
        """
        int8 = sum(r.act_stream_bytes_int8 for r in records)
        f32 = sum(r.act_stream_bytes_f32 for r in records)
        return {"int8_bytes": int8, "f32_bytes": f32,
                "ratio": f32 / int8 if int8 else 0.0}

    def summary(self) -> Dict:
        """Serving report: wall-clock throughput/latency + modeled hardware.

        ``images_per_s_wall`` is sustained throughput over the serving span
        (first batch formed -> last batch done); per-model blocks carry the
        same metrics restricted to that model's batches.
        """
        if not self.records:
            return {"requests": 0, "batches": 0}
        n_req = sum(r.batch_size for r in self.records)
        t0 = min(r.t_formed for r in self.records)
        t1 = max(r.t_formed + r.exec_s for r in self.records)
        span = max(t1 - t0, 1e-9)
        out = {
            "requests": n_req,
            "batches": len(self.records),
            "mean_batch_size": n_req / len(self.records),
            "span_s": span,
            "images_per_s_wall": n_req / span,
            "latency_p50_s": self.latency_percentile(50),
            "latency_p99_s": self.latency_percentile(99),
            "hardware": self._hw_summary(self.records),
            "dispatch": self._dispatch_summary(self.records),
            "fleet": (self._fleet_source() if self._fleet_source is not None
                      else {}),
            "activation_stream": self._act_stream_summary(self.records),
            "models": {},
        }
        for model in sorted({r.model for r in self.records}):
            recs = [r for r in self.records if r.model == model]
            imgs = sum(r.batch_size for r in recs)
            out["models"][model] = {
                "requests": imgs,
                "batches": len(recs),
                "mean_batch_size": imgs / len(recs),
                "latency_p50_s": self.latency_percentile(50, model),
                "latency_p99_s": self.latency_percentile(99, model),
                "hardware": self._hw_summary(recs),
                "activation_stream": self._act_stream_summary(recs),
            }
        return out
