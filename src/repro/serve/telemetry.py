"""Hardware-time telemetry: wall clock AND modeled photonic time per batch.

Every served batch is costed twice: the wall-clock execution time of the
Pallas kernels on the host, and — through core/simulator.simulate — the
cycle-true time/energy the batch would take on each configured photonic
accelerator operating point (accelerator family x bit rate).  The paper's
headline metrics (FPS, FPS/W, Figs. 10-11) therefore fall out of serving
telemetry directly, amortization over the batch included: ``simulate``
spreads per-round overheads (retune + weight-DAC writes + TIA fill) over
the batch's frames exactly as Section VI-A describes.

The log is built to run unbounded: every aggregate ``summary()`` reports
is maintained incrementally as batches stream in, request latencies and
queue waits go into log-bucketed streaming histograms
(:class:`repro.obs.metrics.LogHistogram` — bounded memory, p50/p99 within
one bucket of exact), and the per-batch ``records`` list keeps only the
newest ``max_records`` entries for inspection.  Each batch additionally
accrues per-layer hardware attribution
(:class:`repro.obs.attribution.LayerAttribution`): modeled time, energy
and VDPE utilization by named layer, the Viterbi plan's operating points
and reconfiguration switches — surfaced as ``summary()["layers"]``.
"""
from __future__ import annotations

import copy
import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cnn.layers import ConvKind, LayerSpec
from ..core import simulator as sim
from ..core.operating_point import OperatingPoint
from ..core.tpc import AcceleratorConfig
from ..obs.attribution import LayerAttribution
from ..obs.metrics import LogHistogram, MetricsRegistry


@dataclasses.dataclass(frozen=True)
class HardwarePoint(OperatingPoint):
    """Deprecated alias of :class:`repro.core.OperatingPoint`.

    Telemetry's original point type carried only (accelerator family x
    DAC bit rate); those are exactly the leading fields of the unified
    ``OperatingPoint``, so historical positional construction —
    ``HardwarePoint("AMM", 5.0)`` — still works.  New code should use
    ``OperatingPoint`` directly; constructing this alias warns (and the
    repo's pytest config promotes the warning to an error, so deprecated
    paths cannot creep back into serve/benchmarks).
    """

    def __post_init__(self) -> None:
        warnings.warn(
            "serve.HardwarePoint is deprecated; use "
            "repro.core.OperatingPoint (same leading fields, same "
            "positional construction)",
            DeprecationWarning, stacklevel=2)


DEFAULT_HW_POINTS: Tuple[OperatingPoint, ...] = (
    OperatingPoint("RMAM", 1.0),
    OperatingPoint("MAM", 1.0),
)


@dataclasses.dataclass(frozen=True)
class HwCost:
    """Modeled per-frame cost of one served batch at one operating point."""
    fps: float
    fps_per_watt: float
    frame_latency_s: float
    energy_per_frame_j: float
    #: per-frame joules by ledger component (tpc.LEDGER_COMPONENTS rows;
    #: sums to ``energy_per_frame_j`` up to float rounding)
    energy_components_j: Dict[str, float] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ShardCost:
    """One dispatched shard, costed at its instance's operating point."""
    instance: str
    batch_size: int
    point: str                          # hardware point label
    exec_s: float                       # wall-clock shard time
    cost: HwCost


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    model: str
    batch_size: int
    t_formed: float
    exec_s: float                       # wall-clock kernel time
    queue_waits_s: Tuple[float, ...]    # per request
    latencies_s: Tuple[float, ...]      # submit -> results ready, per request
    hw: Dict[str, HwCost]               # point label -> modeled cost
    shards: Tuple[ShardCost, ...] = ()  # sharded dispatch (empty if single)
    #: per-request priority class, aligned with ``latencies_s`` (empty
    #: when the server predates priorities or none were passed)
    priorities: Tuple[str, ...] = ()
    #: per-batch activation-stream footprint, a *modeled* metric like the
    #: hw costs above: every DIV element the batch pushes through the
    #: engine, priced at the quantized lattice width (int8 for SC/PC/FC,
    #: int32 on the depthwise VPU path — see activation_stream_bytes) vs
    #: a float-domain engine's f32 streams.  NOT the host kernels' HBM
    #: pass count — that model lives in benchmarks/kernel_bench.
    #: (both 0 when the server didn't pass exec_specs)
    act_stream_bytes_int8: int = 0
    act_stream_bytes_f32: int = 0


def activation_stream_elements(specs: Sequence[LayerSpec]) -> int:
    """DIV-stream elements one frame pushes through a layer table.

    SC/PC/FC layers share one (P, S) DIV stream across their F kernels;
    a depthwise layer streams a separate (P, K*K) window per channel.
    The single home of the stream-element formula —
    ``activation_stream_bytes`` prices these same elements per domain.
    """
    return sum(s.n_positions * s.dkv_size * (1 if s.shares_div else s.f)
               for s in specs)


def activation_stream_bytes(specs: Sequence[LayerSpec]) -> Tuple[int, int]:
    """(quantized-domain, f32-domain) activation-stream bytes per frame.

    A *modeled* footprint in the same spirit as telemetry's simulator
    costs: each DIV element priced at the width of its quantized lattice
    — int8 (1 byte) for SC/PC/FC streams, int32 (4 bytes, no saving) on
    the depthwise VPU path — against a float-domain engine streaming
    every element as f32.  This is what a quantized-domain accelerator's
    DACs move per frame, not the host Pallas kernels' HBM pass count
    (absmax reads, raw-f32 fetches); that per-pass model lives in
    benchmarks/kernel_bench._q8_hbm_bytes.
    """
    q = f32 = 0
    for s in specs:
        n = activation_stream_elements((s,))
        q += n * (4 if s.kind is ConvKind.DC else 1)
        f32 += n * 4
    return q, f32


@dataclasses.dataclass
class _Agg:
    """Running per-scope aggregates (one global, one per model)."""
    requests: int = 0
    batches: int = 0
    t0: float = np.inf
    t1: float = -np.inf
    # point label -> {"fps": fps*frames, "fpw": fps_per_watt*frames,
    #                 "energy": J/frame*frames, "frames": frames,
    #                 "components": {ledger row -> J/frame*frames}}
    hw: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    act_int8: int = 0
    act_f32: int = 0


class TelemetryLog:
    def __init__(self, points: Sequence[OperatingPoint] = DEFAULT_HW_POINTS,
                 max_records: int = 4096,
                 metrics: Optional[MetricsRegistry] = None):
        self.points = tuple(points)
        self._acc: Dict[str, AcceleratorConfig] = {
            p.label: p.to_accelerator() for p in self.points}
        #: newest ``max_records`` batches, for inspection/debugging; every
        #: summary aggregate is maintained incrementally and stays exact
        #: after old records fall off
        self.records: List[BatchRecord] = []
        self.max_records = max_records
        self._dropped_records = 0
        # (model, batch_size, point label) fully determines the modeled
        # cost (a model's sim_specs are fixed); memo so the serving loop
        # never re-walks a paper-scale layer table for a repeat batch shape
        self._hw_memo: Dict[Tuple[str, int, str], HwCost] = {}
        # same key at the primary point -> per-frame LayerCost rows
        self._layer_memo: Dict[Tuple[str, int, str],
                               Tuple[sim.LayerCost, ...]] = {}
        self._model_specs: Dict[str, Tuple[LayerSpec, ...]] = {}
        # live fleet-health provider (dispatcher + admission control);
        # summary() deep-copies its report so serialized summaries can't
        # race with in-flight dispatch mutating the counters
        self._fleet_source: Optional[Callable[[], Dict]] = None
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.layers = LayerAttribution()
        self._agg = _Agg()
        self._model_agg: Dict[str, _Agg] = {}
        self._dispatch_agg: Dict[str, Dict] = {}
        self._lat_hist = self.metrics.histogram(
            "serve_request_latency_seconds",
            "submit-to-results request latency")
        self._wait_hist = self.metrics.histogram(
            "serve_queue_wait_seconds", "submit-to-batch-formed queue wait")
        self._model_lat_hist: Dict[str, LogHistogram] = {}
        # per-priority-class latency: streaming histogram + request count
        # (the overload harness's per-class p50/p99 source)
        self._class_lat_hist: Dict[str, LogHistogram] = {}
        self._class_requests: Dict[str, int] = {}

    def attach_fleet(self, source: Callable[[], Dict]) -> None:
        """Register the live fleet-health provider for summary()["fleet"].

        ``source`` is called at summary time and its report deep-copied,
        so the summary reflects the fleet's current quarantine state and
        cumulative retry/timeout/shed counters without handing callers a
        live reference into the dispatcher's mutable state.
        """
        self._fleet_source = source

    def _accelerator(self, point: OperatingPoint) -> AcceleratorConfig:
        """The built accelerator for a point (fleet points added lazily)."""
        acc = self._acc.get(point.label)
        if acc is None:
            acc = point.to_accelerator()
            self._acc[point.label] = acc
        return acc

    def _check_specs(self, model: str,
                     sim_specs: Sequence[LayerSpec]) -> Tuple[LayerSpec, ...]:
        specs = tuple(sim_specs)
        seen = self._model_specs.setdefault(model, specs)
        if seen != specs:
            raise ValueError(
                f"model {model!r} recorded with a different sim_specs "
                f"table than before; one spec table per model name")
        return specs

    def _hw_cost(self, model: str, sim_specs: Sequence[LayerSpec],
                 batch_size: int, point: OperatingPoint) -> HwCost:
        self._check_specs(model, sim_specs)
        key = (model, batch_size, point.label)
        cost = self._hw_memo.get(key)
        if cost is None:
            rep = sim.simulate(self._accelerator(point), sim_specs,
                               batch=batch_size)
            cost = HwCost(fps=rep.fps, fps_per_watt=rep.fps_per_watt,
                          frame_latency_s=rep.frame_latency_s,
                          energy_per_frame_j=rep.energy_per_frame_j,
                          energy_components_j=rep.energy_breakdown())
            self._hw_memo[key] = cost
        return cost

    def _layer_rows(self, model: str, sim_specs: Sequence[LayerSpec],
                    batch_size: int, point: OperatingPoint,
                    ) -> Tuple[sim.LayerCost, ...]:
        """Per-frame LayerCost rows at a point (simulate_layer is memoized
        upstream, so the repeat-batch-shape case costs a dict lookup)."""
        key = (model, batch_size, point.label)
        rows = self._layer_memo.get(key)
        if rows is None:
            rep = sim.simulate(self._accelerator(point), tuple(sim_specs),
                               batch=batch_size)
            rows = tuple(rep.layer_costs())
            self._layer_memo[key] = rows
        return rows

    def record_batch(self, model: str, sim_specs: Sequence[LayerSpec],
                     batch_size: int, t_formed: float, exec_s: float,
                     queue_waits_s: Sequence[float],
                     latencies_s: Sequence[float],
                     shards: Sequence[Tuple[str, int, OperatingPoint,
                                            float]] = (),
                     exec_specs: Optional[Sequence[LayerSpec]] = None,
                     op_points: Optional[Dict[str, str]] = None,
                     reconfig_switches: int = 0,
                     priorities: Sequence[str] = (),
                     ) -> BatchRecord:
        """Record one served batch (and, when sharded, each shard).

        ``shards`` rows are (instance name, shard size, the instance's
        hardware point, wall shard seconds) — each shard is costed through
        the simulator at its *own* operating point, so a heterogeneous
        fleet reports per-instance modeled FPS/FPS-per-W.

        ``exec_specs`` is the layer table the engine actually ran (not
        the paper-scale ``sim_specs``); when given, the batch's
        activation-stream bytes are recorded as int8 (what the
        quantized-domain kernels stream) vs the f32 estimate of the same
        stream, so the HBM saving shows up in ``summary()``.

        ``op_points``/``reconfig_switches`` carry the Viterbi plan's
        per-layer operating points and switch count into the per-layer
        attribution (``summary()["layers"]``).
        """
        hw = {p.label: self._hw_cost(model, sim_specs, batch_size, p)
              for p in self.points}
        shard_costs = tuple(
            ShardCost(instance=name, batch_size=size, point=point.label,
                      exec_s=shard_exec_s,
                      cost=self._hw_cost(model, sim_specs, size, point))
            for name, size, point, shard_exec_s in shards)
        by_q = by_f = 0
        if exec_specs is not None:
            by_q, by_f = activation_stream_bytes(exec_specs)
        priorities = tuple(priorities)
        if priorities and len(priorities) != len(tuple(latencies_s)):
            raise ValueError(
                f"priorities ({len(priorities)}) must align with "
                f"latencies_s ({len(tuple(latencies_s))})")
        rec = BatchRecord(model=model, batch_size=batch_size,
                          t_formed=t_formed, exec_s=exec_s,
                          queue_waits_s=tuple(queue_waits_s),
                          latencies_s=tuple(latencies_s), hw=dict(hw),
                          shards=shard_costs,
                          act_stream_bytes_int8=batch_size * by_q,
                          act_stream_bytes_f32=batch_size * by_f,
                          priorities=priorities)
        self.records.append(rec)
        if len(self.records) > self.max_records:
            drop = len(self.records) - self.max_records
            del self.records[:drop]
            self._dropped_records += drop
        self._accrue(rec, op_points, reconfig_switches, sim_specs)
        return rec

    def _accrue(self, rec: BatchRecord, op_points: Optional[Dict[str, str]],
                reconfig_switches: int,
                sim_specs: Sequence[LayerSpec]) -> None:
        """Fold one record into every running aggregate."""
        for agg in (self._agg, self._model_agg.setdefault(rec.model,
                                                          _Agg())):
            agg.requests += rec.batch_size
            agg.batches += 1
            agg.t0 = min(agg.t0, rec.t_formed)
            agg.t1 = max(agg.t1, rec.t_formed + rec.exec_s)
            for label, cost in rec.hw.items():
                row = agg.hw.setdefault(label, {
                    "fps": 0.0, "fpw": 0.0, "energy": 0.0, "frames": 0,
                    "components": {}})
                row["fps"] += cost.fps * rec.batch_size
                row["fpw"] += cost.fps_per_watt * rec.batch_size
                row["energy"] += cost.energy_per_frame_j * rec.batch_size
                row["frames"] += rec.batch_size
                for c, j in cost.energy_components_j.items():
                    row["components"][c] = (row["components"].get(c, 0.0)
                                            + j * rec.batch_size)
            agg.act_int8 += rec.act_stream_bytes_int8
            agg.act_f32 += rec.act_stream_bytes_f32
        for s in rec.shards:
            d = self._dispatch_agg.setdefault(s.instance, {
                "point": s.point, "frames": 0, "shards": 0,
                "exec_s": 0.0, "fps_frames": 0.0, "fpw_frames": 0.0,
                "energy_frames": 0.0, "components": {}})
            d["frames"] += s.batch_size
            d["shards"] += 1
            d["exec_s"] += s.exec_s
            d["fps_frames"] += s.cost.fps * s.batch_size
            d["fpw_frames"] += s.cost.fps_per_watt * s.batch_size
            d["energy_frames"] += s.cost.energy_per_frame_j * s.batch_size
            for c, j in s.cost.energy_components_j.items():
                d["components"][c] = (d["components"].get(c, 0.0)
                                      + j * s.batch_size)
        # streaming histograms + counters (bounded, scrape-ready)
        mhist = self._model_lat_hist.get(rec.model)
        if mhist is None:
            mhist = self._model_lat_hist[rec.model] = self.metrics.histogram(
                "serve_request_latency_seconds", model=rec.model)
        for lat in rec.latencies_s:
            self._lat_hist.record(lat)
            mhist.record(lat)
        for cls, lat in zip(rec.priorities, rec.latencies_s):
            chist = self._class_lat_hist.get(cls)
            if chist is None:
                chist = self._class_lat_hist[cls] = self.metrics.histogram(
                    "serve_class_latency_seconds",
                    "request latency by priority class", priority=cls)
            chist.record(lat)
            self._class_requests[cls] = self._class_requests.get(cls, 0) + 1
        for w in rec.queue_waits_s:
            self._wait_hist.record(w)
        self.metrics.counter("serve_requests_total",
                             "requests served to completion",
                             model=rec.model).inc(rec.batch_size)
        self.metrics.counter("serve_batches_total", "batches served",
                             model=rec.model).inc()
        for s in rec.shards:
            self.metrics.counter("serve_shard_frames_total",
                                 "frames dispatched per fleet instance",
                                 instance=s.instance).inc(s.batch_size)
        # per-layer hardware attribution at the primary operating point
        primary = self.points[0]
        rows = self._layer_rows(rec.model, sim_specs, rec.batch_size,
                                primary)
        self.layers.record(
            rec.model, primary.label, rows, frames=rec.batch_size,
            frame_latency_s=rec.hw[primary.label].frame_latency_s,
            op_points=op_points, reconfig_switches=reconfig_switches)

    def record_sdc(self, model: str, detections: int,
                   corrupted_frames: int) -> None:
        """Fold one batch's silent-data-corruption outcome into counters.

        ``detections`` — shards flagged by the dispatcher's integrity
        checks (ABFT / range guard / weight checksum / canary) during the
        batch; each was re-executed on a healthy instance before results
        reached requesters.  ``corrupted_frames`` — the batch frames
        attributed to those flagged shards.
        """
        if detections:
            self.metrics.counter(
                "serve_sdc_detections_total",
                "shards flagged corrupted by integrity checks",
                model=model).inc(detections)
        if corrupted_frames:
            self.metrics.counter(
                "serve_sdc_corrupted_frames_total",
                "frames attributed to flagged-and-recovered shards",
                model=model).inc(corrupted_frames)

    def reset(self) -> None:
        """Forget everything served (model spec tables and memos stay)."""
        self.records.clear()
        self._dropped_records = 0
        self._agg = _Agg()
        self._model_agg.clear()
        self._dispatch_agg.clear()
        self._model_lat_hist.clear()
        self._class_lat_hist.clear()
        self._class_requests.clear()
        self.layers.reset()
        self.metrics.reset()
        self._lat_hist = self.metrics.histogram(
            "serve_request_latency_seconds",
            "submit-to-results request latency")
        self._wait_hist = self.metrics.histogram(
            "serve_queue_wait_seconds", "submit-to-batch-formed queue wait")

    # -- aggregation ------------------------------------------------------

    def _latencies(self, model: Optional[str] = None) -> List[float]:
        return [lat for r in self.records
                if model is None or r.model == model
                for lat in r.latencies_s]

    def latency_percentile(self, q: float,
                           model: Optional[str] = None) -> float:
        """Request-latency percentile.

        Exact (numpy over the retained records) while no records have been
        dropped; once the record ring has trimmed, falls back to the
        streaming histogram — still within one bucket of exact.
        """
        if self._dropped_records == 0:
            lats = self._latencies(model)
            if not lats:
                raise ValueError("no served requests to take a percentile of")
            return float(np.percentile(np.asarray(lats), q))
        hist = (self._lat_hist if model is None
                else self._model_lat_hist.get(model))
        if hist is None or hist.count == 0:
            raise ValueError("no served requests to take a percentile of")
        return hist.percentile(q)

    def class_latency_percentile(self, q: float, priority: str) -> float:
        """Request-latency percentile for one priority class.

        Exact (numpy over the retained records' aligned priority rows)
        while nothing has been dropped; falls back to the per-class
        streaming histogram after the record ring trims.
        """
        if self._dropped_records == 0:
            lats = [lat for r in self.records
                    for cls, lat in zip(r.priorities, r.latencies_s)
                    if cls == priority]
            if not lats:
                raise ValueError(
                    f"no served {priority!r}-class requests to take a "
                    f"percentile of")
            return float(np.percentile(np.asarray(lats), q))
        hist = self._class_lat_hist.get(priority)
        if hist is None or hist.count == 0:
            raise ValueError(
                f"no served {priority!r}-class requests to take a "
                f"percentile of")
        return hist.percentile(q)

    @staticmethod
    def _hw_summary(agg: _Agg) -> Dict[str, Dict]:
        """Frame-weighted modeled metrics per operating point.

        (The frame total is per point-row here by construction — the old
        per-record walk recomputed the same ``frames`` sum once per point.)
        """
        out: Dict[str, Dict] = {}
        for label, row in agg.hw.items():
            frames = row["frames"]
            if frames == 0:
                continue
            out[label] = {
                "modeled_fps": row["fps"] / frames,
                "modeled_fps_per_watt": row["fpw"] / frames,
                "modeled_energy_per_frame_j": row["energy"] / frames,
                "energy_components_j": {c: j / frames for c, j
                                        in row["components"].items()}}
        return out

    def _dispatch_summary(self) -> Dict[str, Dict]:
        """Per-instance view of sharded dispatch (empty when unsharded)."""
        out: Dict[str, Dict] = {}
        for inst, d in self._dispatch_agg.items():
            out[inst] = {
                "point": d["point"], "frames": d["frames"],
                "shards": d["shards"], "exec_s": d["exec_s"],
                "modeled_fps": d["fps_frames"] / d["frames"],
                "modeled_fps_per_watt": d["fpw_frames"] / d["frames"],
                "modeled_energy_per_frame_j": (d["energy_frames"]
                                               / d["frames"]),
                "energy_components_j": {c: j / d["frames"] for c, j
                                        in d["components"].items()}}
        return out

    @staticmethod
    def _act_stream_summary(int8: int, f32: int) -> Dict[str, object]:
        """Total activation-stream bytes served: quantized lattice vs f32.

        Records without exec_specs contribute zero to both sides; the
        ratio reports the modeled stream saving of quantized-domain
        execution (activation_stream_bytes).  With no quantized bytes
        recorded there is no measured saving, so the ratio is ``None``
        rather than a 0.0 that reads as "no saving" downstream.
        """
        return {"int8_bytes": int8, "f32_bytes": f32,
                "ratio": f32 / int8 if int8 else None}

    def summary(self, top_k: int = 5) -> Dict:
        """Serving report: wall-clock throughput/latency + modeled hardware.

        ``images_per_s_wall`` is sustained throughput over the serving span
        (first batch formed -> last batch done); per-model blocks carry the
        same metrics restricted to that model's batches.  ``layers`` is the
        per-layer hardware attribution (modeled time/energy/utilization by
        named layer, operating points, reconfiguration switches, top-k
        hotspots).  Every block is a snapshot the caller owns.
        """
        agg = self._agg
        if agg.batches == 0:
            return {"requests": 0, "batches": 0}
        span = max(agg.t1 - agg.t0, 1e-9)
        out = {
            "requests": agg.requests,
            "batches": agg.batches,
            "mean_batch_size": agg.requests / agg.batches,
            "span_s": span,
            "images_per_s_wall": agg.requests / span,
            "latency_p50_s": self.latency_percentile(50),
            "latency_p99_s": self.latency_percentile(99),
            "queue_wait_p50_s": (self._wait_hist.percentile(50)
                                 if self._wait_hist.count else None),
            "hardware": self._hw_summary(agg),
            "dispatch": self._dispatch_summary(),
            "fleet": (copy.deepcopy(self._fleet_source())
                      if self._fleet_source is not None else {}),
            "activation_stream": self._act_stream_summary(agg.act_int8,
                                                          agg.act_f32),
            "layers": self.layers.summary(top_k),
            "classes": {
                cls: {"requests": self._class_requests.get(cls, 0),
                      "latency_p50_s": self.class_latency_percentile(
                          50, cls),
                      "latency_p99_s": self.class_latency_percentile(
                          99, cls)}
                for cls in sorted(self._class_lat_hist)},
            "models": {},
        }
        for model in sorted(self._model_agg):
            m = self._model_agg[model]
            out["models"][model] = {
                "requests": m.requests,
                "batches": m.batches,
                "mean_batch_size": m.requests / m.batches,
                "latency_p50_s": self.latency_percentile(50, model),
                "latency_p99_s": self.latency_percentile(99, model),
                "hardware": self._hw_summary(m),
                "activation_stream": self._act_stream_summary(m.act_int8,
                                                              m.act_f32),
            }
        return out
