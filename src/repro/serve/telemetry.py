"""Hardware-time telemetry: wall clock AND modeled photonic time per batch.

Every served batch is costed twice: the wall-clock execution time of the
Pallas kernels on the host, and — through core/simulator.simulate — the
cycle-true time/energy the batch would take on each configured photonic
accelerator operating point (accelerator family x bit rate).  The paper's
headline metrics (FPS, FPS/W, Figs. 10-11) therefore fall out of serving
telemetry directly, amortization over the batch included: ``simulate``
spreads per-round overheads (retune + weight-DAC writes + TIA fill) over
the batch's frames exactly as Section VI-A describes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cnn.layers import LayerSpec
from ..core import simulator as sim
from ..core.tpc import AcceleratorConfig, build_accelerator


@dataclasses.dataclass(frozen=True)
class HardwarePoint:
    """One modeled operating point: accelerator family x DAC bit rate."""
    accelerator: str = "RMAM"
    bit_rate_gbps: float = 1.0

    @property
    def label(self) -> str:
        return f"{self.accelerator}@{self.bit_rate_gbps:g}G"


DEFAULT_HW_POINTS: Tuple[HardwarePoint, ...] = (
    HardwarePoint("RMAM", 1.0),
    HardwarePoint("MAM", 1.0),
)


@dataclasses.dataclass(frozen=True)
class HwCost:
    """Modeled per-frame cost of one served batch at one operating point."""
    fps: float
    fps_per_watt: float
    frame_latency_s: float
    energy_per_frame_j: float


@dataclasses.dataclass(frozen=True)
class ShardCost:
    """One dispatched shard, costed at its instance's operating point."""
    instance: str
    batch_size: int
    point: str                          # hardware point label
    exec_s: float                       # wall-clock shard time
    cost: HwCost


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    model: str
    batch_size: int
    t_formed: float
    exec_s: float                       # wall-clock kernel time
    queue_waits_s: Tuple[float, ...]    # per request
    latencies_s: Tuple[float, ...]      # submit -> results ready, per request
    hw: Dict[str, HwCost]               # point label -> modeled cost
    shards: Tuple[ShardCost, ...] = ()  # sharded dispatch (empty if single)


class TelemetryLog:
    def __init__(self, points: Sequence[HardwarePoint] = DEFAULT_HW_POINTS):
        self.points = tuple(points)
        self._acc: Dict[str, AcceleratorConfig] = {
            p.label: build_accelerator(p.accelerator, p.bit_rate_gbps)
            for p in self.points}
        self.records: List[BatchRecord] = []
        # (model, batch_size, point label) fully determines the modeled
        # cost (a model's sim_specs are fixed); memo so the serving loop
        # never re-walks a paper-scale layer table for a repeat batch shape
        self._hw_memo: Dict[Tuple[str, int, str], HwCost] = {}
        self._model_specs: Dict[str, Tuple[LayerSpec, ...]] = {}

    def _accelerator(self, point: HardwarePoint) -> AcceleratorConfig:
        """The built accelerator for a point (fleet points added lazily)."""
        acc = self._acc.get(point.label)
        if acc is None:
            acc = build_accelerator(point.accelerator, point.bit_rate_gbps)
            self._acc[point.label] = acc
        return acc

    def _hw_cost(self, model: str, sim_specs: Sequence[LayerSpec],
                 batch_size: int, point: HardwarePoint) -> HwCost:
        specs = tuple(sim_specs)
        seen = self._model_specs.setdefault(model, specs)
        if seen != specs:
            raise ValueError(
                f"model {model!r} recorded with a different sim_specs "
                f"table than before; one spec table per model name")
        key = (model, batch_size, point.label)
        cost = self._hw_memo.get(key)
        if cost is None:
            rep = sim.simulate(self._accelerator(point), sim_specs,
                               batch=batch_size)
            cost = HwCost(fps=rep.fps, fps_per_watt=rep.fps_per_watt,
                          frame_latency_s=rep.frame_latency_s,
                          energy_per_frame_j=rep.energy_per_frame_j)
            self._hw_memo[key] = cost
        return cost

    def record_batch(self, model: str, sim_specs: Sequence[LayerSpec],
                     batch_size: int, t_formed: float, exec_s: float,
                     queue_waits_s: Sequence[float],
                     latencies_s: Sequence[float],
                     shards: Sequence[Tuple[str, int, HardwarePoint,
                                            float]] = ()) -> BatchRecord:
        """Record one served batch (and, when sharded, each shard).

        ``shards`` rows are (instance name, shard size, the instance's
        hardware point, wall shard seconds) — each shard is costed through
        the simulator at its *own* operating point, so a heterogeneous
        fleet reports per-instance modeled FPS/FPS-per-W.
        """
        hw = {p.label: self._hw_cost(model, sim_specs, batch_size, p)
              for p in self.points}
        shard_costs = tuple(
            ShardCost(instance=name, batch_size=size, point=point.label,
                      exec_s=shard_exec_s,
                      cost=self._hw_cost(model, sim_specs, size, point))
            for name, size, point, shard_exec_s in shards)
        rec = BatchRecord(model=model, batch_size=batch_size,
                          t_formed=t_formed, exec_s=exec_s,
                          queue_waits_s=tuple(queue_waits_s),
                          latencies_s=tuple(latencies_s), hw=dict(hw),
                          shards=shard_costs)
        self.records.append(rec)
        return rec

    # -- aggregation ------------------------------------------------------

    def _latencies(self, model: Optional[str] = None) -> List[float]:
        return [lat for r in self.records
                if model is None or r.model == model
                for lat in r.latencies_s]

    def latency_percentile(self, q: float,
                           model: Optional[str] = None) -> float:
        lats = self._latencies(model)
        if not lats:
            raise ValueError("no served requests to take a percentile of")
        return float(np.percentile(np.asarray(lats), q))

    def _hw_summary(self, records: List[BatchRecord]) -> Dict[str, Dict]:
        """Frame-weighted modeled metrics per operating point."""
        out: Dict[str, Dict] = {}
        for p in self.points:
            frames = sum(r.batch_size for r in records)
            if frames == 0:
                continue
            fps = sum(r.hw[p.label].fps * r.batch_size
                      for r in records) / frames
            fpw = sum(r.hw[p.label].fps_per_watt * r.batch_size
                      for r in records) / frames
            out[p.label] = {"modeled_fps": fps, "modeled_fps_per_watt": fpw}
        return out

    def _dispatch_summary(self, records: List[BatchRecord]) -> Dict[str, Dict]:
        """Per-instance view of sharded dispatch (empty when unsharded)."""
        out: Dict[str, Dict] = {}
        for r in records:
            for s in r.shards:
                d = out.setdefault(s.instance, {
                    "point": s.point, "frames": 0, "shards": 0,
                    "exec_s": 0.0, "_fps_frames": 0.0, "_fpw_frames": 0.0})
                d["frames"] += s.batch_size
                d["shards"] += 1
                d["exec_s"] += s.exec_s
                d["_fps_frames"] += s.cost.fps * s.batch_size
                d["_fpw_frames"] += s.cost.fps_per_watt * s.batch_size
        for d in out.values():
            d["modeled_fps"] = d.pop("_fps_frames") / d["frames"]
            d["modeled_fps_per_watt"] = d.pop("_fpw_frames") / d["frames"]
        return out

    def summary(self) -> Dict:
        """Serving report: wall-clock throughput/latency + modeled hardware.

        ``images_per_s_wall`` is sustained throughput over the serving span
        (first batch formed -> last batch done); per-model blocks carry the
        same metrics restricted to that model's batches.
        """
        if not self.records:
            return {"requests": 0, "batches": 0}
        n_req = sum(r.batch_size for r in self.records)
        t0 = min(r.t_formed for r in self.records)
        t1 = max(r.t_formed + r.exec_s for r in self.records)
        span = max(t1 - t0, 1e-9)
        out = {
            "requests": n_req,
            "batches": len(self.records),
            "mean_batch_size": n_req / len(self.records),
            "span_s": span,
            "images_per_s_wall": n_req / span,
            "latency_p50_s": self.latency_percentile(50),
            "latency_p99_s": self.latency_percentile(99),
            "hardware": self._hw_summary(self.records),
            "dispatch": self._dispatch_summary(self.records),
            "models": {},
        }
        for model in sorted({r.model for r in self.records}):
            recs = [r for r in self.records if r.model == model]
            imgs = sum(r.batch_size for r in recs)
            out["models"][model] = {
                "requests": imgs,
                "batches": len(recs),
                "mean_batch_size": imgs / len(recs),
                "latency_p50_s": self.latency_percentile(50, model),
                "latency_p99_s": self.latency_percentile(99, model),
                "hardware": self._hw_summary(recs),
            }
        return out
