"""Brownout ladder: hysteretic, rung-by-rung overload degradation.

An overloaded photonic server has better moves than dropping requests.
The paper's core knob — runtime reconfigurability of the MRR comb-switch
operating point — trades energy/SNR headroom for throughput (HEANA,
arXiv 2402.03247; the MRR-GEMM comparison of arXiv 2402.03149), so the
ladder degrades in order of reversibility and cost:

    rung 0  nominal       — base policy, base operating point
    rung 1  stretch_wait  — larger batching window (max_wait x scale):
                            fuller power-of-two buckets, better
                            amortization, slightly worse queue waits
    rung 2  shed_batch    — stop admitting batch-class work (typed
                            ``BrownoutShed``); interactive keeps its SLO
    rung 3  downshift     — retune the comb-switch to the
                            throughput-optimal reconfigurable point and
                            replan (planner replan is bitwise: packing
                            geometry changes, quantization never does) —
                            more FPS for more peak power

The controller is *hysteretic*, never oscillating: escalation requires
sustained pressure past the high band for ``escalate_dwell_s`` since the
last transition; recovery requires the load signal under the (strictly
lower) low band for ``recover_cooldown_s``.  Both timers gate on the last
transition of either direction, so an escalate→recover flip is separated
by at least ``recover_cooldown_s`` and a recover→escalate flip by at
least ``escalate_dwell_s`` — the property tests/test_overload.py drives
with a sinusoidal load trace.

Pressure is the max of two normalized signals: queue depth over
``queue_high``, and estimated completion time over the SLO deadline
(scaled by ``latency_high``).  The PR-9 power telemetry composes as a
*guard*: with ``power_cap_w`` set, an escalation into a rung whose
operating point's modeled device power exceeds the cap is blocked (and
counted) — a fleet at its power budget sheds instead of downshifting.

The controller is a pure function of its observations — ``observe(now,
...)`` takes the clock explicitly — so virtual-clock harnesses replay it
deterministically.  Applying a rung to a live server (max-wait stretch,
admission gate, operating-point switch + replan, trace instants, metric
counters) is the server's job: ``CNNServer(brownout=...)`` calls
``observe`` each step and applies transitions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.operating_point import OperatingPoint


@dataclasses.dataclass(frozen=True)
class BrownoutRung:
    """One degradation level: batching stretch, admission gate, point.

    ``point=None`` means "the server's base operating point"; a set point
    retunes the device (and replans, when the registry compiles through
    the planner) while outputs stay bitwise-identical.
    """
    name: str
    max_wait_scale: float = 1.0
    admit_batch: bool = True
    point: Optional[OperatingPoint] = None

    def __post_init__(self) -> None:
        if self.max_wait_scale < 1.0:
            raise ValueError(
                f"max_wait_scale must be >= 1, got {self.max_wait_scale}")


#: The default ladder.  The downshift target is the paper's
#: reconfigurability knob itself: the comb-switch-reconfigurable RMAM
#: point is the throughput-optimal configuration (~1.8x the modeled FPS
#: of the fixed point on the paper-scale EfficientNetB7 table) at ~35%
#: higher peak device power — capacity bought with watts, not correctness.
DEFAULT_LADDER: Tuple[BrownoutRung, ...] = (
    BrownoutRung("nominal"),
    BrownoutRung("stretch_wait", max_wait_scale=4.0),
    BrownoutRung("shed_batch", max_wait_scale=4.0, admit_batch=False),
    BrownoutRung("downshift", max_wait_scale=4.0, admit_batch=False,
                 point=OperatingPoint("RMAM", 1.0, reconfigurable=True)),
)


@dataclasses.dataclass(frozen=True)
class RungTransition:
    """One applied ladder move (kept in ``transitions``, newest last)."""
    t: float
    src: int
    dst: int
    pressure: float
    power_w: Optional[float] = None

    @property
    def direction(self) -> str:
        return "escalate" if self.dst > self.src else "recover"


class BrownoutController:
    def __init__(self, rungs: Sequence[BrownoutRung] = DEFAULT_LADDER, *,
                 queue_high: int = 32, queue_low: int = 4,
                 latency_high: float = 1.0, latency_low: float = 0.25,
                 escalate_dwell_s: float = 0.05,
                 recover_cooldown_s: float = 0.5,
                 power_cap_w: Optional[float] = None,
                 max_transitions: int = 4096):
        rungs = tuple(rungs)
        if not rungs:
            raise ValueError("need at least one rung")
        if not 0 <= queue_low < queue_high:
            raise ValueError(
                f"need 0 <= queue_low < queue_high for hysteresis, got "
                f"{queue_low}/{queue_high}")
        if not 0 < latency_low < latency_high:
            raise ValueError(
                f"need 0 < latency_low < latency_high for hysteresis, got "
                f"{latency_low}/{latency_high}")
        if escalate_dwell_s < 0 or recover_cooldown_s < 0:
            raise ValueError("dwell/cooldown must be >= 0")
        self.rungs = rungs
        self.queue_high = queue_high
        self.queue_low = queue_low
        self.latency_high = latency_high
        self.latency_low = latency_low
        self.escalate_dwell_s = escalate_dwell_s
        self.recover_cooldown_s = recover_cooldown_s
        self.power_cap_w = power_cap_w
        self.max_transitions = max_transitions
        self.rung_index = 0
        self._last_change_t: Optional[float] = None
        self.counters: Dict[str, int] = {
            "escalations": 0, "deescalations": 0, "downshifts": 0,
            "power_blocked": 0}
        #: applied transitions, newest last (bounded at max_transitions)
        self.transitions: List[RungTransition] = []
        # modeled peak device power per distinct rung point (memo: the
        # power guard must not rebuild an accelerator every observation;
        # keyed by the full point — fixed vs reconfigurable variants of
        # one family/bit-rate share a label but not a power draw)
        self._power_memo: Dict[OperatingPoint, float] = {}

    @property
    def rung(self) -> BrownoutRung:
        return self.rungs[self.rung_index]

    def pressure(self, depth: int,
                 est_completion_s: Optional[float] = None,
                 deadline_s: Optional[float] = None) -> float:
        """Normalized load: >= 1.0 means "past the high band"."""
        p = depth / self.queue_high
        if est_completion_s is not None and deadline_s:
            p = max(p, (est_completion_s / deadline_s) / self.latency_high)
        return p

    def _recovered(self, depth: int, est_completion_s: Optional[float],
                   deadline_s: Optional[float]) -> bool:
        """Both signals under the low band (strictly below the high one)."""
        if depth > self.queue_low:
            return False
        if (est_completion_s is not None and deadline_s
                and est_completion_s / deadline_s > self.latency_low):
            return False
        return True

    def _rung_power_w(self, rung: BrownoutRung) -> Optional[float]:
        if rung.point is None:
            return None
        w = self._power_memo.get(rung.point)
        if w is None:
            w = rung.point.to_accelerator().power_w()
            self._power_memo[rung.point] = w
        return w

    def _blocked_by_power(self, rung: BrownoutRung) -> bool:
        if self.power_cap_w is None:
            return False
        w = self._rung_power_w(rung)
        return w is not None and w > self.power_cap_w

    def _move(self, now: float, dst: int, pressure: float,
              power_w: Optional[float]) -> RungTransition:
        tr = RungTransition(t=now, src=self.rung_index, dst=dst,
                            pressure=pressure, power_w=power_w)
        if tr.dst > tr.src:
            self.counters["escalations"] += 1
            if (self.rungs[dst].point is not None
                    and self.rungs[dst].point != self.rungs[tr.src].point):
                self.counters["downshifts"] += 1
        else:
            self.counters["deescalations"] += 1
        self.rung_index = dst
        self._last_change_t = now
        self.transitions.append(tr)
        if len(self.transitions) > self.max_transitions:
            del self.transitions[:len(self.transitions)
                                 - self.max_transitions]
        return tr

    def observe(self, now: float, depth: int,
                est_completion_s: Optional[float] = None,
                deadline_s: Optional[float] = None,
                power_w: Optional[float] = None,
                ) -> Optional[RungTransition]:
        """Feed one observation; returns the transition applied (or None).

        At most one rung of movement per observation — the ladder is
        climbed and descended step by step, each step separated by the
        dwell/cooldown gates.
        """
        p = self.pressure(depth, est_completion_s, deadline_s)
        since = (None if self._last_change_t is None
                 else now - self._last_change_t)
        if p >= 1.0 and self.rung_index < len(self.rungs) - 1:
            if since is not None and since < self.escalate_dwell_s:
                return None
            target = self.rungs[self.rung_index + 1]
            if self._blocked_by_power(target):
                self.counters["power_blocked"] += 1
                return None
            return self._move(now, self.rung_index + 1, p, power_w)
        if (self.rung_index > 0
                and self._recovered(depth, est_completion_s, deadline_s)):
            if since is not None and since < self.recover_cooldown_s:
                return None
            return self._move(now, self.rung_index - 1, p, power_w)
        return None

    def report(self) -> Dict:
        """Snapshot for telemetry summaries (counters copied, not live)."""
        return {
            "rung": self.rung_index,
            "rung_name": self.rung.name,
            "ladder": [r.name for r in self.rungs],
            "counters": dict(self.counters),
            "transitions": len(self.transitions),
        }
