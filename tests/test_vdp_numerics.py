"""VDP numerics: slicing + psum reduction is bit-identical to direct GEMM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.mapping import TPCConfig
from repro.core import vdp

RMAM = TPCConfig("MAM", 43, 43, True)
RAMM = TPCConfig("AMM", 31, 31, True)
MAM = TPCConfig("MAM", 44, 44, False)

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(s=st.integers(1, 300), p=st.integers(1, 32), f=st.integers(1, 24),
       seed=st.integers(0, 2**31 - 1))
def test_sliced_equals_direct(s, p, f, seed):
    """Integer psum accumulation is exact for every slice plan."""
    rng = np.random.default_rng(seed)
    divs_q = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
    dkvs_q = jnp.asarray(rng.integers(-7, 8, (f, s)), jnp.int8)
    ref = vdp.direct_quantized_gemm(divs_q, dkvs_q)
    for tpc in (RMAM, RAMM, MAM):
        got = vdp.sliced_vdp_gemm(divs_q, dkvs_q, tpc)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(s=st.integers(1, 9), p=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_mode2_packing_matches_unpacked(s, p, seed):
    """Case-3 block-diagonal packing returns each small DKV's exact VDP."""
    y, x, n = 4, 9, 43
    rng = np.random.default_rng(seed)
    divs_q = jnp.asarray(rng.integers(-7, 8, (p, s)), jnp.int8)
    dkvs_q = jnp.asarray(rng.integers(-7, 8, (y, s)), jnp.int8)
    packed = vdp.mode2_packed_vdp(divs_q, dkvs_q, x=x, y=y, n=n)
    ref = vdp.direct_quantized_gemm(divs_q, dkvs_q)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(ref))


@pytest.mark.parametrize("k,stride,padding", [(3, 1, "SAME"), (3, 2, "SAME"),
                                              (1, 1, "SAME"), (5, 1, "VALID")])
def test_im2col_matches_lax_conv(k, stride, padding):
    """patch . flattened-kernel == lax conv output (float, un-quantized)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(12, 12, 5)), jnp.float32)
    kernels = jnp.asarray(rng.normal(size=(7, k, k, 5)), jnp.float32)
    divs = vdp.im2col(x, k, stride, padding)
    dkvs = vdp.dkv_matrix(kernels)
    ours = (divs @ dkvs.T)
    ref = vdp.conv2d_direct(x, kernels, stride, padding).reshape(-1, 7)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_vdp_exact_equivalence():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 8, 16)), jnp.float32)
    kernels = jnp.asarray(rng.normal(size=(12, 3, 3, 16)), jnp.float32)
    for tpc in (RMAM, RAMM, MAM):
        out_vdp, out_ref = vdp.conv2d_vdp(x, kernels, tpc)
        np.testing.assert_array_equal(np.asarray(out_vdp), np.asarray(out_ref))


def test_depthwise_vdp_exact_equivalence():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 8, 6)), jnp.float32)
    kernels = jnp.asarray(rng.normal(size=(6, 3, 3)), jnp.float32)
    out_vdp, out_ref = vdp.depthwise_conv2d_vdp(x, kernels, RMAM)
    np.testing.assert_array_equal(np.asarray(out_vdp), np.asarray(out_ref))


def test_quantization_error_bounded():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 100)), jnp.float32)
    q, scale = vdp.quantize_symmetric(x, bits=4)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(scale) - np.asarray(x))
    assert err.max() <= np.asarray(scale) / 2 + 1e-6
    assert np.asarray(q).max() <= 7 and np.asarray(q).min() >= -7


def test_noisy_vdp_statistics():
    """Analog SE noise perturbs psums by O(1 LSB) at the design point."""
    rng = np.random.default_rng(4)
    divs_q = jnp.asarray(rng.integers(-7, 8, (64, 43)), jnp.int8)
    dkvs_q = jnp.asarray(rng.integers(-7, 8, (8, 43)), jnp.int8)
    clean = vdp.sliced_vdp_gemm(divs_q, dkvs_q, RMAM)
    noisy = vdp.noisy_vdp_gemm(jax.random.PRNGKey(0), divs_q, dkvs_q, RMAM)
    diff = np.abs(np.asarray(noisy) - np.asarray(clean))
    assert diff.mean() < 4.0          # a few integer LSBs at the 4-bit point
    assert (diff > 0).any()           # noise actually injected
