"""Sharded multi-accelerator dispatch tests: apportionment, bitwise
identity vs the single-accelerator path on ragged batches, per-shard
telemetry costing, and server/registry routing."""
import math

import jax
import numpy as np
import pytest

from repro import engine, serve
from repro.serve import models as zoo

try:                       # optional dev dep (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:        # pragma: no cover
    given = None

jax.config.update("jax_platform_name", "cpu")

RMAM1 = serve.OperatingPoint("RMAM", 1.0)
RMAM5 = serve.OperatingPoint("RMAM", 5.0)


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    yield
    engine.plan_cache_clear()


def _fleet(caps=(1.0, 1.0), points=None):
    points = points or [RMAM1] * len(caps)
    return serve.ShardedDispatcher([
        serve.AcceleratorInstance(f"acc{i}", hw=p, capacity=c)
        for i, (c, p) in enumerate(zip(caps, points))])


# ---------------------------------------------------------------------------
# apportionment
# ---------------------------------------------------------------------------

def test_shard_sizes_sum_and_proportionality():
    d = _fleet((2.0, 1.0, 1.0))
    for b in range(0, 33):
        sizes = d.shard_sizes(b)
        assert sum(sizes) == b and all(s >= 0 for s in sizes)
    assert d.shard_sizes(8) == [4, 2, 2]
    assert d.shard_sizes(1) == [1, 0, 0]     # ties go to earlier instances


def test_shard_sizes_deterministic():
    d = _fleet((1.0, 1.0, 1.0))
    assert d.shard_sizes(7) == d.shard_sizes(7) == [3, 2, 2]


def test_shard_sizes_over_reduced_active_set():
    """Quarantine re-deals over the healthy subset: same invariants."""
    d = _fleet((2.0, 1.0, 1.0))
    healthy = [d.instances[0], d.instances[2]]       # acc1 quarantined
    for b in range(0, 17):
        sizes = d.shard_sizes(b, active=healthy)
        assert len(sizes) == 2 and sum(sizes) == b
        assert all(s >= 0 for s in sizes)
    assert d.shard_sizes(9, active=healthy) == [6, 3]   # 2:1 capacities
    with pytest.raises(serve.NoHealthyInstances):
        d.shard_sizes(4, active=[])


if given is not None:
    @settings(max_examples=120, deadline=None)
    @given(batch=st.integers(0, 64),
           caps=st.lists(st.floats(0.25, 8.0), min_size=1, max_size=6),
           mask=st.lists(st.booleans(), min_size=6, max_size=6))
    def test_shard_sizes_property(batch, caps, mask):
        """Largest-remainder apportionment invariants, any fleet shape:
        sizes sum to the batch, are deterministic, never negative, and
        stay within one frame of each instance's exact quota — including
        over a reduced (quarantine-survivor) active subset."""
        d = _fleet(tuple(caps))
        sizes = d.shard_sizes(batch)
        assert sum(sizes) == batch
        assert sizes == d.shard_sizes(batch)         # deterministic
        total = sum(caps)
        for s, c in zip(sizes, caps):
            quota = batch * c / total
            assert math.floor(quota) - 1e-9 <= s <= math.ceil(quota) + 1e-9
        active = [i for i, m in zip(d.instances, mask) if m]
        if active:
            reduced = d.shard_sizes(batch, active=active)
            assert len(reduced) == len(active)
            assert sum(reduced) == batch
            assert all(s >= 0 for s in reduced)
        else:
            with pytest.raises(serve.NoHealthyInstances):
                d.shard_sizes(batch, active=active)
else:                      # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_shard_sizes_property():
        pass


def test_dispatcher_validates_instances():
    with pytest.raises(ValueError):
        serve.ShardedDispatcher([])
    with pytest.raises(ValueError):
        _fleet((1.0, -1.0))
    with pytest.raises(ValueError):
        serve.ShardedDispatcher(
            [serve.AcceleratorInstance("a"), serve.AcceleratorInstance("a")])
    with pytest.raises(ValueError):
        serve.default_fleet(0)


# ---------------------------------------------------------------------------
# bitwise identity vs single accelerator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 2, 3, 5, 7, 8])
def test_sharded_dispatch_bitwise_on_ragged_batches(batch):
    name = "shufflenet_mini"
    defs = zoo.serving_defs(name)
    plan = engine.compile_model(f"{name}#d{batch}", defs)
    rng = np.random.default_rng(batch)
    xb = rng.normal(size=(batch, *zoo.serving_input_shape(name))).astype(
        np.float32)
    single = np.asarray(engine.forward_jit(plan, xb))
    for caps in ((1.0, 1.0), (3.0, 1.0), (1.0, 1.0, 1.0)):
        d = _fleet(caps)
        out, runs = d.run(plan, xb)
        np.testing.assert_array_equal(np.asarray(out), single)
        assert sum(r.batch_size for r in runs) == batch
        assert all(r.batch_size > 0 for r in runs)   # empty shards skipped


def test_sharded_dispatch_bitwise_with_planner_plan():
    name = "xception_mini"
    defs = zoo.serving_defs(name)
    shape = zoo.serving_input_shape(name)
    planned = engine.plan_model(f"{name}#dp", defs, shape)
    fixed = engine.compile_model(f"{name}#df", defs)
    rng = np.random.default_rng(0)
    xb = rng.normal(size=(5, *shape)).astype(np.float32)
    out, _ = _fleet((2.0, 1.0), [RMAM1, RMAM5]).run(planned, xb)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(engine.forward_jit(fixed, xb)))


def test_hardware_pacing_floors_shard_service_time():
    """pace="hardware" floors each shard at the cycle-true simulator's
    modeled device time for that shard size at the instance's operating
    point — fleet throughput then scales like K real accelerators instead
    of K threads fighting over the host."""
    from repro.core import simulator as sim
    from repro.core.tpc import build_accelerator
    name = "shufflenet_mini"
    plan = engine.compile_model(f"{name}#pace", zoo.serving_defs(name))
    specs = tuple(zoo.paper_scale_specs(name))
    rng = np.random.default_rng(11)
    xb = rng.normal(size=(4, *zoo.serving_input_shape(name))).astype(
        np.float32)
    single = np.asarray(engine.forward_jit(plan, xb))
    d = serve.ShardedDispatcher(serve.default_fleet(2, hw=RMAM1),
                                pace="hardware")
    out, runs = d.run(plan, xb, sim_specs=specs)
    d.close()
    np.testing.assert_array_equal(np.asarray(out), single)   # pacing only
    acc = build_accelerator("RMAM", 1.0)
    for r in runs:
        floor = r.batch_size / sim.simulate(acc, specs,
                                            batch=r.batch_size).fps
        assert r.exec_s >= floor - 1e-6
    # without sim_specs there is nothing to pace against: still bitwise
    d2 = serve.ShardedDispatcher(serve.default_fleet(2, hw=RMAM1),
                                 pace="hardware")
    out2, _ = d2.run(plan, xb)
    d2.close()
    np.testing.assert_array_equal(np.asarray(out2), single)


# ---------------------------------------------------------------------------
# telemetry: per-shard costing
# ---------------------------------------------------------------------------

def test_telemetry_costs_each_shard_at_its_point():
    log = serve.TelemetryLog(points=(RMAM1,))
    specs = tuple(zoo.paper_scale_specs("xception_mini"))
    rec = log.record_batch(
        model="m", sim_specs=specs, batch_size=8, t_formed=0.0,
        exec_s=0.1, queue_waits_s=[0.0] * 8, latencies_s=[0.1] * 8,
        shards=[("acc0", 5, RMAM1, 0.06), ("acc1", 3, RMAM5, 0.04)])
    assert len(rec.shards) == 2
    by_inst = {s.instance: s for s in rec.shards}
    assert by_inst["acc0"].point == "RMAM@1G"
    assert by_inst["acc1"].point == "RMAM@5G"
    # shard costs use the shard's batch size at the shard's point
    from repro.core import simulator as sim
    from repro.core.tpc import build_accelerator
    exp = sim.simulate(build_accelerator("RMAM", 5.0), specs, batch=3)
    assert by_inst["acc1"].cost.fps == pytest.approx(exp.fps)
    summ = log.summary()
    assert summ["dispatch"]["acc0"]["frames"] == 5
    assert summ["dispatch"]["acc1"]["point"] == "RMAM@5G"


# ---------------------------------------------------------------------------
# server + registry routing
# ---------------------------------------------------------------------------

def test_server_routes_through_dispatcher_bitwise():
    fleet = _fleet((2.0, 1.0), [RMAM1, RMAM5])
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          dispatcher=fleet)
    srv1 = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4)
    rng = np.random.default_rng(5)
    for name in zoo.SERVING_MODELS:
        for x in rng.normal(size=(5, *zoo.serving_input_shape(name))):
            srv.submit(name, x.astype(np.float32))
            srv1.submit(name, x.astype(np.float32))
    out, out1 = srv.run_until_drained(), srv1.run_until_drained()
    assert out.keys() == out1.keys()
    for rid in out:
        np.testing.assert_array_equal(out[rid], out1[rid])
    summ = srv.telemetry.summary()
    assert set(summ["dispatch"]) == {"acc0", "acc1"}
    assert sum(d["frames"] for d in summ["dispatch"].values()) \
        == summ["requests"]
    assert srv1.telemetry.summary()["dispatch"] == {}


def test_warm_pipelines_covers_shard_buckets():
    fleet = _fleet((1.0, 1.0, 1.0))
    reg = serve.paper_cnn_registry()
    name = next(iter(zoo.SERVING_MODELS))
    buckets = reg.warm_pipelines(name, max_batch=6, dispatcher=fleet)
    # shards of batches 1..6 over 3 equal instances are 1 or 2 frames
    assert buckets == [1, 2]
    # serving through the dispatcher now pays zero compile stalls
    srv = serve.CNNServer(reg, max_batch=6, dispatcher=fleet)
    rng = np.random.default_rng(9)
    for x in rng.normal(size=(6, *zoo.serving_input_shape(name))):
        srv.submit(name, x.astype(np.float32))
    srv.run_until_drained()
    assert srv.pipeline_compiles == 0
