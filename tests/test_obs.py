"""Observability subsystem tests: streaming histograms vs exact
percentiles on adversarial distributions, the metrics registry's
Prometheus/snapshot surfaces, span tracer semantics (nesting, ring
bound, sampling, the free no-op path), Chrome trace export validation
with the dual host/hardware clock, per-layer hardware attribution, the
telemetry satellites (deep-copied fleet snapshots, None activation
ratio, bounded records with histogram fallback), and an end-to-end
traced fault-injected fleet."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro import engine, obs, serve
from repro.core import simulator as sim
from repro.core.tpc import build_accelerator
from repro.obs.metrics import DEFAULT_GROWTH
from repro.serve import models as zoo

jax.config.update("jax_platform_name", "cpu")

RMAM1 = serve.OperatingPoint("RMAM", 1.0)


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    yield
    engine.plan_cache_clear()


def _fake_clock(step=1.0):
    t = [0.0]

    def now():
        t[0] += step
        return t[0]
    return now


# ---------------------------------------------------------------------------
# LogHistogram vs exact percentiles
# ---------------------------------------------------------------------------

def _adversarial_distributions():
    rng = np.random.default_rng(7)
    return {
        "heavy_tail": rng.lognormal(mean=-3.0, sigma=2.0, size=20_000),
        "bimodal": np.concatenate([rng.normal(1e-3, 1e-4, 10_000),
                                   rng.normal(10.0, 1.0, 10_000)]).clip(1e-6),
        "uniform": rng.uniform(0.01, 0.02, 5_000),
        "constant": np.full(1_000, 0.125),
    }


@pytest.mark.parametrize("name", sorted(_adversarial_distributions()))
def test_histogram_percentile_within_one_bucket_of_exact(name):
    values = _adversarial_distributions()[name]
    h = obs.LogHistogram()
    h.record_many(values)
    ordered = np.sort(values)
    for q in (1, 25, 50, 90, 99, 99.9):
        # the histogram's guarantee is against the order statistic at the
        # target rank (numpy's default interpolates between samples —
        # between a bimodal's modes that lands where no sample exists)
        rank = max(1, int(np.ceil(q / 100.0 * len(values))))
        exact = float(ordered[rank - 1])
        approx = h.percentile(q)
        # the representative is the geometric bucket midpoint: one
        # growth-factor relative band of the exact rank value
        assert approx == pytest.approx(exact, rel=DEFAULT_GROWTH - 1.0)
    assert h.count == len(values)
    assert h.total == pytest.approx(float(values.sum()))
    assert h.vmin == pytest.approx(float(values.min()))
    assert h.vmax == pytest.approx(float(values.max()))


def test_histogram_constant_and_single_sample_are_exact():
    h = obs.LogHistogram()
    h.record(0.125)
    for q in (0, 50, 100):
        # representatives clamp to [vmin, vmax], so one sample is exact
        assert h.percentile(q) == 0.125
    c = obs.LogHistogram()
    c.record_many([3.7] * 999)
    assert c.percentile(50) == 3.7 and c.percentile(99) == 3.7


def test_histogram_bounded_buckets_and_range_clamp():
    h = obs.LogHistogram(min_value=1e-9, max_value=1e9)
    rng = np.random.default_rng(0)
    h.record_many(np.exp(rng.uniform(np.log(1e-12), np.log(1e12), 50_000)))
    # index range is fixed by the geometry, not the stream length
    assert len(h.buckets) <= h.index(1e9) - h.index(1e-9) + 1
    assert h.index(1e-30) == h.index(1e-9)          # underflow clamp
    assert h.index(1e30) == h.index(1e9)            # overflow clamp
    assert h.percentile(100) <= h.vmax


def test_histogram_merge_matches_concatenation():
    rng = np.random.default_rng(3)
    a, b = rng.lognormal(size=4_000), rng.lognormal(mean=2.0, size=6_000)
    ha, hb, hall = obs.LogHistogram(), obs.LogHistogram(), obs.LogHistogram()
    ha.record_many(a)
    hb.record_many(b)
    hall.record_many(np.concatenate([a, b]))
    ha.merge(hb)
    assert ha.count == hall.count and ha.buckets == hall.buckets
    for q in (10, 50, 99):
        assert ha.percentile(q) == hall.percentile(q)
    with pytest.raises(ValueError):
        ha.merge(obs.LogHistogram(growth=1.5))


def test_histogram_serialization_roundtrip_through_json():
    h = obs.LogHistogram()
    h.record_many(np.random.default_rng(1).lognormal(size=500))
    doc = json.loads(json.dumps(h.to_dict()))
    h2 = obs.LogHistogram.from_dict(doc)
    assert h2.count == h.count and h2.buckets == h.buckets
    assert h2.percentile(95) == h.percentile(95)
    assert h2.vmin == h.vmin and h2.vmax == h.vmax


def test_histogram_validation():
    with pytest.raises(ValueError):
        obs.LogHistogram(growth=1.0)
    with pytest.raises(ValueError):
        obs.LogHistogram(min_value=2.0, max_value=1.0)
    h = obs.LogHistogram()
    with pytest.raises(ValueError):
        h.percentile(50)                 # empty
    h.record(1.0)
    with pytest.raises(ValueError):
        h.percentile(101)


# ---------------------------------------------------------------------------
# MetricsRegistry: Prometheus text + snapshot round-trip
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_type_conflicts():
    reg = obs.MetricsRegistry()
    c = reg.counter("reqs_total", "requests", model="a")
    assert reg.counter("reqs_total", model="a") is c
    assert reg.counter("reqs_total", model="b") is not c
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")          # name already a counter
    with pytest.raises(ValueError):
        c.inc(-1)                        # counters only go up


def test_prometheus_text_exposition_shape():
    reg = obs.MetricsRegistry()
    reg.counter("served_total", "frames served", model="m").inc(7)
    reg.gauge("depth", "queue depth").set(3)
    h = reg.histogram("lat_seconds", "latency")
    h.record_many([0.001, 0.002, 0.004, 0.1])
    text = reg.prometheus_text()
    assert "# TYPE served_total counter" in text
    assert 'served_total{model="m"} 7' in text
    assert "# TYPE depth gauge" in text and "\ndepth 3" in text
    assert "# HELP lat_seconds latency" in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert "lat_seconds_count 4" in text
    # cumulative bucket counts never decrease
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_seconds_bucket")]
    assert cums == sorted(cums)


def test_registry_snapshot_roundtrip():
    reg = obs.MetricsRegistry()
    reg.counter("a_total", "a", k="v").inc(5)
    reg.gauge("g").set(-2.5)
    reg.histogram("h_seconds", "h").record_many([0.01, 0.5, 2.0])
    snap = json.loads(json.dumps(reg.snapshot()))
    reg2 = obs.MetricsRegistry.from_snapshot(snap)
    assert reg2.prometheus_text() == reg.prometheus_text()
    reg.reset()
    assert reg.counter("a_total", k="v").value == 0
    assert reg.histogram("h_seconds").count == 0


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_attributes():
    tr = obs.Tracer(time_fn=_fake_clock())
    with tr.span("batch", cat="batch", model="m") as outer:
        with tr.span("exec", cat="batch") as inner:
            inner.set(size=4)
        tr.instant("shed", cat="admission")
        outer.set(compiles=1)
    recs = tr.events()
    by_name = {r.name: r for r in recs}
    assert by_name["exec"].parent_id == by_name["batch"].span_id
    assert by_name["shed"].parent_id == by_name["batch"].span_id
    assert by_name["batch"].parent_id is None
    assert by_name["exec"].args == {"size": 4}
    assert by_name["batch"].args == {"model": "m", "compiles": 1}
    assert by_name["batch"].dur > by_name["exec"].dur > 0


def test_tracer_exception_annotates_span():
    tr = obs.Tracer()
    with pytest.raises(KeyError):
        with tr.span("boom"):
            raise KeyError("x")
    (rec,) = tr.events()
    assert rec.args["error"] == "KeyError"


def test_tracer_ring_is_bounded():
    tr = obs.Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    recs = tr.events()
    assert [r.name for r in recs] == [f"e{i}" for i in range(12, 20)]
    st = tr.stats()
    assert st["retained"] == 8 and st["dropped_ring"] == 12
    assert st["emitted"] == 20
    tr.clear()
    assert tr.events() == () and tr.stats()["emitted"] == 0
    with pytest.raises(ValueError):
        obs.Tracer(capacity=0)


def test_tracer_sampling_is_deterministic_per_category():
    def run():
        tr = obs.Tracer(sample={"shard": 0.25})
        for i in range(16):
            with tr.span(f"s{i}", cat="shard"):
                pass
            tr.instant(f"k{i}", cat="fault")     # unlisted: always kept
        return tr
    tr = run()
    shard = tr.events_by_cat("shard")
    assert [r.name for r in shard] == ["s0", "s4", "s8", "s12"]
    assert len(tr.events_by_cat("fault")) == 16
    assert tr.stats()["sampled_out"] == 12
    assert [r.name for r in run().events_by_cat("shard")] \
        == [r.name for r in shard]               # replayable
    with pytest.raises(ValueError):
        obs.Tracer(sample={"shard": 0.0})


def test_noop_tracer_is_free_and_shared():
    tr = obs.NOOP_TRACER
    assert tr.enabled is False
    s1 = tr.span("a", model="m")
    with s1 as s:
        s.set(x=1)
        s.hw("acc0", 1.0)
    assert tr.span("b") is s1                    # shared stateless span
    tr.instant("i")
    tr.async_begin("r", aid=1)
    tr.async_end("r", aid=1)
    assert tr.events() == ()
    assert tr.stats() == {"enabled": False, "emitted": 0, "retained": 0,
                          "dropped_ring": 0, "sampled_out": 0}


def test_async_pairs_and_census():
    tr = obs.Tracer(time_fn=_fake_clock())
    tr.async_begin("request", aid=11, model="m")
    tr.async_end("request", aid=11, latency_s=0.5)
    recs = tr.events()
    assert [r.ph for r in recs] == ["b", "e"]
    assert recs[0].aid == recs[1].aid == 11
    assert recs[0].tid == "requests"
    assert obs.category_census(recs) == {"request": 2}


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _traced_records():
    tr = obs.Tracer(time_fn=_fake_clock(0.5))
    with tr.span("batch", cat="batch", model="m"):
        with tr.span("shard", cat="shard", tid="acc0") as sp:
            sp.hw("acc0", 2.0)
        with tr.span("shard", cat="shard", tid="acc1") as sp:
            sp.hw("acc1", 1.5)
    tr.instant("fault.crash", cat="fault", tid="acc0")
    tr.async_begin("request", aid=1)
    tr.async_end("request", aid=1)
    return tr.events()


def test_chrome_trace_export_validates_dual_clock():
    doc = obs.chrome_trace(_traced_records())
    n = obs.validate_chrome_trace(doc, require_dual_clock=True)
    assert n == len(doc["traceEvents"])
    for ev in doc["traceEvents"]:
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
    pids = {ev["pid"] for ev in doc["traceEvents"] if ev["ph"] != "M"}
    assert pids == {obs.PID_HOST, obs.PID_HW}
    # every track is named for Perfetto via thread_name metadata
    named = {(ev["pid"], ev["tid"]) for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    used = {(ev["pid"], ev["tid"]) for ev in doc["traceEvents"]
            if ev["ph"] != "M"}
    assert used <= named
    busy = obs.hw_occupancy(doc)
    assert busy == {"acc0": pytest.approx(2.0), "acc1": pytest.approx(1.5)}
    census = obs.event_census(doc)
    assert census["fault"] == 1 and census["request"] == 2
    assert census["hw.shard"] == 2


def test_hw_events_never_overlap_per_instance():
    """The occupancy cursor lays hw events end-to-end per instance even
    when their wall-clock spans overlap."""
    recs = [sim_rec for i, sim_rec in enumerate(
        obs.SpanRecord(name=f"s{i}", cat="shard", ph="X", t0=1.0,
                       dur=0.1, tid="w", span_id=i + 1, parent_id=None,
                       args={}, hw_instance="acc0", hw_s=3.0)
        for i in range(4))]
    doc = obs.chrome_trace(recs)
    hw = sorted((ev["ts"], ev["dur"]) for ev in doc["traceEvents"]
                if ev.get("pid") == obs.PID_HW and ev["ph"] == "X")
    for (ts0, d0), (ts1, _) in zip(hw, hw[1:]):
        assert ts1 >= ts0 + d0 - 1e-6
    assert obs.hw_occupancy(doc)["acc0"] == pytest.approx(12.0)


@pytest.mark.parametrize("mutate, err", [
    (lambda d: d.pop("traceEvents"), "traceEvents"),
    (lambda d: d["traceEvents"].append({"name": "x", "ph": "Z", "pid": 1,
                                        "tid": 1}), "phase"),
    (lambda d: d["traceEvents"].append({"name": "", "ph": "i", "pid": 1,
                                        "tid": 1, "ts": 0, "cat": "c"}),
     "name"),
    (lambda d: d["traceEvents"].append({"name": "x", "ph": "i", "pid": 1,
                                        "tid": "w", "ts": 0, "cat": "c"}),
     "tid"),
    (lambda d: d["traceEvents"].append({"name": "x", "ph": "i", "pid": 1,
                                        "tid": 1, "ts": -5, "cat": "c"}),
     "ts"),
    (lambda d: d["traceEvents"].append({"name": "x", "ph": "X", "pid": 1,
                                        "tid": 1, "ts": 0, "cat": "c"}),
     "dur"),
    (lambda d: d["traceEvents"].append({"name": "x", "ph": "b", "pid": 1,
                                        "tid": 1, "ts": 0, "cat": "c"}),
     "id"),
])
def test_validate_rejects_malformed_events(mutate, err):
    doc = obs.chrome_trace(_traced_records())
    mutate(doc)
    with pytest.raises(ValueError, match=err):
        obs.validate_chrome_trace(doc)


def test_validate_dual_clock_requires_hw_process():
    tr = obs.Tracer(time_fn=_fake_clock())
    with tr.span("batch"):                       # no .hw() annotation
        pass
    doc = obs.chrome_trace(tr.events())
    assert obs.validate_chrome_trace(doc) == len(doc["traceEvents"])
    with pytest.raises(ValueError, match="dual-clock"):
        obs.validate_chrome_trace(doc, require_dual_clock=True)


def test_write_load_trace_roundtrip(tmp_path):
    path = tmp_path / "t.json"
    doc = obs.write_trace(path, _traced_records())
    assert obs.load_trace(path) == doc


# ---------------------------------------------------------------------------
# per-layer costs (simulator) and attribution
# ---------------------------------------------------------------------------

def test_layer_costs_decompose_report_exactly():
    specs = tuple(zoo.paper_scale_specs("shufflenet_mini"))
    rep = sim.simulate(build_accelerator("RMAM", 1.0), specs, batch=3)
    rows = rep.layer_costs()
    assert [r.name for r in rows] == [s.name for s in specs]
    assert sum(r.time_s for r in rows) \
        == pytest.approx(rep.frame_latency_s, rel=1e-9)
    assert sum(r.energy_j for r in rows) \
        == pytest.approx(rep.energy_per_frame_j, rel=1e-9)
    assert {r.kind for r in rows} <= {"SC", "DC", "PC", "FC"}
    # reports without names (old pickles, hand-built) degrade gracefully
    bare = dataclasses.replace(rep, layer_names=None)
    assert bare.layer_costs()[0].name == "layer0"


def test_layer_attribution_coverage_and_hotspots():
    specs = tuple(zoo.paper_scale_specs("shufflenet_mini"))
    rep = sim.simulate(build_accelerator("RMAM", 1.0), specs, batch=2)
    rows = rep.layer_costs()
    att = obs.LayerAttribution()
    att.record("m", "RMAM@1G", rows, frames=2,
               frame_latency_s=rep.frame_latency_s,
               op_points={specs[0].name: "MAM@5G"}, reconfig_switches=3)
    att.record("m", "RMAM@1G", rows, frames=4,
               frame_latency_s=rep.frame_latency_s)
    assert att.coverage("m") == pytest.approx(1.0, rel=1e-9)
    summ = att.summary(top_k=3)["m"]
    assert summ["frames"] == 6 and summ["reconfig_switches"] == 3
    assert summ["operating_points"] == {specs[0].name: "MAM@5G"}
    top = summ["top"]
    assert len(top) == 3
    assert [t["time_s"] for t in top] \
        == sorted((t["time_s"] for t in top), reverse=True)
    assert sum(r["share"] for r in summ["top"]) <= 1.0 + 1e-9
    # per-row operating point: the plan's per-layer point when known,
    # else the model's primary point
    by_layer = {t["layer"]: t for t in top}
    for t in top:
        expect = "MAM@5G" if t["layer"] == specs[0].name else "RMAM@1G"
        assert t["point"] == expect
    assert by_layer  # non-empty sanity
    att.reset()
    assert att.models() == []


# ---------------------------------------------------------------------------
# telemetry satellites
# ---------------------------------------------------------------------------

def _record(log, model="m", size=4, lat=0.1, shards=(), t0=0.0):
    specs = tuple(zoo.paper_scale_specs("shufflenet_mini"))
    return log.record_batch(
        model=model, sim_specs=specs, batch_size=size, t_formed=t0,
        exec_s=0.05, queue_waits_s=[0.01] * size,
        latencies_s=[lat] * size, shards=shards)


def test_summary_fleet_snapshot_is_deep_copied():
    log = serve.TelemetryLog(points=(RMAM1,))
    state = {"instances": {"acc0": {"healthy": True}}, "sheds": 0}
    log.attach_fleet(lambda: state)
    _record(log)
    summ = log.summary()
    summ["fleet"]["instances"]["acc0"]["healthy"] = False
    summ["fleet"]["sheds"] = 99
    assert log.summary()["fleet"] == state     # caller owns the snapshot
    assert state["sheds"] == 0
    bare = serve.TelemetryLog(points=(RMAM1,))
    _record(bare)
    assert bare.summary()["fleet"] == {}       # no fleet attached


def test_activation_ratio_is_none_without_exec_specs():
    log = serve.TelemetryLog(points=(RMAM1,))
    _record(log)                               # no exec_specs passed
    act = log.summary()["activation_stream"]
    assert act["int8_bytes"] == 0 and act["ratio"] is None
    assert log.summary()["models"]["m"]["activation_stream"]["ratio"] is None


def test_single_request_percentiles():
    log = serve.TelemetryLog(points=(RMAM1,))
    specs = tuple(zoo.paper_scale_specs("shufflenet_mini"))
    log.record_batch(model="only", sim_specs=specs, batch_size=1,
                     t_formed=0.0, exec_s=0.01, queue_waits_s=[0.0],
                     latencies_s=[0.25])
    assert log.latency_percentile(50, "only") == 0.25
    assert log.latency_percentile(99, "only") == 0.25
    assert log.summary()["models"]["only"]["latency_p99_s"] == 0.25


def test_bounded_records_fall_back_to_histogram_percentiles():
    log = serve.TelemetryLog(points=(RMAM1,), max_records=2)
    lats = [0.01, 0.02, 0.04, 0.08, 0.16]
    for i, lat in enumerate(lats):
        _record(log, size=2, lat=lat, t0=float(i))
    assert len(log.records) == 2               # ring trimmed
    assert log._dropped_records == 3
    summ = log.summary()
    assert summ["requests"] == 10              # aggregates stay exact
    exact = float(np.percentile(np.repeat(lats, 2), 50))
    assert summ["latency_p50_s"] \
        == pytest.approx(exact, rel=DEFAULT_GROWTH - 1.0)
    # the per-model histogram backs model percentiles too
    assert log.latency_percentile(99, "m") \
        == pytest.approx(0.16, rel=DEFAULT_GROWTH - 1.0)
    with pytest.raises(ValueError):
        log.latency_percentile(50, "never_served")


def test_hw_summary_is_frame_weighted():
    log = serve.TelemetryLog(points=(RMAM1,))
    r1 = _record(log, size=1, t0=0.0)
    r8 = _record(log, size=8, t0=1.0)
    hw = log.summary()["hardware"]["RMAM@1G"]
    f1, f8 = r1.hw["RMAM@1G"].fps, r8.hw["RMAM@1G"].fps
    assert hw["modeled_fps"] == pytest.approx((f1 + 8 * f8) / 9)
    assert f8 > f1                             # batch amortization


def test_mixed_sharded_and_unsharded_batches():
    log = serve.TelemetryLog(points=(RMAM1,))
    _record(log, size=4, shards=[("acc0", 3, RMAM1, 0.02),
                                 ("acc1", 1, RMAM1, 0.01)])
    _record(log, size=2, t0=1.0)               # unsharded
    summ = log.summary()
    assert summ["requests"] == 6
    assert summ["dispatch"]["acc0"]["frames"] == 3
    assert summ["dispatch"]["acc1"]["frames"] == 1
    assert sum(d["frames"] for d in summ["dispatch"].values()) == 4
    assert summ["layers"]["m"]["coverage"] == pytest.approx(1.0, rel=1e-9)
    # scrape counters follow the same split
    text = log.metrics.prometheus_text()
    assert 'serve_shard_frames_total{instance="acc0"} 3' in text
    assert 'serve_requests_total{model="m"} 6' in text


def test_pipeline_dispatch_counts():
    plan = engine.compile_model("obs_counts",
                                zoo.serving_defs("shufflenet_mini"))
    engine.pipeline_cache_clear()
    rng = np.random.default_rng(2)
    shape = zoo.serving_input_shape("shufflenet_mini")
    for size in (1, 1, 3):
        engine.forward_jit(plan, rng.normal(size=(size, *shape))
                           .astype(np.float32))
    counts = engine.pipeline_dispatch_counts()
    assert counts[("obs_counts", engine.batch_bucket(1))] == 2
    assert counts[("obs_counts", engine.batch_bucket(3))] == 1
    engine.pipeline_cache_clear()
    assert engine.pipeline_dispatch_counts() == {}


# ---------------------------------------------------------------------------
# end-to-end: traced fault-injected fleet
# ---------------------------------------------------------------------------

def test_traced_fleet_end_to_end(tmp_path):
    tracer = obs.Tracer()
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.CRASH, start=1,
                         duration=2)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(2),
                                    fault_injector=injector,
                                    probe_cooldown_s=0.01)
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=3,
                          dispatcher=fleet, tracer=tracer)
    rng = np.random.default_rng(4)
    n = 6
    shape = zoo.serving_input_shape("shufflenet_mini")
    for x in rng.normal(size=(n, *shape)).astype(np.float32):
        srv.submit("shufflenet_mini", x)
    out = srv.run_until_drained()
    fleet.close()
    assert len(out) == n

    recs = tracer.events()
    census = obs.category_census(recs)
    assert census.get("shard", 0) > 0
    assert census.get("fault", 0) > 0          # the crash left instants
    assert census.get("request", 0) >= 2 * n   # async begin/end pairs
    batch_spans = [r for r in recs if r.cat == "batch" and r.ph == "X"]
    assert any(r.name == "shard.exec" and r.args.get("error")
               for r in recs)                  # the crash annotated a span
    assert batch_spans and all("model" in r.args or r.parent_id
                               for r in batch_spans)

    doc = obs.write_trace(tmp_path / "trace.json", recs)
    obs.validate_chrome_trace(doc, require_dual_clock=True)
    assert obs.hw_occupancy(doc)               # modeled clock populated

    summ = srv.telemetry.summary()
    assert summ["layers"]["shufflenet_mini"]["coverage"] >= 0.95
    assert summ["fleet"]["instances"]        # health snapshot attached
    text = srv.telemetry.metrics.prometheus_text()
    assert "serve_requests_total" in text
    assert "serve_request_latency_seconds_bucket" in text

    # reset forgets the trace's telemetry but keeps serving viable
    srv.reset()
    assert srv.telemetry.summary() == {"requests": 0, "batches": 0}


def test_server_unsharded_traces_local_hw_clock():
    tracer = obs.Tracer()
    srv = serve.CNNServer(serve.paper_cnn_registry(), max_batch=4,
                          tracer=tracer)
    rng = np.random.default_rng(6)
    shape = zoo.serving_input_shape("shufflenet_mini")
    for x in rng.normal(size=(4, *shape)).astype(np.float32):
        srv.submit("shufflenet_mini", x)
    srv.run_until_drained()
    doc = obs.chrome_trace(tracer.events())
    obs.validate_chrome_trace(doc, require_dual_clock=True)
    busy = obs.hw_occupancy(doc)
    assert set(busy) == {"local"} and busy["local"] > 0
