"""Quantized-domain execution: int8 operand streams end to end.

Bitwise contracts under test:

* the fused-quantize kernels (vdpe_gemm_q8 / vdpe_pack_gemm_zs_q8 /
  vdpe_conv_q8 / vdpe_pack_conv_zs_q8) == quantizing in XLA and calling
  the pre-quantized kernels — including the explicit double-buffered
  K-block / DIV-stream DMA loops and multi-block grids;
* pre-quantized kernels fed lattice-f32 operands (the quantize-then-float
  oracle's GEMMs) == their int8 results exactly (f32 accumulation of int8
  products is exact below 2^24);
* engine forward / forward_layer (int8 path) == forward_f32 (the float
  oracle) == forward_im2col across ALL FOUR layer kinds (SC/DC/PC/FC),
  both packing modes, per-image dequant scales, ragged batches, eager and
  whole-model jit;
* plan weight-bytes accounting and the registry's packed-vs-f32 report.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.cnn.layers import ConvKind
from repro.engine import executor as ex
from repro.kernels import common
from repro.kernels import vdpe_conv as kconv
from repro.kernels import vdpe_gemm as kern
from repro.serve import models as zoo
from repro.serve.registry import PlanRegistry

jax.config.update("jax_platform_name", "cpu")


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _quantize_rows(lhs, a_rows, bits=4):
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(lhs / a_rows[:, None]),
                    -qmax, qmax).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Kernel level: fused quantize prologue == quantize-then-kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_k", [1, 3])
@pytest.mark.parametrize("act", ["none", "relu6"])
def test_vdpe_gemm_q8_matches_prequantized(n_k, act):
    """The double-buffered K-pipelined q8 GEMM == XLA quantize + vdpe_gemm
    with per-row scales (pad rows carry scale 1)."""
    rng = np.random.default_rng(0)
    b, k, o = 256, 128 * n_k, 256
    lhs = jnp.asarray(rng.normal(size=(b, k)) * 3.0, jnp.float32)
    rhs = jnp.asarray(rng.integers(-7, 8, (k, o)), jnp.int8)
    a_rows = jnp.asarray(np.abs(rng.normal(size=(b,))) + 0.05, jnp.float32)
    a_rows = a_rows.at[-5:].set(1.0)              # "pad" rows
    w_scale = jnp.float32(0.037)
    bias = jnp.asarray(rng.normal(size=(1, o)), jnp.float32)
    got = kern.vdpe_gemm_q8(lhs, rhs, a_rows, w_scale, bits=4,
                            interpret=True, bias=bias, act=act)
    want = kern.vdpe_gemm(_quantize_rows(lhs, a_rows), rhs,
                          interpret=True, scale=a_rows * w_scale,
                          bias=bias, act=act)
    _eq(got, want)


@pytest.mark.parametrize("n_b", [1, 3])
def test_vdpe_pack_gemm_zs_q8_matches_prequantized(n_b):
    """The stream-double-buffered zero-skipping q8 GEMM == XLA quantize +
    vdpe_pack_gemm_zs, across multiple DIV-stream blocks."""
    rng = np.random.default_rng(1)
    b, x, o = 128 * n_b, 32, 128
    lhs = jnp.asarray(rng.normal(size=(b, x)) * 2.0, jnp.float32)
    rhs = jnp.asarray(rng.integers(-7, 8, (x, o)), jnp.int8)
    a_rows = jnp.asarray(np.abs(rng.normal(size=(b,))) + 0.05, jnp.float32)
    w_scale = jnp.float32(0.021)
    got = kern.vdpe_pack_gemm_zs_q8(lhs, rhs, a_rows, w_scale, bits=4,
                                    interpret=True, act="relu")
    want = kern.vdpe_pack_gemm_zs(_quantize_rows(lhs, a_rows), rhs,
                                  interpret=True, scale=a_rows * w_scale,
                                  act="relu")
    _eq(got, want)


@pytest.mark.parametrize("k,stride", [(1, 1), (3, 1), (3, 2)])
def test_conv_q8_matches_prequantized(k, stride):
    """The fused-prologue conv kernels (in-kernel absmax + quantize) ==
    the XLA quantize passes + the pre-quantized conv kernels."""
    rng = np.random.default_rng(2)
    b, h, w, d = 3, 9, 9, 4
    from repro.core import vdp
    ho, wo = vdp.out_hw(h, w, k, stride, "SAME")
    x4 = jnp.asarray(rng.normal(size=(b, h, w, d)) * 4.0, jnp.float32)
    x4p = ex._pad_spatial(x4, k, stride, "SAME")
    s = k * k * d
    s_rows = common.round_up(s, 128)
    rhs = jnp.asarray(rng.integers(-7, 8, (s_rows, 128)), jnp.int8)
    w_scale = jnp.float32(0.013)
    bias = jnp.asarray(rng.normal(size=(1, 128)), jnp.float32)
    got = kconv.vdpe_conv_q8(x4p, rhs, w_scale, k, stride, ho, wo,
                             bits=4, interpret=True, bias=bias, act="relu")
    a_scale = ex._stable_scale(
        jnp.maximum(ex._window_absmax(x4p, k, stride, ho, wo, False),
                    1e-12) * common.inv_qmax(4))
    x_q = jnp.clip(jnp.round(x4p / a_scale[:, None, None, None]),
                   -7, 7).astype(jnp.int8)
    want = kconv.vdpe_conv(x_q, rhs, k, stride, ho, wo, interpret=True,
                           scale=a_scale * w_scale, bias=bias, act="relu")
    _eq(got, want)


def test_lattice_f32_gemms_match_int8_exactly():
    """int8-lattice values streamed as f32 accumulate EXACTLY: the float
    oracle's GEMMs are bit-convertible to the int8 GEMMs' results."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.integers(-7, 8, (128, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-7, 8, (256, 128)), jnp.int8)
    got_i = kern.vdpe_gemm(q, w, interpret=True)
    got_f = kern.vdpe_gemm(q.astype(jnp.float32), w.astype(jnp.float32),
                           interpret=True)
    assert got_f.dtype == jnp.float32
    _eq(got_i.astype(jnp.float32), got_f)
    qs = jnp.asarray(rng.integers(-7, 8, (128, 32)), jnp.int8)
    ws = jnp.asarray(rng.integers(-7, 8, (32, 128)), jnp.int8)
    _eq(kern.vdpe_pack_gemm_zs(qs, ws, interpret=True).astype(jnp.float32),
        kern.vdpe_pack_gemm_zs(qs.astype(jnp.float32),
                               ws.astype(jnp.float32), interpret=True))


# ---------------------------------------------------------------------------
# Engine level: all four kinds, both modes, per-image scales, ragged
# ---------------------------------------------------------------------------

def _all_modes_defs():
    """A chain covering SC/DC/PC/FC in BOTH packing modes.

    stem SC s=27 (Mode 2) -> dw DC -> sc2 SC s=72 (Mode 1) -> pw1 PC s=10
    (Mode 2) -> pw2 PC s=40 (Mode 1) -> fc1 S=192 (Mode 1) -> fc2 S=16
    (Mode 2).
    """
    rng = np.random.default_rng(42)

    def w(shape, s=0.5):
        return jnp.asarray(rng.normal(size=shape) * s, jnp.float32)

    return [
        engine.LayerDef("stem", ConvKind.SC, w((8, 3, 3, 3)),
                        act="relu", stride=2),
        engine.LayerDef("dw", ConvKind.DC, w((8, 3, 3)), act="relu6"),
        engine.LayerDef("sc2", ConvKind.SC, w((10, 3, 3, 8)),
                        bias=w((10,), 0.1), act="relu"),
        engine.LayerDef("pw1", ConvKind.PC, w((40, 1, 1, 10)), act="relu"),
        engine.LayerDef("pw2", ConvKind.PC, w((12, 1, 1, 40)),
                        bias=w((12,), 0.1), act="relu6"),
        engine.LayerDef("fc1", ConvKind.FC, w((16, 4 * 4 * 12)),
                        bias=w((16,), 0.1), act="relu"),
        engine.LayerDef("fc2", ConvKind.FC, w((5, 16))),
    ]


@pytest.fixture(scope="module")
def all_modes_plan():
    plan = engine.compile_model("q8_all_modes", _all_modes_defs())
    modes = [(lp.kind, lp.mode) for lp in plan.layers]
    # the chain must actually span both modes for every GEMM-kind
    assert (ConvKind.SC, engine.MODE_PACKED) in modes
    assert (ConvKind.SC, engine.MODE_DENSE) in modes
    assert (ConvKind.PC, engine.MODE_PACKED) in modes
    assert (ConvKind.PC, engine.MODE_DENSE) in modes
    assert (ConvKind.FC, engine.MODE_PACKED) in modes
    assert (ConvKind.FC, engine.MODE_DENSE) in modes
    assert (ConvKind.DC, engine.MODE_DEPTHWISE) in modes
    return plan


@pytest.mark.parametrize("batch", [1, 3, 5])
def test_q8_layerwise_matches_float_oracle(all_modes_plan, batch):
    """Satellite contract: per-image dequant-scale epilogues on the int8
    path, ragged batches, all four layer kinds, both modes — bitwise vs
    the float oracle, layer by layer."""
    plan = all_modes_plan
    rng = np.random.default_rng(batch)
    # per-image magnitudes spanning 4 orders: per-image DAC scales differ
    # wildly, so any cross-image scale leakage would flip integers
    mags = (10.0 ** np.arange(batch) / 100.0).reshape(batch, 1, 1, 1)
    x = jnp.asarray(rng.normal(size=(batch, 8, 8, 3)) * mags, jnp.float32)
    for lp in plan.layers:
        got = ex.forward_layer(plan, lp, x, interpret=True)
        want = ex.forward_layer_f32(plan, lp, x, interpret=True)
        _eq(got, want)
        x = got


def test_q8_batched_equals_per_image_loop(all_modes_plan):
    """Per-image quantization survives batching on the int8 path."""
    plan = all_modes_plan
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.normal(size=(4, 8, 8, 3))
                     * (10.0 ** np.arange(4)).reshape(4, 1, 1, 1) / 10.0,
                     jnp.float32)
    batched = engine.forward(plan, xs, interpret=True)
    for i in range(4):
        # a single image's FC output stays (1, F) by the engine contract
        _eq(batched[i], engine.forward(plan, xs[i], interpret=True)[0])


@pytest.mark.parametrize("model", list(zoo.SERVING_MODELS))
def test_zoo_q8_eager_jit_and_oracles(model):
    """Whole serving zoo: int8 path == float oracle == im2col oracle,
    eager AND whole-model jit, batched and ragged."""
    engine.pipeline_cache_clear()
    plan = engine.compile_model(f"q8_{model}", zoo.serving_defs(model, 0))
    shape = zoo.serving_input_shape(model)
    rng = np.random.default_rng(0)
    for batch in (1, 5):
        x = jnp.asarray(rng.normal(size=(batch, *shape)), jnp.float32)
        got = engine.forward(plan, x, interpret=True)
        _eq(got, engine.forward_f32(plan, x, interpret=True))
        _eq(got, engine.forward_im2col(plan, x, interpret=True))
        _eq(got, engine.forward_jit(plan, x, interpret=True))


def test_planner_plan_q8_bitwise(all_modes_plan):
    """Planner-compiled heterogeneous-point plans ride the q8 path too and
    stay bitwise-equal to the fixed-point plan."""
    defs = _all_modes_defs()
    planned = engine.plan_model("q8_all_modes_planned", defs, (8, 8, 3))
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(3, 8, 8, 3)), jnp.float32)
    _eq(engine.forward(planned, x, interpret=True),
        engine.forward(all_modes_plan, x, interpret=True))


# ---------------------------------------------------------------------------
# Plan weight bytes: the int8 imprint's HBM footprint
# ---------------------------------------------------------------------------

def test_plan_weight_bytes_halve_or_better(all_modes_plan):
    plan = all_modes_plan
    for lp in plan.layers:
        assert lp.rhs.dtype == jnp.int8       # pre-quantized at plan time
    assert plan.weight_bytes < plan.weight_bytes_f32
    # int8 operands + f32 scale/bias metadata: at least 2x under the f32
    # stream (in practice close to 4x — biases are the f32 remainder)
    assert plan.weight_bytes_f32 / plan.weight_bytes >= 2.0


def test_registry_weight_report():
    reg = PlanRegistry(capacity=2)
    reg.register("wr", lambda: _all_modes_defs(), input_shape=(8, 8, 3))
    reg.register("other", lambda: _all_modes_defs(), input_shape=(8, 8, 3))
    # cold-model report is read-only: computed out-of-band, nothing loaded
    rep_cold = reg.weight_report("wr")
    assert rep_cold["packed_bytes"] > 0
    assert rep_cold["ratio"] >= 2.0
    assert reg.loaded == []
    # resident report peeks the loaded plan without LRU promotion
    reg.get("wr")
    reg.get("other")                       # LRU order: [wr, other]
    rep = reg.weight_report("wr")
    assert rep == rep_cold
    assert reg.loaded == ["wr", "other"]   # no move_to_end from the peek
    st = reg.stats()
    assert st["weight_bytes_packed"] == 2 * rep["packed_bytes"]
    assert st["weight_bytes_f32_equiv"] == 2 * rep["f32_equiv_bytes"]
    with pytest.raises(KeyError):
        reg.weight_report("never_registered")
