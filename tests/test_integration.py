"""Cross-layer integration + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cnn.layers import pc
from repro.cnn.models import MODEL_ZOO
from repro.core import simulator as sim
from repro.core import tpc
from repro.launch.train import train_loop

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# end-to-end training per model family (reduced configs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-2.7b",
                                  "seamless-m4t-large-v2"])
def test_train_loop_family(arch):
    """MoE / SSM / enc-dec families train end-to-end with finite loss."""
    out = train_loop(arch, steps=3, batch=2, seq=32, log_every=100)
    assert np.isfinite(out["final_loss"])


def test_train_loop_quantized_opt_states():
    """grok's int8-moment path runs end-to-end (reduced config)."""
    out = train_loop("grok-1-314b", steps=3, batch=2, seq=32, log_every=100)
    assert np.isfinite(out["final_loss"])


# ---------------------------------------------------------------------------
# simulator properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 2000), f=st.integers(1, 256), hw=st.integers(1, 24))
def test_more_vdpes_never_slower(s, f, hw):
    """FPS is monotone non-decreasing in the VDPE count."""
    layer = pc("l", s, f, hw, hw)
    small = tpc.build_accelerator("RMAM", 1.0, n_vdpe=256)
    big = tpc.build_accelerator("RMAM", 1.0, n_vdpe=1024)
    t_small = sim.simulate_layer(small, layer).time_s
    t_big = sim.simulate_layer(big, layer).time_s
    assert t_big <= t_small * 1.0001


@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 2000), f=st.integers(1, 256))
def test_layer_time_positive_and_finite(s, f):
    layer = pc("l", s, f, 7, 7)
    for name in tpc.ACCELERATORS:
        rep = sim.simulate_layer(tpc.build_accelerator(name, 1.0), layer)
        assert 0 < rep.time_s < 10.0
        assert 0 < rep.utilization <= 1.0


def test_full_zoo_simulates_on_all_accelerators():
    """Every CNN in the zoo runs on every accelerator at every paper BR."""
    for cnn, build in MODEL_ZOO.items():
        layers = build()
        for name in ("RMAM", "AMM"):
            for br in (1.0, 5.0):
                rep = sim.simulate(tpc.build_accelerator(name, br), layers)
                assert np.isfinite(rep.fps) and rep.fps > 0, (cnn, name, br)


def test_reconfig_helps_most_on_depthwise_heavy_nets():
    """The paper's premise: DSC-heavy nets benefit most from Mode 2."""
    gains = {}
    for cnn in ("mobilenet_v1", "resnet50"):
        layers = MODEL_ZOO[cnn]()
        rmam = sim.simulate(tpc.build_accelerator("RMAM", 1.0), layers).fps
        mam = sim.simulate(tpc.build_accelerator("MAM", 1.0), layers).fps
        gains[cnn] = rmam / mam
    assert gains["mobilenet_v1"] > gains["resnet50"]


# ---------------------------------------------------------------------------
# kernels x numerics cross-check on real CNN layer shapes
# ---------------------------------------------------------------------------

def test_kernel_path_on_paper_dkv_sizes():
    """Mode routing handles the exact Table III DKV sizes."""
    from repro.core import vdp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for s in (8, 9, 12, 25, 27, 96, 640):
        divs = jnp.asarray(rng.integers(-7, 8, (32, s)), jnp.int8)
        dkvs = jnp.asarray(rng.integers(-7, 8, (16, s)), jnp.int8)
        got = ops.mixed_size_gemm(divs, dkvs, interpret=True)
        want = vdp.direct_quantized_gemm(divs, dkvs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
