"""Pallas kernel validation: interpret-mode allclose sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import vdpe_gemm as kern

jax.config.update("jax_platform_name", "cpu")


def _rand_int8(rng, shape, lo=-7, hi=8):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


@pytest.mark.parametrize("b,s,f", [
    (128, 128, 128), (256, 384, 128), (128, 256, 256), (384, 128, 384),
])
def test_vdpe_gemm_aligned(b, s, f):
    rng = np.random.default_rng(b + s + f)
    lhs = _rand_int8(rng, (b, s))
    rhs = _rand_int8(rng, (s, f))
    got = kern.vdpe_gemm(lhs, rhs, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.vdpe_gemm_ref(lhs, rhs)))


@pytest.mark.parametrize("p,s,f", [
    (1, 1, 1), (7, 9, 3), (100, 27, 64), (129, 130, 257), (64, 2304, 48),
    (200, 43, 512), (31, 3840, 8),
])
def test_mode1_gemm_shape_sweep(p, s, f):
    """Arbitrary (P, S, F) through the padded Mode-1 wrapper."""
    rng = np.random.default_rng(p * 7 + s * 3 + f)
    divs = _rand_int8(rng, (p, s))
    dkvs = _rand_int8(rng, (f, s))
    got = ops.mode1_gemm(divs, dkvs, interpret=True)
    want = ref.vdpe_gemm_ref(divs, dkvs.T)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("p,s,f,x,y", [
    (64, 9, 16, 32, 4), (128, 25, 200, 32, 4), (1, 32, 1, 32, 4),
    (100, 8, 33, 16, 8), (17, 27, 129, 32, 4),
])
def test_mode2_pack_gemm_shape_sweep(p, s, f, x, y):
    """Small-S contractions through the Mode-2 packed kernel."""
    rng = np.random.default_rng(p + s + f)
    divs = _rand_int8(rng, (p, s))
    dkvs = _rand_int8(rng, (f, s))
    got = ops.mode2_gemm(divs, dkvs, x=x, y=y, interpret=True)
    want = ref.vdpe_gemm_ref(divs, dkvs.T)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pack_mode2_weights_matches_ref():
    rng = np.random.default_rng(0)
    dkvs = _rand_int8(rng, (10, 9))
    got = ops.pack_mode2_weights(dkvs, x=16, y=8)
    want = ref.pack_block_diagonal_ref(dkvs, x=16, y=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mixed_size_gemm_routes_both_modes():
    rng = np.random.default_rng(1)
    for s in (8, 32, 64, 129, 400):          # spans Case 3 / padded / Case 1
        divs = _rand_int8(rng, (40, s))
        dkvs = _rand_int8(rng, (24, s))
        got = ops.mixed_size_gemm(divs, dkvs, interpret=True)
        want = ref.vdpe_gemm_ref(divs, dkvs.T)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"S={s}")


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,s,o", [(128, 128, 128), (64, 300, 77)])
def test_gemm_bf16_sweep(dtype, b, s, o):
    rng = np.random.default_rng(b + o)
    lhs = jnp.asarray(rng.normal(size=(b, s)), dtype)
    rhs = jnp.asarray(rng.normal(size=(s, o)), dtype)
    got = ops.gemm_bf16(lhs, rhs, interpret=True)
    want = ref.gemm_bf16_ref(lhs, rhs)
    # K-blocked accumulation reorders fp sums vs the single-dot oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-1 if dtype == jnp.bfloat16 else 1e-4)


def test_mode2_hbm_traffic_advantage():
    """The packed kernel's input BlockSpec is y-fold narrower than dense."""
    # structural check: lhs block is (BLOCK_B, x) vs (BLOCK_B, y*x)
    assert ops.X_TPU * (ops.N_TPU // ops.X_TPU) == ops.N_TPU


@pytest.mark.parametrize("t,d,h,e", [
    (200, 64, 48, 4), (17, 32, 32, 8), (512, 128, 128, 8), (1, 16, 8, 2),
    (300, 96, 200, 3),
])
def test_grouped_matmul_sweep(t, d, h, e):
    """MoE ragged GEMM kernel vs per-token oracle across shapes."""
    rng = np.random.default_rng(t + d + h + e)
    tokens = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    weights = jnp.asarray(rng.normal(size=(e, d, h)), jnp.float32)
    gids = jnp.asarray(rng.integers(0, e, t), jnp.int32)
    got = ops.grouped_matmul(tokens, weights, gids, interpret=True)
    want = ref.grouped_matmul_ref(tokens, weights, gids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_grouped_matmul_skewed_groups():
    """All tokens on one expert (max raggedness) still exact."""
    rng = np.random.default_rng(9)
    tokens = jnp.asarray(rng.normal(size=(100, 32)), jnp.float32)
    weights = jnp.asarray(rng.normal(size=(4, 32, 16)), jnp.float32)
    gids = jnp.full((100,), 2, jnp.int32)
    got = ops.grouped_matmul(tokens, weights, gids, interpret=True)
    want = ref.grouped_matmul_ref(tokens, weights, gids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,s,t,hd,causal", [
    (4, 128, 128, 64, True), (2, 256, 256, 128, True),
    (2, 128, 384, 64, False), (1, 256, 512, 32, True),
])
def test_flash_attention_sweep(bh, s, t, hd, causal):
    """Fused online-softmax attention vs naive oracle."""
    from repro.kernels.flash_attention import flash_attention_kernel
    rng = np.random.default_rng(bh + s + t)
    q = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, t, hd)), jnp.float32)
    got = flash_attention_kernel(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_conv_through_kernels_end_to_end():
    """im2col conv executed through the Pallas mixed-size path."""
    from repro.core import vdp
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 8, 3)), jnp.float32)
    kernels = jnp.asarray(rng.normal(size=(5, 3, 3, 3)), jnp.float32)
    divs = vdp.im2col(x, 3, 1, "SAME")
    dkvs = vdp.dkv_matrix(kernels)
    divs_q, sa = vdp.quantize_symmetric(divs)
    dkvs_q, sb = vdp.quantize_symmetric(dkvs)
    got = ops.mixed_size_gemm(divs_q, dkvs_q, interpret=True)
    want = vdp.direct_quantized_gemm(divs_q, dkvs_q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
