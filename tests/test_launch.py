"""Launch-layer tests: roofline parsing, specs, mesh, train/serve loops."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config, load_all
from repro.configs.shapes import SHAPES, applicable_shapes
from repro.launch import roofline

jax.config.update("jax_platform_name", "cpu")
load_all()

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# roofline unit tests
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %ag = bf16[8,4096,1024]{2,1,0} all-gather(%p0), replica_groups={...}
  %ar.1 = f32[256,128]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_census_parses_hlo():
    out = roofline.collective_bytes_from_hlo(SAMPLE_HLO)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 8 * 4096 * 1024 * 2
    assert out["all-reduce"]["bytes"] == 256 * 128 * 4
    assert out["reduce-scatter"]["count"] == 1
    assert out["collective-permute"]["bytes"] == 2 * 2 * 2
    assert out["total_count"] == 4


def test_model_flops_conventions():
    cfg = get_config("qwen1.5-0.5b")
    tr = roofline.model_flops(cfg, SHAPES["train_4k"])
    pf = roofline.model_flops(cfg, SHAPES["prefill_32k"])
    dc = roofline.model_flops(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.n_active_params() * 256 * 4096)
    assert pf == pytest.approx(2 * cfg.n_active_params() * 32 * 32768)
    assert dc == pytest.approx(2 * cfg.n_active_params() * 128)
    # MoE: active < total
    grok = get_config("grok-1-314b")
    assert (roofline.model_flops(grok, SHAPES["train_4k"])
            < 6 * grok.n_params() * 256 * 4096)


def test_roofline_terms_bound_selection():
    rec = {"n_chips": 256, "flops": 197e12, "bytes_accessed": 819e9 * 2,
           "collectives": {"total_bytes": 50e9 * 0.5}}
    cfg = get_config("qwen1.5-0.5b")
    out = roofline.roofline_terms(rec, cfg, SHAPES["train_4k"])
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(2.0)
    assert out["collective_s"] == pytest.approx(0.5)
    assert out["bound"] == "memory"
    assert out["roofline_fraction"] == pytest.approx(0.5)


def test_applicable_shapes_long_context_rule():
    long_ok = {a for a in load_all()
               if any(s.name == "long_500k"
                      for s in applicable_shapes(get_config(a)))}
    assert long_ok == {"mamba2-2.7b", "hymba-1.5b", "mixtral-8x7b",
                       "gemma2-2b"}


def test_total_cell_count():
    """40 assigned cells; full-attention archs skip long_500k."""
    cells = sum(len(applicable_shapes(get_config(a))) for a in load_all())
    assert cells == 4 * 10 - 6      # 34 runnable of the 40 (6 skips noted)


# ---------------------------------------------------------------------------
# mesh + dryrun integration (subprocess: needs 512 forced host devices)
# ---------------------------------------------------------------------------

def test_production_mesh_shapes_subprocess():
    code = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m1 = make_production_mesh();"
        "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape\n"
        "m2 = make_production_mesh(multi_pod=True);"
        "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}\n"
        "print('MESH_OK')\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "MESH_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """One full dry-run cell end-to-end (decode: fastest to compile)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k"],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO)
    assert "failures=0" in out.stdout, out.stdout + out.stderr[-2000:]
    path = os.path.join(REPO, "experiments", "dryrun",
                        "qwen1.5-0.5b_decode_32k_16x16.json")
    rec = json.load(open(path))
    assert rec["flops"] > 0
    assert rec["roofline"]["bound"] in ("compute", "memory", "collective")


# ---------------------------------------------------------------------------
# train / serve loops (reduced configs, real execution)
# ---------------------------------------------------------------------------

def test_train_loop_improves_and_resumes():
    from repro.launch.train import train_loop
    with tempfile.TemporaryDirectory() as d:
        out = train_loop("qwen1.5-0.5b", steps=6, batch=2, seq=32,
                         ckpt_dir=d, save_every=2, log_every=100)
        assert out["final_loss"] < out["first_loss"] + 1.0
        # resume continues from the checkpoint, not from scratch
        out2 = train_loop("qwen1.5-0.5b", steps=8, batch=2, seq=32,
                          ckpt_dir=d, save_every=2, log_every=100)
        assert out2["steps"] == 2           # only steps 6..7 remain


def test_train_loop_with_compression():
    from repro.launch.train import train_loop
    out = train_loop("qwen1.5-0.5b", steps=4, batch=2, seq=32,
                     use_compression=True, log_every=100)
    assert np.isfinite(out["final_loss"])


def test_batched_server_serves_requests():
    from repro.launch.serve import BatchedServer
    srv = BatchedServer("qwen1.5-0.5b", batch=2, ctx=64)
    rids = [srv.submit([5, 6, 7], max_tokens=4) for _ in range(3)]
    outs = srv.run_until_done()
    assert set(rids) == set(outs)
    assert all(len(v) == 4 for v in outs.values())


def test_batched_server_slot_recycling_keys_outputs():
    """More requests than slots: slots recycle and every request's output
    lands under its own id at full length."""
    from repro.launch.serve import BatchedServer
    srv = BatchedServer("qwen1.5-0.5b", batch=2, ctx=64)
    rids = [srv.submit([3 + i, 11, 7 + i], max_tokens=3) for i in range(5)]
    outs = srv.run_until_done()
    assert sorted(outs) == sorted(rids)
    assert all(len(outs[r]) == 3 for r in rids)


def test_slots_do_not_corrupt_each_others_context():
    """Regression: decode_fn writes every batch row's k/v at the scalar
    cache index, so a shared multi-row cache let one slot's step clobber
    the others' history.  With per-slot caches, a request served while
    another slot is busy must decode exactly what it decodes alone."""
    from repro.launch.serve import BatchedServer
    prompts = [[5, 6, 7], [42, 43, 44, 45]]
    busy = BatchedServer("qwen1.5-0.5b", batch=2, ctx=64)
    rids = [busy.submit(p, max_tokens=4) for p in prompts]
    got = busy.run_until_done()
    for prompt, rid in zip(prompts, rids):
        solo = BatchedServer("qwen1.5-0.5b", batch=1, ctx=64)
        srid = solo.submit(prompt, max_tokens=4)
        want = solo.run_until_done()[srid]
        assert got[rid] == want, (prompt, got[rid], want)


def test_decode_never_replays_prefilled_positions(monkeypatch):
    """Regression: the decode loop used to re-feed the last prompt token at
    pos-1, replaying an already-prefilled cache position.  Every (slot,
    position) sequence must be strictly increasing within one request's
    occupancy (resets mark slot recycling)."""
    from repro.launch.serve import BatchedServer
    srv = BatchedServer("qwen1.5-0.5b", batch=2, ctx=64)
    fed = []
    orig = srv._step_slot

    def spy(slot, token, pos):
        fed.append((slot, int(pos)))
        return orig(slot, token, pos)

    monkeypatch.setattr(srv, "_step_slot", spy)
    for i in range(4):
        srv.submit([5, 6, 7 + i], max_tokens=3)
    srv.run_until_done()
    per_slot = {}
    for slot, pos in fed:
        per_slot.setdefault(slot, []).append(pos)
    for slot, positions in per_slot.items():
        for prev, nxt in zip(positions, positions[1:]):
            # strictly increasing within a request; a drop back to 0 is the
            # next request being prefilled into the recycled slot
            assert nxt > prev or nxt == 0, (slot, positions)
