"""Overload-robustness tests: priority/deadline batching, the brownout
ladder's hysteresis and bitwise-downshift contract, typed shedding
(queue bound, brownout door, expiry sweep), and resource hygiene
(dispatcher pool close/context-manager, server reset).

Everything server-side runs on a virtual clock with an injected
``service_model`` — modeled seconds, deterministic across hosts.
"""
import jax
import numpy as np
import pytest

from repro import engine, obs, serve
from repro.cnn.layers import ConvKind
from repro.serve.batcher import ContinuousBatcher, DynamicBatcher
from repro.serve.registry import PlanRegistry

jax.config.update("jax_platform_name", "cpu")

BASE = serve.OperatingPoint("RMAM", 1.0)
RECONF = serve.OperatingPoint("RMAM", 1.0, reconfigurable=True)


def _tiny_factory(seed=0, f=6, s=5):
    def factory():
        rng = np.random.default_rng(seed)
        w = np.asarray(rng.normal(size=(f, 1, 1, s)), np.float32)
        return [engine.LayerDef("pc", ConvKind.PC, w, act="relu")]
    return factory


def _tiny_registry(names, capacity=4, planner=False):
    reg = PlanRegistry(capacity=capacity, planner=planner)
    for i, name in enumerate(names):
        reg.register(name, _tiny_factory(seed=i), input_shape=(4, 4, 5))
    return reg


class _Clock:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


def _virtual_server(reg, clock, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.0)
    kw.setdefault("time_fn", clock.now)
    kw.setdefault("service_model",
                  lambda model, batch, point:
                  0.01 * batch / (2.0 if point.reconfigurable else 1.0))
    return serve.CNNServer(reg, **kw)


# ---------------------------------------------------------------------------
# batcher: deadlines, expiry, flush-deadline regression
# ---------------------------------------------------------------------------

def test_expiry_sweep_returns_dead_requests_and_keeps_order():
    b = DynamicBatcher(max_batch=8, max_wait_s=10.0)
    r1 = b.submit("m", None, now=0.0, deadline_s=1.0)
    r2 = b.submit("m", None, now=0.0)               # no deadline: immortal
    r3 = b.submit("m", None, now=0.5, deadline_s=1.0)
    assert b.expire(now=0.5) == []
    expired = b.expire(now=1.2)                     # r1 dead, r3 alive
    assert [r.rid for r in expired] == [r1]
    assert b.pending() == 2
    expired = b.expire(now=2.0)
    assert [r.rid for r in expired] == [r3]
    fb = b.pop_batch(now=2.0, force=True)
    assert [r.rid for r in fb.requests] == [r2]


def test_flush_deadline_recomputed_after_head_expiry():
    """Satellite regression: the oldest-wait flush signal must scan LIVE
    requests, not trust the queue head — an expired head would otherwise
    keep forcing flushes (or mask a younger request's wait) forever."""
    b = DynamicBatcher(max_batch=8, max_wait_s=1.0)
    b.submit("m", None, now=0.0, deadline_s=2.0)    # will die at t=2
    r2 = b.submit("m", None, now=1.5)
    # head alive: it is the oldest wait
    assert b.oldest_wait_s(1.9) == pytest.approx(1.9)
    # head dead (no expire() call needed): r2's wait, not the corpse's
    assert b.oldest_wait_s(2.5) == pytest.approx(1.0)
    fb = b.pop_batch(now=2.5, force=False)          # r2 stale past max_wait
    assert fb is not None and [r.rid for r in fb.requests] == [r2]
    # the corpse is never selected; the explicit sweep fails it typed
    assert b.oldest_wait_s(3.0) is None
    assert len(b.expire(now=3.0)) == 1
    assert b.pending() == 0


def test_submit_validates_priority_and_deadline():
    b = DynamicBatcher()
    with pytest.raises(ValueError, match="priority"):
        b.submit("m", None, now=0.0, priority="urgent")
    with pytest.raises(ValueError, match="deadline_s"):
        b.submit("m", None, now=0.0, deadline_s=0.0)


# ---------------------------------------------------------------------------
# batcher: two-class priority + aging, bounded queue
# ---------------------------------------------------------------------------

def test_interactive_selected_before_older_batch_requests():
    b = DynamicBatcher(max_batch=2, max_wait_s=0.0)
    b1 = b.submit("m", None, now=0.0, priority=serve.BATCH)
    b2 = b.submit("m", None, now=0.1, priority=serve.BATCH)
    i1 = b.submit("m", None, now=0.5, priority=serve.INTERACTIVE)
    fb = b.pop_batch(now=0.5)
    # interactive jumps the line; the older batch request fills the slot
    assert {r.rid for r in fb.requests} == {i1, b1}
    # within the formed batch, submission order is preserved (stacking
    # order is part of the bitwise contract)
    assert [r.rid for r in fb.requests] == [b1, i1]
    fb2 = b.pop_batch(now=0.5, force=True)
    assert [r.rid for r in fb2.requests] == [b2]


def test_batch_class_ages_into_interactive_precedence():
    b = DynamicBatcher(max_batch=1, max_wait_s=0.0, age_promote_s=5.0)
    old = b.submit("m", None, now=0.0, priority=serve.BATCH)
    b.submit("m", None, now=4.9, priority=serve.INTERACTIVE)
    # before promotion: interactive first despite being younger
    fb = b.pop_batch(now=4.9)
    assert fb.priorities() == [serve.INTERACTIVE]
    # past age_promote_s the starved batch request outranks a fresh
    # interactive one (same promoted class, older submission)
    b.submit("m", None, now=5.1, priority=serve.INTERACTIVE)
    fb = b.pop_batch(now=5.1)
    assert [r.rid for r in fb.requests] == [old]


def test_bounded_queue_sheds_typed_and_counts_nothing():
    b = DynamicBatcher(max_batch=8, max_wait_s=0.0, max_queue=2)
    b.submit("m", None, now=0.0)
    b.submit("m", None, now=0.0)
    with pytest.raises(serve.QueueOverflow) as ei:
        b.submit("m", None, now=0.0)
    assert ei.value.model == "m" and ei.value.max_queue == 2
    assert b.pending("m") == 2                       # nothing half-queued
    b.submit("other", None, now=0.0)                 # per-model bound


def test_continuous_batcher_is_work_conserving_for_promoted_work():
    cont = ContinuousBatcher(max_batch=8, max_wait_s=60.0)
    plain = DynamicBatcher(max_batch=8, max_wait_s=60.0)
    for b in (cont, plain):
        b.submit("m", None, now=0.0, priority=serve.INTERACTIVE)
    # the continuous batcher dispatches a lone interactive request NOW;
    # the plain batcher holds for batch-mates until max_wait
    assert plain.pop_batch(now=0.0) is None
    fb = cont.pop_batch(now=0.0)
    assert fb is not None and fb.size == 1
    # batch-class work still waits for the window (it is not starved —
    # aging promotes it — but it must not defeat batching amortization)
    cont.submit("m", None, now=1.0, priority=serve.BATCH)
    assert cont.pop_batch(now=1.0) is None
    assert cont.pop_batch(now=1.0, force=True).size == 1


# ---------------------------------------------------------------------------
# server: expiry sweep, typed failures, class-aware admission
# ---------------------------------------------------------------------------

def test_server_expires_queued_requests_with_typed_failures():
    clock = _Clock()
    reg = _tiny_registry(["m1"])
    srv = _virtual_server(reg, clock, max_batch=8, max_wait_s=60.0)
    x = np.zeros((4, 4, 5), np.float32)
    doomed = srv.submit("m1", x, deadline_s=0.5)
    safe = srv.submit("m1", x)
    clock.t = 1.0
    srv.step()                                       # sweep runs first
    fail = srv.failures[doomed]
    assert isinstance(fail, serve.RequestExpired)
    assert fail.model == "m1" and fail.deadline_s == pytest.approx(0.5)
    assert fail.waited_s == pytest.approx(1.0)
    assert doomed not in srv.results
    assert srv.admission["expired"] == 1
    m = srv.telemetry.metrics
    assert m.counter("serve_requests_expired_total",
                     model="m1").value == 1.0
    outs = srv.run_until_drained()
    assert safe in outs and doomed not in outs


def test_interactive_admission_ignores_unpromoted_batch_backlog():
    """Class-aware admission: a deep batch-class backlog must not shed
    interactive traffic the priority scheduler would serve in time."""
    clock = _Clock()
    reg = _tiny_registry(["m1"])
    srv = _virtual_server(reg, clock, max_batch=4, max_wait_s=60.0,
                          continuous=True,
                          slo=serve.ServeSLO(deadline_s=0.1,
                                             min_observations=1))
    x = np.zeros((4, 4, 5), np.float32)
    srv.submit("m1", x)
    srv.step(force=True)                             # seed the EMA
    # bury the queue in batch-class work: full depth would blow the SLO
    for _ in range(40):
        srv.submit("m1", x, priority=serve.BATCH)
    est_batch = srv.estimated_completion_s(priority=serve.BATCH)
    est_inter = srv.estimated_completion_s(priority=serve.INTERACTIVE,
                                           now=clock.t)
    assert est_batch > 0.1          # the backlog itself is past deadline
    assert est_inter < est_batch    # ...but interactive jumps it
    rid = srv.submit("m1", x)       # admitted, not shed
    assert srv.admission["shed"] == 0
    # a batch request carrying its own deadline IS estimate-checked
    with pytest.raises(serve.AdmissionRejected):
        srv.submit("m1", x, priority=serve.BATCH, deadline_s=0.05)
    outs = srv.run_until_drained()
    assert rid in outs


# ---------------------------------------------------------------------------
# brownout controller: hysteresis, power guard
# ---------------------------------------------------------------------------

def test_controller_validates_hysteresis_bands():
    with pytest.raises(ValueError, match="queue_low < queue_high"):
        serve.BrownoutController(queue_high=4, queue_low=4)
    with pytest.raises(ValueError, match="latency_low < latency_high"):
        serve.BrownoutController(latency_high=0.5, latency_low=0.5)
    with pytest.raises(ValueError, match="max_wait_scale"):
        serve.BrownoutRung("bad", max_wait_scale=0.5)


def test_hysteresis_never_oscillates_under_sinusoidal_load():
    """Property: opposite-direction transitions are separated by at least
    the relevant dwell/cooldown, whatever the load trace does — driven
    with a sinusoid straddling both bands, the worst case for chatter."""
    ctl = serve.BrownoutController(
        queue_high=16, queue_low=4,
        escalate_dwell_s=0.3, recover_cooldown_s=1.1)
    period = 2.0
    for i in range(4000):
        t = i * 0.01
        depth = int(16 + 14 * np.sin(2 * np.pi * t / period))
        ctl.observe(t, depth)
    trs = ctl.transitions
    assert len(trs) >= 4                             # it did move
    for prev, cur in zip(trs, trs[1:]):
        gap = cur.t - prev.t
        if cur.direction == "escalate":
            assert gap >= ctl.escalate_dwell_s - 1e-9
        else:
            assert gap >= ctl.recover_cooldown_s - 1e-9
    # and the counters reconcile with the trajectory
    c = ctl.counters
    assert c["escalations"] - c["deescalations"] == ctl.rung_index


def test_recovery_requires_the_lower_band_not_just_sub_high():
    ctl = serve.BrownoutController(queue_high=8, queue_low=2,
                                   escalate_dwell_s=0.0,
                                   recover_cooldown_s=0.0)
    assert ctl.observe(0.0, depth=8) is not None     # escalate
    # depth 5: below the high band but above the low one — hold the rung
    assert ctl.observe(1.0, depth=5) is None
    assert ctl.rung_index == 1
    tr = ctl.observe(2.0, depth=1)                   # under low band
    assert tr is not None and tr.direction == "recover"
    assert ctl.rung_index == 0


def test_power_cap_blocks_downshift_and_counts_it():
    cap = RECONF.to_accelerator().power_w() - 1.0    # just under the rung
    ctl = serve.BrownoutController(
        queue_high=2, queue_low=1, escalate_dwell_s=0.0,
        recover_cooldown_s=0.0, power_cap_w=cap)
    for t in range(10):
        ctl.observe(float(t), depth=50)
    # climbed the no-point rungs, then hit the power wall below downshift
    assert ctl.rung.name == "shed_batch"
    assert ctl.counters["power_blocked"] > 0
    assert ctl.counters["downshifts"] == 0


# ---------------------------------------------------------------------------
# server + brownout: the full ladder on a virtual clock
# ---------------------------------------------------------------------------

def _ladder_server(clock, tracer=None, planner=True):
    reg = _tiny_registry(["m1"], planner=planner)
    brown = serve.BrownoutController(
        queue_high=4, queue_low=1,
        escalate_dwell_s=0.0, recover_cooldown_s=0.0)
    srv = _virtual_server(reg, clock, max_batch=2, max_wait_s=0.01,
                          continuous=True, brownout=brown, tracer=tracer)
    return reg, brown, srv


def test_brownout_ladder_escalates_sheds_downshifts_and_recovers():
    clock = _Clock()
    tracer = obs.Tracer(time_fn=clock.now)
    reg, brown, srv = _ladder_server(clock, tracer=tracer)
    x = np.zeros((4, 4, 5), np.float32)
    base_wait = srv.batcher.max_wait_s
    assert srv.serving_point == BASE

    for _ in range(8):
        srv.submit("m1", x)                          # depth past the band
    srv.step()                                       # -> stretch_wait
    assert brown.rung.name == "stretch_wait"
    assert srv.batcher.max_wait_s == pytest.approx(4 * base_wait)
    srv.step()                                       # -> shed_batch
    assert brown.rung.name == "shed_batch"
    with pytest.raises(serve.BrownoutShed) as ei:
        srv.submit("m1", x, priority=serve.BATCH)
    assert ei.value.rung == "shed_batch"
    assert srv.admission["brownout_shed"] == 1
    srv.submit("m1", x)                              # interactive still in
    srv.step()                                       # -> downshift
    assert brown.rung.name == "downshift"
    assert srv.serving_point == RECONF               # comb-switch retuned
    assert reg.stats()["replans"] == 1               # planner replanned
    assert brown.counters["downshifts"] == 1

    srv.run_until_drained()
    # queue empty: each further step recovers one rung (cooldown 0)
    for _ in range(3):
        srv.step()
    assert brown.rung_index == 0
    assert srv.serving_point == BASE                 # point restored...
    assert reg.stats()["replans"] == 2               # ...via a replan
    assert srv.batcher.max_wait_s == pytest.approx(base_wait)

    # transitions are observable: metrics counters + trace instants
    m = srv.telemetry.metrics
    assert m.counter("serve_brownout_transitions_total",
                     direction="escalate").value == 3.0
    assert m.counter("serve_brownout_transitions_total",
                     direction="recover").value == 3.0
    assert m.gauge("serve_brownout_rung").value == 0.0
    rungs = [e for e in tracer.events()
             if e.name == "brownout.rung"]
    assert len(rungs) == 6
    switches = [e for e in tracer.events()
                if e.name == "serve.point_switch"]
    assert len(switches) == 2                        # down and back
    # and the fleet summary carries the controller's report
    rep = srv.telemetry.summary()["fleet"]["brownout"]
    assert rep["rung"] == 0 and rep["counters"]["downshifts"] == 1


def test_downshifted_rung_serves_bitwise_identical_outputs():
    """Satellite: every rung's operating point — including the planner
    replan at the downshift rung — must serve bit-identical outputs."""
    clock = _Clock()
    reg = _tiny_registry(["m1"], planner=True)
    srv = _virtual_server(reg, clock, max_batch=4, continuous=True)
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(3, 4, 4, 5)).astype(np.float32)
    outs_by_point = {}
    for rung in serve.DEFAULT_LADDER:
        point = rung.point if rung.point is not None else BASE
        srv.set_operating_point(point)
        rids = [srv.submit("m1", x) for x in xs]
        res = srv.run_until_drained()
        outs_by_point[rung.name] = [res[r] for r in rids]
        srv.reset()
    base = outs_by_point["nominal"]
    for name, outs in outs_by_point.items():
        for got, want in zip(outs, base):
            np.testing.assert_array_equal(got, want, err_msg=name)
    # exactly one device move: the three no-point rungs share the base
    # point (no spurious replans), only the downshift rung retunes
    assert reg.stats()["replans"] == 1


def test_set_operating_point_is_noop_for_same_point():
    clock = _Clock()
    reg = _tiny_registry(["m1"], planner=True)
    srv = _virtual_server(reg, clock)
    srv.set_operating_point(BASE)                    # == telemetry primary
    assert reg.stats()["replans"] == 0
    m = srv.telemetry.metrics
    assert m.counter("serve_point_switches_total").value == 0.0


# ---------------------------------------------------------------------------
# resource hygiene: dispatcher close/context-manager, server reset
# ---------------------------------------------------------------------------

def test_dispatcher_context_manager_closes_pool():
    reg = _tiny_registry(["m1"])
    x = np.zeros((2, 4, 4, 5), np.float32)
    with serve.ShardedDispatcher(serve.default_fleet(2)) as fleet:
        entry = reg.get("m1")
        out, runs = fleet.run(entry.plan, x)
        assert fleet._pool is not None               # lazily created
    assert fleet._pool is None                       # closed on exit
    # close() is idempotent and the pool is lazily recreated after it
    fleet.close()
    out2, _ = fleet.run(entry.plan, x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    fleet.close()


def test_server_reset_closes_pool_and_clears_admission():
    clock = _Clock()
    fleet = serve.ShardedDispatcher(serve.default_fleet(2))
    reg = _tiny_registry(["m1"])
    srv = _virtual_server(reg, clock, max_batch=2, dispatcher=fleet,
                          max_queue=1)
    x = np.zeros((4, 4, 5), np.float32)
    srv.submit("m1", x)
    with pytest.raises(serve.QueueOverflow):
        srv.submit("m1", x)
    srv.run_until_drained()
    assert fleet._pool is not None
    assert srv.admission["admitted"] == 1
    assert srv.admission["queue_shed"] == 1
    srv.reset()
    assert fleet._pool is None                       # no pool leak
    assert all(v == 0 for v in srv.admission.values())
    assert srv.failures == {} and srv.results == {}
    rid = srv.submit("m1", x)                        # still servable
    assert rid in srv.run_until_drained()
    fleet.close()
