"""LM substrate tests: per-arch smoke + numerics equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_all, get_config
from repro.models import build_model
from repro.models import ssm as ssm_mod
from repro.models.transformer import layer_windows, GLOBAL_WINDOW

jax.config.update("jax_platform_name", "cpu")

ARCHS = list(load_all().keys())


def _train_batch(r, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if r.n_encoder_layers:
        batch["enc_embeds"] = jnp.full((b, s, r.d_model), 0.01, jnp.float32)
    if r.prefix_len:
        batch["prefix_embeds"] = jnp.full((b, r.prefix_len, r.d_model),
                                          0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward + backward, finite, right shapes."""
    r = get_config(arch).reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(0))
    batch = _train_batch(r)
    loss, grads = jax.jit(jax.value_and_grad(m.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    r = get_config(arch).reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(0))
    b, ctx = 2, 64
    cache = m.init_cache(b, ctx)
    dbatch = {"tokens": jnp.ones((b, 1), jnp.int32)}
    if r.n_encoder_layers:
        hd, nkv = r.resolved_head_dim, r.n_kv_heads
        dbatch["cross_k"] = jnp.zeros((r.n_layers, b, 16, nkv, hd), r.dtype)
        dbatch["cross_v"] = jnp.zeros((r.n_layers, b, 16, nkv, hd), r.dtype)
    logits, cache2 = jax.jit(m.decode_fn)(params, dbatch, cache,
                                          jnp.int32(3))
    assert logits.shape == (b, 1, r.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    # cache was written at slot 3
    if "k" in cache2:
        assert not np.allclose(np.asarray(cache2["k"][:, :, 3]), 0.0)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-2.7b",
                                  "hymba-1.5b", "gemma2-2b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the parallel forward's logits."""
    r = get_config(arch).reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(1))
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, r.vocab)
    full = m.prefill_fn(params, {"tokens": tokens})      # last-pos logits
    cache = m.init_cache(b, s)
    decode = jax.jit(m.decode_fn)
    for t in range(s):
        logits, cache = decode(params, {"tokens": tokens[:, t:t + 1]},
                               cache, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD scan == step-by-step recurrence (the SSD identity)."""
    r = get_config("mamba2-2.7b").reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(3))
    lp = jax.tree.map(lambda p: p[0], params["layers"])   # layer 0
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, r.d_model),
                          jnp.float32) * 0.3
    y_par, _ = ssm_mod.ssm_apply(lp["ssm"], x, r, state=None)
    state = ssm_mod.init_ssm_state(r, b)
    ys = []
    for t in range(s):
        y_t, state = ssm_mod.ssm_apply(lp["ssm"], x[:, t:t + 1], r,
                                       state=state)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_layer_windows_gemma2_alternation():
    cfg = get_config("gemma2-2b")
    w = np.asarray(layer_windows(cfg))
    assert (w[0::2] == cfg.sliding_window).all()
    assert (w[1::2] == int(GLOBAL_WINDOW)).all()


def test_sliding_window_restricts_attention():
    """A token beyond the window cannot influence the output (mixtral)."""
    r = get_config("mixtral-8x7b").reduced()      # window 16
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(5))
    s = 24
    t1 = jax.random.randint(jax.random.PRNGKey(6), (1, s), 0, r.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % r.vocab)   # perturb pos 0
    l1 = m.prefill_fn(params, {"tokens": t1})
    l2 = m.prefill_fn(params, {"tokens": t2})
    # last position (23) only sees (7, 23]; pos 0 is outside every layer's
    # window in this 2-layer reduced model's receptive field? NO — depth
    # widens the receptive field (2 layers x window 16 covers pos 0), so
    # instead check a 1-layer slice: rerun with n_layers=1.
    import dataclasses
    r1 = dataclasses.replace(r, n_layers=1)
    m1 = build_model(r1)
    p1 = m1.init(jax.random.PRNGKey(5))
    l1 = m1.prefill_fn(p1, {"tokens": t1})
    l2 = m1.prefill_fn(p1, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


def test_qwen_qkv_bias_present():
    r = get_config("qwen1.5-0.5b").reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(0))
    assert "bq" in params["layers"]["attn"]


def test_moe_aux_loss_nonzero():
    r = get_config("mixtral-8x7b").reduced()
    m = build_model(r)
    params = m.init(jax.random.PRNGKey(0))
    loss_with = m.loss_fn(params, _train_batch(r))
    assert np.isfinite(float(loss_with))


def test_param_count_formula_matches_init():
    """Analytic n_params() agrees with abstract init sizes (FULL configs).

    jax.eval_shape materializes nothing, so this checks the real 314B-param
    structures too.
    """
    for arch in ARCHS:
        cfg = get_config(arch)
        m = build_model(cfg)
        shapes = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in
                     jax.tree_util.tree_leaves(shapes))
        assert actual == pytest.approx(cfg.n_params(), rel=0.02), arch
