"""Reconfiguration-aware planner tests: determinism, bitwise equivalence
vs the fixed-point plan, switch-penalty monotonicity, and the search-cache
lifecycle (plan_cache_clear + registry LRU eviction)."""
import jax
import numpy as np
import pytest

from repro import engine, serve
from repro.cnn.models import MODEL_ZOO
from repro.core import mapping
from repro.core.tpc import accelerator_at, build_accelerator
from repro.engine import plan as plan_mod
from repro.serve import models as zoo

jax.config.update("jax_platform_name", "cpu")

DS_MODELS = tuple(zoo.SERVING_MODELS)   # all minis are depthwise-separable


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    yield
    engine.plan_cache_clear()


def _planned(name, seed=0):
    defs = zoo.serving_defs(name, seed)
    return engine.plan_model(f"{name}#t", defs,
                             zoo.serving_input_shape(name)), defs


# ---------------------------------------------------------------------------
# operating-point candidates
# ---------------------------------------------------------------------------

def test_point_options_honor_comb_switch_constraint():
    for n in (16, 22, 27, 43):
        opts = mapping.point_options(n)
        assert opts[-1] == mapping.FIXED_POINT_OPTION
        recon = opts[:-1]
        assert recon, f"no reconfigurable option for n={n}"
        assert recon[0].x == mapping.REAGG_SIZE_X or n < 2 * mapping.REAGG_SIZE_X
        for o in recon:
            assert n >= 2 * o.x, (n, o.x)   # y > 0 (paper Section V-A)
            tpc = mapping.tpc_at(build_accelerator("RMAM", 1.0).tpc_config, o)
            assert tpc.y > 0


def test_accelerator_at_changes_only_geometry():
    acc = build_accelerator("RMAM", 1.0)
    acc2 = accelerator_at(acc, mapping.PointOption(x=21))
    assert acc2.x == 21 and acc2.n == acc.n and acc2.n_vdpe == acc.n_vdpe
    fixed = accelerator_at(acc, mapping.FIXED_POINT_OPTION)
    assert fixed.y == 0 and fixed.tpc_config.y == 0


# ---------------------------------------------------------------------------
# search: determinism + monotonicity + uplift
# ---------------------------------------------------------------------------

def test_search_deterministic_same_defs_same_sequence():
    specs = MODEL_ZOO["xception"]()
    a = engine.search_points(specs)
    b = engine.search_points(specs)
    assert a.labels == b.labels
    assert a.total_time_s == b.total_time_s
    # and through plan_model: identical point sequence for identical defs
    p1, _ = _planned("efficientnet_mini")
    engine.plan_cache_clear()
    p2, _ = _planned("efficientnet_mini")
    assert p1.point_labels == p2.point_labels
    assert p1.points == p2.points


def test_switch_penalty_monotonicity():
    specs = MODEL_ZOO["shufflenet_v2"]()
    penalties = (0.0, 1e-9, 1e-6, 1e-3, 1.0)
    switches = [engine.search_points(specs, switch_penalty_s=p).switches
                for p in penalties]
    assert switches == sorted(switches, reverse=True)
    assert switches[0] > 0          # free switching does reconfigure
    assert switches[-1] == 0        # a frame-dominating penalty pins one point


def test_planner_beats_fixed_geometry_on_paper_tables():
    for name in ("efficientnet_b7", "xception", "shufflenet_v2"):
        rep = engine.search_points(MODEL_ZOO[name]())
        assert rep.uplift > 1.3, (name, rep.uplift)
        assert rep.mean_utilization > rep.fixed_utilization
        # total time accounts for every switch at the charged penalty
        assert rep.total_time_s == pytest.approx(
            sum(c.time_s for c in rep.choices)
            + rep.switches * rep.switch_penalty_s)


def test_search_rejects_empty_options():
    with pytest.raises(ValueError):
        engine.search_points(MODEL_ZOO["xception"]()[:3], options=())


# ---------------------------------------------------------------------------
# planned plans: bitwise identity + differing census/points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DS_MODELS)
def test_planned_plan_bitwise_equals_fixed_plan(name):
    planned, defs = _planned(name)
    fixed = engine.compile_model(f"{name}#fixed", defs, engine.DEFAULT_POINT)
    rng = np.random.default_rng(7)
    xb = rng.normal(size=(3, *zoo.serving_input_shape(name))).astype(
        np.float32)
    np.testing.assert_array_equal(
        np.asarray(engine.forward(planned, xb)),
        np.asarray(engine.forward(fixed, xb)))
    # the jitted pipeline agrees too (per-layer points are static in it)
    np.testing.assert_array_equal(
        np.asarray(engine.forward_jit(planned, xb)),
        np.asarray(engine.forward(fixed, xb)))


@pytest.mark.parametrize("name", DS_MODELS)
def test_planned_point_sequence_and_census_differ(name):
    planned, defs = _planned(name)
    fixed = engine.compile_model(f"{name}#fixed", defs, engine.DEFAULT_POINT)
    assert planned.planner is not None and fixed.planner is None
    assert planned.points != fixed.points
    assert planned.mode_census != fixed.mode_census
    # heterogeneous: the planner used more than one hardware point
    assert len(set(planned.point_labels)) > 1


def test_planned_layers_keep_quantization_bits():
    planned, defs = _planned("xception_mini")
    for lp in planned.layers:
        assert lp.point.bits == engine.DEFAULT_POINT.bits


def test_packed_width_covers_contraction():
    planned, _ = _planned("xception_mini")
    for lp in planned.layers:
        if lp.mode == engine.MODE_PACKED:
            assert lp.point.x >= lp.s
            assert lp.rhs.shape[0] == lp.point.x


# ---------------------------------------------------------------------------
# search cache lifecycle
# ---------------------------------------------------------------------------

def test_search_cache_memoizes_and_clears():
    _planned("efficientnet_mini")
    info = engine.plan_cache_info()
    assert info["search_misses"] == 1 and info["search_size"] == 1
    _planned("efficientnet_mini")
    info = engine.plan_cache_info()
    assert info["search_hits"] == 1
    engine.plan_cache_clear()          # satellite: clears the search memo
    info = engine.plan_cache_info()
    assert info["search_size"] == 0
    assert info["search_hits"] == info["search_misses"] == 0


def test_search_cache_guards_structural_reuse():
    defs = zoo.serving_defs("efficientnet_mini", 0)
    shape = zoo.serving_input_shape("efficientnet_mini")
    engine.plan_model("dup", defs, shape)
    other = zoo.serving_defs("xception_mini", 0)
    with pytest.raises(ValueError, match="structurally different"):
        engine.plan_model("dup", other,
                          zoo.serving_input_shape("xception_mini"))


def test_registry_eviction_drops_search_cache():
    reg = serve.paper_cnn_registry(capacity=1, planner=True)
    names = list(zoo.SERVING_MODELS)
    reg.get(names[0])
    assert any(k[0] == names[0] for k in plan_mod._SEARCH_CACHE)
    reg.get(names[1])                  # evicts names[0]
    assert not any(k[0] == names[0] for k in plan_mod._SEARCH_CACHE)
    assert any(k[0] == names[1] for k in plan_mod._SEARCH_CACHE)
    # re-load recomputes the search and serves bit-identical outputs
    rng = np.random.default_rng(3)
    x = rng.normal(size=zoo.serving_input_shape(names[0])).astype(np.float32)
    before = np.asarray(engine.forward(reg.get(names[0]).plan, x))
    reg.get(names[1])
    after = np.asarray(engine.forward(reg.get(names[0]).plan, x))
    np.testing.assert_array_equal(before, after)


def test_planner_registry_serves_bitwise_vs_fixed_registry():
    reg_p = serve.paper_cnn_registry(planner=True)
    reg_f = serve.paper_cnn_registry(planner=False)
    rng = np.random.default_rng(11)
    for name in zoo.SERVING_MODELS:
        x = rng.normal(size=(2, *zoo.serving_input_shape(name))).astype(
            np.float32)
        np.testing.assert_array_equal(
            np.asarray(engine.forward_jit(reg_p.get(name).plan, x)),
            np.asarray(engine.forward_jit(reg_f.get(name).plan, x)))
        assert reg_p.get(name).plan.point_labels is not None
