"""Silent-data-corruption defense tests.

Covers the whole inject -> detect -> contain -> recover chain:

* ABFT row/column checksums detect ANY single corrupted accumulator
  element (property-based under hypothesis when installed, a seeded
  sweep otherwise — the container does not ship hypothesis);
* the guarded execution twin is bitwise-identical to the plain jitted
  pipeline on clean dispatches, and corruption injection is
  deterministic under seed replay;
* the dispatcher flags corrupted shards (``OutputCorrupted``),
  re-executes them bitwise-identically on healthy instances, and
  records detection latency;
* readmission probes reject instances that would still corrupt values;
* the planner's SNR budget filter (Eq. 9) excludes infeasible operating
  points without perturbing plans that never used them;
* the server's corrupted-frame-rate SLO sheds typed and recovers.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, serve
from repro.core import photonics as ph
from repro.core import vdp
from repro.core.mapping import TPCConfig
from repro.core.tpc import build_accelerator
from repro.engine import plan as plan_mod
from repro.obs.metrics import MetricsRegistry
from repro.serve import models as zoo
from repro.serve.faults import (AVAILABILITY_KINDS, FAILING_KINDS,
                                INTEGRITY_KINDS, CorruptionSpec)

jax.config.update("jax_platform_name", "cpu")

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container ships no hypothesis; seeded sweep
    HAVE_HYPOTHESIS = False

MODEL = "shufflenet_mini"


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    engine.pipeline_cache_clear()
    yield
    engine.plan_cache_clear()
    engine.pipeline_cache_clear()


def _plan(key):
    return engine.compile_model(f"sdc-{key}", zoo.serving_defs(MODEL))


def _batch(b, seed=0, model=MODEL):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(
        size=(b, *zoo.serving_input_shape(model))).astype(np.float32))


# ---------------------------------------------------------------------------
# ABFT: any single corrupted element is detected (exactly, no tolerances)
# ---------------------------------------------------------------------------

def _check_abft_single_corruption(seed: int) -> None:
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 6))
    s = int(rng.integers(1, 9))
    f = int(rng.integers(1, 7))
    lhs = rng.integers(-7, 8, (b, s)).astype(np.int32)
    rhs = rng.integers(-7, 8, (s, f)).astype(np.int32)
    acc = lhs @ rhs
    clean = int(engine.abft_flags(jnp.asarray(lhs), jnp.asarray(rhs),
                                  jnp.asarray(acc)))
    assert clean == 0, "ABFT flagged a clean GEMM"
    i, j = int(rng.integers(b)), int(rng.integers(f))
    # any nonzero delta, including ones that wrap int32 (the checksum
    # identities hold in Z/2^32, so wraparound is not an escape hatch)
    delta = int(rng.integers(1, 2 ** 31))
    bad = acc.copy()
    bad[i, j] = np.int32(((int(acc[i, j]) + delta + 2 ** 31) % 2 ** 32)
                         - 2 ** 31)
    if bad[i, j] == acc[i, j]:
        return                       # delta was a multiple of 2^32: no-op
    flags = int(engine.abft_flags(jnp.asarray(lhs), jnp.asarray(rhs),
                                  jnp.asarray(bad)))
    assert flags & engine.DET_ABFT_COL, f"column checksum missed ({seed})"
    assert flags & engine.DET_ABFT_ROW, f"row checksum missed ({seed})"


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.integers(min_value=0, max_value=2 ** 32 - 1))
    def test_abft_detects_any_single_corruption(seed):
        _check_abft_single_corruption(seed)
else:
    @pytest.mark.parametrize("seed", range(0, 200, 2))
    def test_abft_detects_any_single_corruption(seed):
        _check_abft_single_corruption(seed)


def test_detector_names_roundtrip():
    mask = engine.DET_ABFT_COL | engine.DET_RANGE
    names = engine.detector_names(mask)
    assert "abft_col" in "".join(names) or names  # non-empty, stable
    assert engine.detector_names(0) == ()


# ---------------------------------------------------------------------------
# guarded twin: bitwise on clean dispatches, deterministic under corruption
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", sorted(zoo.SERVING_MODELS))
def test_guarded_twin_bitwise_clean(model):
    plan = engine.compile_model(f"sdc-twin-{model}",
                                zoo.serving_defs(model))
    xb = _batch(2, seed=1, model=model)
    ref = np.asarray(engine.forward_jit(plan, xb))
    out, flags = engine.forward_jit_guarded(
        plan, xb, cargs=engine.null_corruption_args())
    assert (np.asarray(out) == ref).all(), \
        f"guarded twin diverged on clean dispatch ({model})"
    assert not np.asarray(flags).any(), \
        f"false positive on clean dispatch ({model}): {np.asarray(flags)}"


def test_corruption_deterministic_replay():
    plan = _plan("replay")
    xb = _batch(2)
    cargs = engine.corruption_args(seed=7, sigma_lsb=2.0)
    out1, fl1 = engine.forward_jit_guarded(plan, xb, cargs=cargs)
    out2, fl2 = engine.forward_jit_guarded(plan, xb, cargs=cargs)
    assert (np.asarray(out1) == np.asarray(out2)).all()
    assert (np.asarray(fl1) == np.asarray(fl2)).all()
    assert np.asarray(fl1).any(), "sigma=2 LSB never flagged"


@pytest.mark.parametrize("kw", [
    {"sigma_lsb": 3.0},               # ANALOG_NOISE
    {"gain": 1.05, "bias_lsb": 4.0},  # THERMAL_DETUNE
    {"flip_prob": 0.01},              # ADC_BITFLIP
])
def test_value_corruption_detected_and_visible(kw):
    plan = _plan("kinds")
    xb = _batch(2)
    ref = np.asarray(engine.forward_jit(plan, xb))
    out, flags = engine.forward_jit_guarded(
        plan, xb, cargs=engine.corruption_args(seed=3, **kw))
    assert np.asarray(flags).any(), f"{kw} never flagged"
    assert not (np.asarray(out) == ref).all(), f"{kw} was a silent no-op"


def test_weight_checksum_catches_stuck_mrr():
    plan = _plan("stuck")
    xb = _batch(2)
    params = engine.corrupted_layer_params(plan, seed=3, stuck_rings=2)
    out, flags = engine.forward_jit_guarded(
        plan, xb, cargs=engine.null_corruption_args(), params=params)
    masks = np.asarray(flags)
    assert (masks & engine.DET_WEIGHT).any(), (
        f"stuck-MRR weights escaped the imprint checksum: {masks}")


def test_integrity_policy_validation():
    with pytest.raises(ValueError):
        engine.IntegrityPolicy(check_every=-1)
    assert engine.DISABLED_POLICY.check_every == 0
    assert engine.DEFAULT_POLICY.check_every == 1


# ---------------------------------------------------------------------------
# fault taxonomy + injector semantics
# ---------------------------------------------------------------------------

def test_fault_kind_taxonomy_partitions():
    assert set(AVAILABILITY_KINDS) & set(INTEGRITY_KINDS) == set()
    assert (set(AVAILABILITY_KINDS) | set(INTEGRITY_KINDS)
            == set(serve.FaultKind))
    assert set(FAILING_KINDS) <= set(AVAILABILITY_KINDS)


def test_random_schedule_default_stays_availability_only():
    """PR-6 seeded schedules replay bit-identically: the default kinds
    never include the new integrity faults."""
    ev = serve.random_schedule(3, ["a", "b"], n_events=8)
    assert all(e.kind in AVAILABILITY_KINDS for e in ev)
    assert serve.random_schedule(3, ["a", "b"], n_events=8) == ev


def test_random_schedule_integrity_severities_kind_appropriate():
    events = serve.random_schedule(5, ["a"], n_events=24,
                                   kinds=INTEGRITY_KINDS)
    seen = set()
    for e in events:
        seen.add(e.kind)
        if e.kind is serve.FaultKind.ANALOG_NOISE:
            assert e.severity >= 0.5          # >= the Eq. 9 design floor
        elif e.kind is serve.FaultKind.ADC_BITFLIP:
            assert 1e-4 <= e.severity <= 1e-2
        elif e.kind is serve.FaultKind.STUCK_MRR:
            assert e.severity >= 1.0
        elif e.kind is serve.FaultKind.THERMAL_DETUNE:
            assert 0.0 < e.severity <= 0.25
    assert len(seen) >= 3                     # the draw actually mixes


def test_corruption_spec_active_and_fold():
    assert not CorruptionSpec().active
    assert CorruptionSpec(sigma_lsb=0.1).active
    inj = serve.FaultInjector([
        serve.FaultEvent("a", serve.FaultKind.ANALOG_NOISE, start=0,
                         duration=2, severity=1.5),
        serve.FaultEvent("a", serve.FaultKind.THERMAL_DETUNE, start=0,
                         duration=2, severity=0.1)])
    eff = inj.on_dispatch("a")
    assert eff.corruption is not None
    assert eff.corruption.sigma_lsb == pytest.approx(1.5)
    assert eff.corruption.gain == pytest.approx(1.1)
    assert inj.corrupted_dispatches == 1


def test_probe_dispatches_excluded_and_reject_corrupters():
    inj = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         duration=3, severity=2.0)])
    eff = inj.on_dispatch("acc0", probe=True)
    assert eff.corruption is not None          # the probe SEES corruption
    assert inj.corrupted_dispatches == 0       # but doesn't count it
    assert inj.on_dispatch("acc0").corruption is not None
    assert inj.corrupted_dispatches == 1
    # dispatcher probes fail while the instance would corrupt values
    fleet = serve.ShardedDispatcher(serve.default_fleet(1),
                                    fault_injector=inj)
    assert not fleet._probe(fleet.instances[0])
    fleet.close()


def test_injector_corruption_seed_replay():
    sched = [serve.FaultEvent("a", serve.FaultKind.ANALOG_NOISE, start=0,
                              duration=4, severity=2.0)]
    a = serve.FaultInjector(sched, seed=9)
    b = serve.FaultInjector(sched, seed=9)
    for _ in range(3):
        ea, eb = a.on_dispatch("a"), b.on_dispatch("a")
        assert ea.corruption == eb.corruption
    # a different injector seed draws different corruption seeds
    c = serve.FaultInjector(sched, seed=10)
    d = serve.FaultInjector(sched, seed=9)
    assert c.on_dispatch("a").corruption.seed \
        != d.on_dispatch("a").corruption.seed


# ---------------------------------------------------------------------------
# dispatcher: detect, contain, recover bitwise
# ---------------------------------------------------------------------------

def test_dispatch_detects_and_recovers_bitwise():
    plan = _plan("recover")
    xb = _batch(4, seed=2)
    ref = np.asarray(engine.forward_jit(plan, xb))
    schedule = [
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         duration=1, severity=3.0),
        serve.FaultEvent("acc1", serve.FaultKind.ADC_BITFLIP, start=1,
                         duration=1, severity=0.01),
    ]
    injector = serve.FaultInjector(schedule, seed=4)
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.01, max_retries=8,
        integrity=serve.IntegrityConfig(check_every=1))
    fleet.metrics = MetricsRegistry()
    for _ in range(3):
        out, _ = fleet.run(plan, xb)
    fleet.close()
    assert (np.asarray(out) == ref).all(), \
        "recovered outputs diverged from the fault-free run"
    assert fleet.counters["sdc_detections"] >= 1
    assert fleet.counters["sdc_detections"] == injector.corrupted_dispatches
    assert fleet.counters["corrupted_shards"] >= 1
    assert fleet.counters["quarantines"] >= 1
    hist = fleet.metrics.histogram("serve_sdc_detection_latency_seconds",
                                   model=plan.name)
    assert hist.count == fleet.counters["sdc_detections"]
    assert hist.percentile(0.5) > 0.0


def test_dispatch_silent_without_integrity_config():
    """The baseline the defense exists for: corruption flows through."""
    plan = _plan("silent")
    xb = _batch(4, seed=2)
    ref = np.asarray(engine.forward_jit(plan, xb))
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         severity=3.0)])
    fleet = serve.ShardedDispatcher(serve.default_fleet(3),
                                    fault_injector=injector)
    out, _ = fleet.run(plan, xb)
    fleet.close()
    assert not (np.asarray(out) == ref).all()
    assert fleet.counters["sdc_detections"] == 0


def test_canary_quarantines_persistent_corrupter():
    plan = _plan("canary")
    xb = _batch(4, seed=5)
    ref = np.asarray(engine.forward_jit(plan, xb))
    injector = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.STUCK_MRR, start=0,
                         severity=2.0)])
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.01,
        integrity=serve.IntegrityConfig(check_every=0, canary_every=1))
    for _ in range(3):
        out, _ = fleet.run(plan, xb)
    fleet.close()
    assert (np.asarray(out) == ref).all(), \
        "stuck-MRR outputs reached the caller"
    assert fleet.counters["canary_failures"] >= 1
    assert fleet.counters["quarantines"] >= 1


def test_integrity_config_validation():
    with pytest.raises(ValueError):
        serve.IntegrityConfig(check_every=-1)
    with pytest.raises(ValueError):
        serve.IntegrityConfig(canary_every=-2)
    pol = serve.IntegrityConfig(check_every=2, abft=False).policy()
    assert pol.check_every == 2 and not pol.abft


def test_output_corrupted_is_typed_serving_fault():
    exc = serve.OutputCorrupted("acc0", layer=3,
                                detectors=("abft_col",))
    assert isinstance(exc, serve.ServingFault)
    assert exc.instance == "acc0" and exc.layer == 3
    budget = serve.CorruptionBudgetExceeded(MODEL, rate=0.4, budget=0.25)
    assert isinstance(budget, serve.ServingFault)


# ---------------------------------------------------------------------------
# planner: the Eq. 9 SNR budget filters operating points
# ---------------------------------------------------------------------------

def test_snr_filter_excludes_infeasible_points():
    acc = build_accelerator("RMAM", 1.0)
    specs = zoo.paper_scale_specs("xception_mini")
    rep = plan_mod.search_points(specs, acc)
    assert "x7" in rep.snr_excluded
    labels = tuple(c.option.label for c in rep.choices)
    assert "x7" not in labels
    unfiltered = plan_mod.search_points(specs, acc, snr_filter=False)
    assert unfiltered.snr_excluded == ()


@pytest.mark.parametrize("model", ["efficientnet_mini", MODEL])
def test_snr_filter_preserves_feasible_plans(model):
    """Where every operating point meets the SNR budget (2-bit weights on
    RMAM@1G), the filter is a no-op and plans are identical."""
    acc = build_accelerator("RMAM", 1.0)
    specs = zoo.paper_scale_specs(model)
    with_f = plan_mod.search_points(specs, acc, bits=2)
    without = plan_mod.search_points(specs, acc, bits=2, snr_filter=False)
    assert with_f.snr_excluded == ()
    assert (tuple(c.option for c in with_f.choices)
            == tuple(c.option for c in without.choices))
    assert with_f.switches == without.switches
    assert with_f.total_time_s == without.total_time_s


def test_snr_filter_never_schedules_excluded_points():
    """The surviving plan is drawn only from SNR-feasible points, and the
    schedule-time penalty of losing a point stays marginal."""
    acc = build_accelerator("RMAM", 1.0)
    specs = zoo.paper_scale_specs(MODEL)
    with_f = plan_mod.search_points(specs, acc)
    without = plan_mod.search_points(specs, acc, snr_filter=False)
    assert with_f.snr_excluded == ("x7",)
    assert all(c.option.label not in with_f.snr_excluded
               for c in with_f.choices)
    assert with_f.total_time_s == pytest.approx(without.total_time_s,
                                                rel=0.05)


def test_snr_filter_raises_when_nothing_survives():
    acc = build_accelerator("RMAM", 5.0)
    specs = zoo.paper_scale_specs(MODEL)
    with pytest.raises(ph.InfeasiblePrecisionError):
        plan_mod.search_points(specs, acc, bits=8)


def test_snr_feasible_options_drops_high_y():
    acc = build_accelerator("RMAM", 1.0)
    rep = plan_mod.search_points(zoo.paper_scale_specs(MODEL), acc,
                                 snr_filter=False)
    kept, dropped = plan_mod.snr_feasible_options(acc, rep.options,
                                                  bits=4)
    assert kept, "the SNR filter dropped every operating point"
    assert set(kept).isdisjoint(dropped)
    assert {o.label for o in dropped} == {"x7"}


def test_noisy_vdp_infeasible_precision_raises():
    rng = np.random.default_rng(0)
    divs = jnp.asarray(rng.integers(-7, 8, (8, 43)), jnp.int8)
    dkvs = jnp.asarray(rng.integers(-7, 8, (4, 43)), jnp.int8)
    tpc = TPCConfig("MAM", 43, 43, True)
    with pytest.raises(vdp.InfeasiblePrecisionError):
        vdp.noisy_vdp_gemm(jax.random.PRNGKey(0), divs, dkvs, tpc,
                           br_hz=5e9, bits=8)


# ---------------------------------------------------------------------------
# server: corrupted-frame-rate SLO
# ---------------------------------------------------------------------------

def test_serve_slo_corruption_budget_validation():
    with pytest.raises(ValueError):
        serve.ServeSLO(deadline_s=1.0, max_corrupted_frame_rate=0.0)
    with pytest.raises(ValueError):
        serve.ServeSLO(deadline_s=1.0, max_corrupted_frame_rate=1.5)
    with pytest.raises(ValueError):
        serve.ServeSLO(deadline_s=1.0, corruption_halflife_s=0.0)


def test_server_sheds_typed_on_corruption_and_recovers():
    reg = serve.paper_cnn_registry()
    injector = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.ANALOG_NOISE, start=0,
                         duration=2, severity=3.0)])
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        probe_cooldown_s=0.01, max_retries=8,
        integrity=serve.IntegrityConfig(check_every=1))
    slo = serve.ServeSLO(deadline_s=30.0, max_corrupted_frame_rate=0.25,
                         corruption_halflife_s=0.1)
    srv = serve.CNNServer(reg, max_batch=4, dispatcher=fleet, slo=slo)
    xs = np.asarray(_batch(10, seed=6))
    shed = 0
    for x in xs[:6]:
        try:
            srv.submit(MODEL, x)
        except serve.CorruptionBudgetExceeded as e:
            assert e.rate > e.budget
            shed += 1
        srv.step(force=True)
    assert fleet.counters["sdc_detections"] >= 1
    assert shed >= 1, "corruption never tripped the frame-rate SLO"
    assert srv.admission["integrity_shed"] == shed
    time.sleep(0.5)                       # several half-lives
    admitted_after = 0
    for x in xs[6:]:
        try:
            srv.submit(MODEL, x)
            admitted_after += 1
        except serve.CorruptionBudgetExceeded:
            pass
        srv.step(force=True)
    fleet.close()
    assert admitted_after >= 1, "admission never recovered after decay"
    sdc = srv.telemetry.summary()["fleet"]["sdc"]
    assert sdc["budget"] == pytest.approx(0.25)
    text = srv.telemetry.metrics.prometheus_text()
    assert "serve_sdc_detections_total" in text
