"""Mapping tests: Cases 1/2/3, slice plans, utilization — incl. property tests."""
import math

import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cnn.layers import dc, fc, pc, sc
from repro.core.mapping import (TPCConfig, map_layer, select_case, slice_plan,
                                vdpe_utilization_for_s)

RMAM = TPCConfig("MAM", 43, 43, True)
RAMM = TPCConfig("AMM", 31, 31, True)
MAM = TPCConfig("MAM", 44, 44, False)
AMM = TPCConfig("AMM", 31, 31, False)
RAMM_5G = TPCConfig("AMM", 16, 16, True)     # y = 0: no reconfiguration


def test_mode_selection_cases():
    assert select_case(RMAM, 100) == 1      # S > N
    assert select_case(RMAM, 43) == 1       # S == N
    assert select_case(RMAM, 20) == 2       # x < S < N
    assert select_case(RMAM, 9) == 3        # S <= x
    assert select_case(MAM, 20) == 0        # fixed-N fallback
    assert select_case(RAMM_5G, 9) == 0     # y == 0 behaves fixed


@given(s=st.integers(1, 5000))
def test_slice_plan_covers_s(s):
    for tpc in (RMAM, RAMM, MAM, AMM, RAMM_5G):
        plan = slice_plan(tpc, s)
        assert sum(w * c for _, w, c in plan) == s
        for mode, w, c in plan:
            assert c >= 1
            assert 1 <= w <= tpc.n
            if mode == 2:
                assert w <= tpc.x and tpc.y > 0
            if tpc.y == 0:
                assert mode == 1


@given(s=st.integers(1, 5000))
def test_utilization_bounds(s):
    for tpc in (RMAM, RAMM, MAM, AMM):
        u = vdpe_utilization_for_s(tpc, s)
        assert 0.0 < u <= 1.0


@given(s=st.integers(1, 42))
def test_reconfigurable_beats_fixed_utilization_small_s(s):
    """Mode 2 never reduces per-VDPE utilization for sub-N DKVs."""
    u_r = vdpe_utilization_for_s(RMAM, s)
    u_f = vdpe_utilization_for_s(TPCConfig("MAM", 43, 43, False), s)
    assert u_r >= u_f - 1e-12


def test_paper_utilization_endpoints():
    """Fig. 6 anchor points: baselines strand MRRs at small S."""
    assert vdpe_utilization_for_s(MAM, 9) == pytest.approx(9 / 44)
    assert vdpe_utilization_for_s(AMM, 9) == pytest.approx(9 / 31)
    # RMAM Mode 2 on S=9: y=4 lanes x 9 of 43 rings
    assert vdpe_utilization_for_s(RMAM, 9) == pytest.approx(36 / 43)
    assert vdpe_utilization_for_s(RAMM, 9) == pytest.approx(27 / 31)


@settings(max_examples=60)
@given(s=st.integers(1, 4000), f=st.integers(1, 512), p=st.integers(1, 1024))
def test_mapping_work_conservation(s, f, p):
    """used MRR-cycles == total pointwise products; active >= used."""
    side = max(1, int(math.isqrt(p)))
    layer = pc("l", s, f, side, side)
    for tpc in (RMAM, RAMM, MAM, AMM):
        m = map_layer(tpc, layer)
        assert m.used_mrr_cycles == layer.macs
        assert m.active_mrr_cycles >= m.used_mrr_cycles
        assert sum(g.width * g.n_slices for g in m.groups) == s
        for g in m.groups:
            assert g.passes >= 1
            assert g.stream_cycles >= 1
            assert g.supply_points >= 1


def test_dc_on_mam_single_vdpe():
    """Depthwise on MAM: shared DIV leaves one distinct-kernel VDPE (Mode 1)."""
    layer = dc("d", 5, 64, 14, 14)          # S=25, 64 channels
    m_fixed = map_layer(MAM, layer)
    (g,) = m_fixed.groups
    assert g.passes == 64                    # one pass per channel
    # Mode 2 on RMAM recovers y-way channel parallelism
    m_rec = map_layer(RMAM, layer)
    total = sum(g.passes for g in m_rec.groups)
    assert total < 64                        # 25 -> 2x9+7: ceil(64/4)*3 = 48


def test_case1_remainder_reaggregation():
    """S > N remainder slices run in Mode 2 on reconfigurable VDPEs."""
    layer = pc("p", 96, 128, 7, 7)           # S=96 = 2*43 + 10 on RMAM
    m = map_layer(RMAM, layer)
    modes = [g.mode for g in m.groups]
    assert 1 in modes and 2 in modes
    m_fixed = map_layer(MAM, layer)
    assert all(g.mode == 1 for g in m_fixed.groups)


def test_position_parallel_stream():
    """AMM family streams ceil(P/M) position groups per pass."""
    layer = sc("s", 3, 64, 128, 28, 28)      # P = 784
    m = map_layer(AMM, layer)
    assert all(g.stream_cycles == math.ceil(784 / 31) for g in m.groups)
    # kernel-parallel MAM streams every position
    m2 = map_layer(MAM, layer)
    assert all(g.stream_cycles == 784 for g in m2.groups)


def test_fc_layer_maps():
    layer = fc("fc", 2560, 1000)
    for tpc in (RMAM, RAMM, MAM, AMM):
        m = map_layer(tpc, layer)
        assert m.used_mrr_cycles == layer.macs
