"""Weight-stationary engine tests: zero-skipping kernel vs the block-
diagonal oracle, fused epilogues, plan-vs-eager equivalence over the paper
CNNs' layer shapes, and the memoization caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.cnn.layers import ConvKind
from repro.cnn.models import MODEL_ZOO, PAPER_CNNS
from repro.core import vdp
from repro.core.mapping import TPCConfig, map_layer
from repro.cnn.layers import pc as pc_spec
from repro.kernels import ops, ref
from repro.kernels import vdpe_gemm as kern

jax.config.update("jax_platform_name", "cpu")

Y = ops.N_TPU // ops.X_TPU


def _rand_int8(rng, shape, lo=-7, hi=8):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)



def _assert_epilogue_equal(got, want, exact: bool):
    """Fused-epilogue comparison.

    Without a bias the fused kernel's act(acc*scale) is bit-identical to
    the eager oracle.  With a bias, XLA contracts the kernel's
    ``acc*scale + bias`` into an FMA (one rounding) while the eager oracle
    rounds the multiply first — a <=1-ulp difference, so compare to float32
    ulp tolerance instead.
    """
    if exact:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-5)


# ---------------------------------------------------------------------------
# Zero-skipping Mode-2 kernel vs the block-diagonal oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [9, 25, 32])
def test_zs_kernel_matches_blockdiag_oracle(s):
    """Bit-identical to the (y*x)-deep block-diagonal kernel it replaced."""
    rng = np.random.default_rng(s)
    p, f = 128, 256
    divs = _rand_int8(rng, (p, s))
    dkvs = _rand_int8(rng, (f, s))
    lhs = jnp.pad(divs, ((0, 0), (0, ops.X_TPU - s)))
    rhs_bd = ops.pack_mode2_weights(dkvs, ops.X_TPU, Y)
    rhs_zs = ops.pack_mode2_segments(dkvs, ops.X_TPU)
    got = kern.vdpe_pack_gemm_zs(lhs, rhs_zs, interpret=True)
    want_pallas = ref.vdpe_pack_gemm_blockdiag(lhs, rhs_bd, Y, interpret=True)
    want_jnp = ref.vdpe_pack_gemm_ref(lhs, rhs_bd, Y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_pallas))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_jnp))


def test_zs_kernel_issues_x_deep_contraction():
    """Pass-count/block-shape check: the zs kernel contracts x deep per
    output tile — never y*x — and structurally cannot take the y*x operand."""
    lhs_shape, rhs_shape, _ = kern.zs_block_shapes(ops.X_TPU)
    assert lhs_shape[1] == ops.X_TPU
    assert rhs_shape[0] == ops.X_TPU
    assert rhs_shape[0] != Y * ops.X_TPU
    rng = np.random.default_rng(0)
    lhs = _rand_int8(rng, (128, ops.X_TPU))
    rhs_bd = _rand_int8(rng, (Y * ops.X_TPU, 128))   # block-diagonal shape
    with pytest.raises(AssertionError):
        kern.vdpe_pack_gemm_zs(lhs, rhs_bd, interpret=True)


def test_segment_sum_collapses_block_diagonal():
    """pack_mode2_segments == the y row-segments of the block-diagonal pack
    summed (segments are column-disjoint, so nothing is lost)."""
    rng = np.random.default_rng(1)
    dkvs = _rand_int8(rng, (24, 25))
    bd = ops.pack_mode2_weights(dkvs, ops.X_TPU, Y)
    seg = ops.pack_mode2_segments(dkvs, ops.X_TPU)
    collapsed = np.asarray(bd, np.int32).reshape(Y, ops.X_TPU, 24).sum(0)
    np.testing.assert_array_equal(collapsed, np.asarray(seg, np.int32))
    np.testing.assert_array_equal(
        np.asarray(seg),
        np.asarray(ref.pack_mode2_segments_ref(dkvs, ops.X_TPU, Y)))


# ---------------------------------------------------------------------------
# Fused epilogues
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["none", "relu", "relu6"])
@pytest.mark.parametrize("with_bias", [False, True])
def test_mode1_epilogue_fusion(act, with_bias):
    rng = np.random.default_rng(7)
    p, s, f = 100, 300, 77
    divs = _rand_int8(rng, (p, s))
    dkvs = _rand_int8(rng, (f, s))
    scale = jnp.float32(0.031)
    bias = (jnp.asarray(rng.normal(size=(f,)), jnp.float32)
            if with_bias else None)
    got = ops.mode1_gemm(divs, dkvs, interpret=True,
                         scale=scale, bias=bias, act=act)
    acc = ops.mode1_gemm(divs, dkvs, interpret=True)
    want = ref.epilogue_ref(acc, scale,
                            None if bias is None else bias[None, :], act)
    assert got.dtype == jnp.float32
    _assert_epilogue_equal(got, want, exact=bias is None)


@pytest.mark.parametrize("act", ["none", "relu", "relu6"])
@pytest.mark.parametrize("with_bias", [False, True])
def test_mode2_epilogue_fusion(act, with_bias):
    rng = np.random.default_rng(8)
    p, s, f = 40, 25, 33
    divs = _rand_int8(rng, (p, s))
    dkvs = _rand_int8(rng, (f, s))
    scale = jnp.float32(0.008)
    bias = (jnp.asarray(rng.normal(size=(f,)), jnp.float32)
            if with_bias else None)
    got = ops.mode2_gemm(divs, dkvs, ops.X_TPU, Y, interpret=True,
                         scale=scale, bias=bias, act=act)
    acc = ops.mode2_gemm(divs, dkvs, ops.X_TPU, Y, interpret=True)
    want = ref.epilogue_ref(acc, scale,
                            None if bias is None else bias[None, :], act)
    _assert_epilogue_equal(got, want, exact=bias is None)


@pytest.mark.parametrize("act", ["relu", "relu6"])
def test_bf16_epilogue_fusion(act):
    rng = np.random.default_rng(9)
    b, s, o = 64, 300, 77
    lhs = jnp.asarray(rng.normal(size=(b, s)), jnp.float32)
    rhs = jnp.asarray(rng.normal(size=(s, o)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(o,)), jnp.float32)
    got = ops.gemm_bf16(lhs, rhs, interpret=True, bias=bias, act=act)
    acc = ops.gemm_bf16(lhs, rhs, interpret=True)
    want = ref.epilogue_ref(acc, 1.0, bias[None, :], act)
    _assert_epilogue_equal(got, want, exact=False)


# ---------------------------------------------------------------------------
# Plan-vs-eager equivalence across the paper CNNs' layer shapes
# ---------------------------------------------------------------------------

def _paper_gemm_sizes():
    """Every distinct GEMM contraction size in the 4 paper CNNs."""
    sizes = set()
    for name in PAPER_CNNS:
        for l in MODEL_ZOO[name]():
            if l.kind is not ConvKind.DC:
                sizes.add(l.dkv_size)
    return sorted(sizes)


def _paper_dc_kernels():
    ks = set()
    for name in PAPER_CNNS:
        for l in MODEL_ZOO[name]():
            if l.kind is ConvKind.DC:
                ks.add(l.k)
    return sorted(ks)


@pytest.mark.parametrize("s", _paper_gemm_sizes())
def test_plan_vs_eager_gemm_shapes(s):
    """Engine forward == the eager quantize->GEMM->dequant->act oracle for
    every distinct contraction size the four paper CNNs produce."""
    rng = np.random.default_rng(s)
    f = 3
    w = jnp.asarray(rng.normal(size=(f, 1, 1, s)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 4, s)), jnp.float32)
    plan = engine.compile_model(
        f"shape_s{s}", [engine.LayerDef("l", ConvKind.PC, w,
                                        bias=bias, act="relu")])
    (lp,) = plan.layers
    assert lp.mode == (engine.MODE_PACKED if s <= ops.X_TPU
                      else engine.MODE_DENSE)
    got = engine.forward(plan, x, interpret=True)

    divs = vdp.im2col(x, 1, 1, "SAME")
    divs_q, sa = vdp.quantize_symmetric(divs)
    dkvs_q, sb = vdp.quantize_symmetric(w.reshape(f, -1))
    acc = vdp.direct_quantized_gemm(divs_q, dkvs_q)
    want = ref.epilogue_ref(acc, sa * sb, bias[None, :], "relu")
    _assert_epilogue_equal(jnp.asarray(np.asarray(got).reshape(-1, f)),
                           want, exact=False)


@pytest.mark.parametrize("k", _paper_dc_kernels())
def test_plan_vs_eager_depthwise(k):
    """Engine depthwise path == core/vdp.depthwise_conv2d_vdp + relu."""
    rng = np.random.default_rng(k)
    d = 6
    w = jnp.asarray(rng.normal(size=(d, k, k)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(10, 10, d)), jnp.float32)
    plan = engine.compile_model(
        f"dw_k{k}", [engine.LayerDef("dw", ConvKind.DC, w, act="relu")])
    got = engine.forward(plan, x, interpret=True)
    out, ref_out = vdp.depthwise_conv2d_vdp(x, w, TPCConfig("MAM", 43, 43, True))
    assert jnp.array_equal(out, ref_out)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jax.nn.relu(out)))


def test_engine_micro_cnn_end_to_end():
    """SC -> DC -> PC -> FC chain: engine == layer-by-layer eager path,
    spanning Mode-1, Mode-2 and depthwise routing in one plan."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 8, 3)), jnp.float32)
    stem = jnp.asarray(rng.normal(size=(8, 3, 3, 3)), jnp.float32)   # S=27
    dw = jnp.asarray(rng.normal(size=(8, 3, 3)), jnp.float32)
    pw = jnp.asarray(rng.normal(size=(40, 1, 1, 8)), jnp.float32)    # S=8
    fcw = jnp.asarray(rng.normal(size=(10, 8 * 8 * 40)), jnp.float32)  # S big
    plan = engine.compile_model("micro_e2e", [
        engine.LayerDef("stem", ConvKind.SC, stem, act="relu"),
        engine.LayerDef("dw", ConvKind.DC, dw, act="relu6"),
        engine.LayerDef("pw", ConvKind.PC, pw, act="relu"),
        engine.LayerDef("fc", ConvKind.FC, fcw),
    ])
    modes = [lp.mode for lp in plan.layers]
    assert modes == [engine.MODE_PACKED, engine.MODE_DEPTHWISE,
                     engine.MODE_PACKED, engine.MODE_DENSE]
    got = engine.forward(plan, x, interpret=True)

    rmam = TPCConfig("MAM", 43, 43, True)
    h, _ = vdp.conv2d_vdp(x, stem, rmam)
    h = jax.nn.relu(h)
    h2, _ = vdp.depthwise_conv2d_vdp(h, dw, rmam)
    h = jnp.clip(h2, 0.0, 6.0)
    h3, _ = vdp.conv2d_vdp(h, pw, rmam)
    h = jax.nn.relu(h3)
    divs = h.reshape(1, -1)
    divs_q, sa = vdp.quantize_symmetric(divs)
    fc_q, sb = vdp.quantize_symmetric(fcw)
    want = ref.epilogue_ref(vdp.direct_quantized_gemm(divs_q, fc_q),
                            sa * sb, None, "none")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_forward_never_repacks_weights(monkeypatch):
    """Pack-once: forward must not touch the weight-side packers."""
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=(8, 1, 1, 9)), jnp.float32)
    plan = engine.compile_model(
        "no_repack", [engine.LayerDef("pc", ConvKind.PC, w, act="relu")])

    def _boom(*a, **k):
        raise AssertionError("weights repacked during forward")

    monkeypatch.setattr(ops, "pack_mode2_weights", _boom)
    monkeypatch.setattr(ops, "pack_mode2_segments", _boom)
    x = jnp.asarray(rng.normal(size=(4, 4, 9)), jnp.float32)
    engine.forward(plan, x, interpret=True)  # must not raise


# ---------------------------------------------------------------------------
# Batched executor path (the serving runtime's folded position streams)
# ---------------------------------------------------------------------------

def _micro_plan():
    """SC -> DC(bias) -> PC(bias) -> FC: all four kinds, both GEMM modes."""
    rng = np.random.default_rng(11)
    stem = jnp.asarray(rng.normal(size=(8, 3, 3, 3)), jnp.float32)   # Mode 2
    dw = jnp.asarray(rng.normal(size=(8, 3, 3)), jnp.float32)
    pw = jnp.asarray(rng.normal(size=(40, 1, 1, 8)), jnp.float32)    # Mode 2
    fcw = jnp.asarray(rng.normal(size=(10, 8 * 8 * 40)), jnp.float32)  # M. 1
    return engine.compile_model("batched_micro", [
        engine.LayerDef("stem", ConvKind.SC, stem, act="relu"),
        engine.LayerDef("dw", ConvKind.DC, dw,
                        bias=jnp.asarray(rng.normal(size=(8,)), jnp.float32),
                        act="relu6"),
        engine.LayerDef("pw", ConvKind.PC, pw,
                        bias=jnp.asarray(rng.normal(size=(40,)), jnp.float32),
                        act="relu"),
        engine.LayerDef("fc", ConvKind.FC, fcw),
    ])


@pytest.mark.parametrize("b", [1, 3, 5])
def test_batched_forward_bit_identical_to_per_image_loop(b):
    """NHWC batches fold into one position stream, bit-identical to looping
    the per-image forward — across SC/DC/PC/FC and both Pallas modes,
    including ragged (non-power-of-two, non-block-multiple) batch sizes."""
    plan = _micro_plan()
    rng = np.random.default_rng(b)
    xb = jnp.asarray(rng.normal(size=(b, 8, 8, 3)), jnp.float32)
    got = engine.forward(plan, xb, interpret=True)
    want = jnp.concatenate([engine.forward(plan, xb[i], interpret=True)
                            for i in range(b)], axis=0)
    assert got.shape == (b, 10)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("s", [25, 144])        # Mode 2 / Mode 1
def test_batched_forward_layer_both_modes(s):
    """Single conv layer, batched vs per-image, spatial output preserved."""
    rng = np.random.default_rng(s)
    f = 7
    w = jnp.asarray(rng.normal(size=(f, 1, 1, s)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(f,)), jnp.float32)
    plan = engine.compile_model(
        f"batched_s{s}",
        [engine.LayerDef("pc", ConvKind.PC, w, bias=bias, act="relu6")])
    (lp,) = plan.layers
    assert lp.mode == (engine.MODE_PACKED if s <= ops.X_TPU
                       else engine.MODE_DENSE)
    xb = jnp.asarray(rng.normal(size=(4, 5, 5, s)), jnp.float32)
    got = engine.forward_layer(plan, lp, xb, interpret=True)
    assert got.shape == (4, 5, 5, f)
    for i in range(4):
        want = engine.forward_layer(plan, lp, xb[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


def test_batched_fc_accepts_row_batches():
    """FC treats 2-D input as batched rows, each with its own DAC scale."""
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    plan = engine.compile_model(
        "batched_fc", [engine.LayerDef("fc", ConvKind.FC, w)])
    xb = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    got = engine.forward(plan, xb, interpret=True)
    assert got.shape == (3, 5)
    for i in range(3):
        want = engine.forward(plan, xb[i:i + 1], interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i:i + 1]),
                                      np.asarray(want))


def test_batched_forward_rejects_wrong_width():
    rng = np.random.default_rng(8)
    w = jnp.asarray(rng.normal(size=(4, 1, 1, 9)), jnp.float32)
    plan = engine.compile_model(
        "bad_width", [engine.LayerDef("pc", ConvKind.PC, w)])
    x = jnp.zeros((2, 4, 4, 7), jnp.float32)    # D=7, layer expects 9
    with pytest.raises(ValueError, match="contraction"):
        engine.forward(plan, x, interpret=True)


# ---------------------------------------------------------------------------
# Memoization caches
# ---------------------------------------------------------------------------

def test_map_layer_cache_hits():
    """Same shape under different names, and the same layer at another bit
    rate's identical operating point, share one cache entry."""
    map_layer.cache_clear()
    tpc = TPCConfig("MAM", 43, 43, True)
    a = pc_spec("conv_a", 64, 128, 14, 14)
    b = pc_spec("conv_b", 64, 128, 14, 14)     # same shape, different name
    m1 = map_layer(tpc, a)
    info = map_layer.cache_info()
    assert (info.hits, info.misses) == (0, 1)
    m2 = map_layer(tpc, b)
    info = map_layer.cache_info()
    assert (info.hits, info.misses) == (1, 1)
    assert m1 is m2
    map_layer(tpc, a)
    assert map_layer.cache_info().hits == 2


def test_simulate_layer_cache_hits():
    from repro.core import simulator as sim
    from repro.core import tpc as tpc_mod
    sim.simulate_layer.cache_clear()
    acc = tpc_mod.build_accelerator("RMAM", 1.0)
    a = pc_spec("conv_a", 64, 128, 14, 14)
    b = pc_spec("conv_b", 64, 128, 14, 14)
    r1 = sim.simulate_layer(acc, a)
    r2 = sim.simulate_layer(acc, b)
    assert r1 is r2
    info = sim.simulate_layer.cache_info()
    assert (info.hits, info.misses) == (1, 1)


def test_plan_cache_keyed_on_model_and_point():
    engine.plan_cache_clear()
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(4, 1, 1, 9)), jnp.float32)
    defs = [engine.LayerDef("pc", ConvKind.PC, w)]
    p1 = engine.get_plan("m", defs)
    p2 = engine.get_plan("m", defs)
    assert p1 is p2
    other_point = engine.EnginePoint(bits=8)
    p3 = engine.get_plan("m", defs, other_point)
    assert p3 is not p1
    info = engine.plan_cache_info()
    assert info["hits"] == 1 and info["misses"] == 2 and info["size"] == 2
