"""Scalability analysis tests: paper Table II / Table IV / Figs. 4-5."""
import math

import pytest

from repro.core import photonics as ph
from repro.core import scalability as sc


def test_table2_exact():
    """The calibrated solver reproduces every Table II cell exactly."""
    got = sc.table2()
    assert got == sc.PAPER_TABLE_II


@pytest.mark.parametrize("arch", ["MAM", "AMM", "RMAM", "RAMM"])
def test_n_monotone_decreasing_in_precision(arch):
    p = ph.PhotonicParams()
    a = ph.ARCHS[arch]
    ns = [ph.max_vdpe_size(p, a, bits, 5e9) for bits in range(1, 9)]
    assert all(n1 >= n2 for n1, n2 in zip(ns, ns[1:]))


@pytest.mark.parametrize("arch", ["MAM", "AMM"])
def test_n_monotone_decreasing_in_bitrate(arch):
    p = ph.PhotonicParams()
    a = ph.ARCHS[arch]
    ns = [ph.max_vdpe_size(p, a, 4, br * 1e9) for br in (1, 3, 5, 10)]
    assert all(n1 >= n2 for n1, n2 in zip(ns, ns[1:]))


def test_8bit_unsupportable():
    """Paper: AMM and MAM TPCs cannot support a useful N at 8-bit."""
    p = ph.PhotonicParams()
    for arch in ("MAM", "AMM"):
        assert ph.max_vdpe_size(p, ph.ARCHS[arch], 8, 10e9) == 0
        assert ph.max_vdpe_size(p, ph.ARCHS[arch], 8, 1e9) <= 1


def test_amm_supports_less_than_mam():
    """AMM's longer waveguides + penalty always cost it VDPE size."""
    p = ph.PhotonicParams()
    for bits in (1, 2, 3, 4, 5):
        for br in (1e9, 3e9, 5e9, 10e9):
            assert (ph.max_vdpe_size(p, ph.AMM, bits, br)
                    <= ph.max_vdpe_size(p, ph.MAM, bits, br))


def test_pd_power_inverts_eq9():
    p = ph.PhotonicParams()
    for bits in (1, 4, 6):
        for br in (1e9, 10e9):
            pw = ph.pd_power_for_precision(p, bits, br)
            assert pw is not None
            assert ph.achievable_bits(p, pw, br) >= bits
            assert ph.achievable_bits(p, pw * 0.98, br) < bits


def test_comb_switch_pairs_formula():
    """y = N >= 2x ? floor(N/x) : 0 — Table IV's CS-pair counts."""
    assert ph.num_comb_switch_pairs(43) == 4
    assert ph.num_comb_switch_pairs(31) == 3
    assert ph.num_comb_switch_pairs(28) == 3
    assert ph.num_comb_switch_pairs(22) == 2
    assert ph.num_comb_switch_pairs(20) == 2
    assert ph.num_comb_switch_pairs(16) == 0   # 16 < 2x = 18
    assert ph.num_comb_switch_pairs(12) == 0


def test_table4_radii_and_fsr():
    """CS designs reproduce Table IV FSR/radius within 15%.

    The modulator FSR implied by Table IV's rows varies between 42.7 and
    49.9 nm (the paper designed each row separately in Lumerical); our fixed
    FSR_MOD = 44.8 nm reproduces every row within 15% and the radius-vs-FSR
    law R = lambda^2/(2 pi n_g FSR) with n_g = 4.36 within 3% when fed the
    paper's own FSR values (test below).
    """
    for rows in sc.PAPER_TABLE_IV.values():
        for br, (n, fsr_ref, radius_ref, y_ref) in rows.items():
            d = ph.design_comb_switch(n)
            assert d.y == y_ref
            if fsr_ref is None:
                continue
            assert d.cs_fsr_nm == pytest.approx(fsr_ref, rel=0.15)
            assert d.radius_um == pytest.approx(radius_ref, rel=0.15)


def test_table4_radius_law_exact():
    """R = lambda^2/(2 pi n_g FSR) reproduces Table IV radii from its FSRs."""
    for rows in sc.PAPER_TABLE_IV.values():
        for br, (n, fsr_ref, radius_ref, y_ref) in rows.items():
            if fsr_ref is None:
                continue
            assert ph.comb_switch_radius_um(fsr_ref) == pytest.approx(
                radius_ref, rel=0.03)


def test_channel_spacing_eq12():
    n = 43
    delta = ph.channel_spacing_nm(n)
    assert delta == pytest.approx(ph.FSR_MOD_NM / (n + 1))
    assert ph.comb_switch_fsr_nm(n) == pytest.approx(n * delta / 9)


def test_sweep_shapes():
    pts = sc.sweep("MAM")
    assert len(pts) == 8 * 4
    by = {(p.precision_bits, p.bit_rate_gbps): p for p in pts}
    assert by[(4, 1.0)].max_n == 44
    # received power at max N stays above PD sensitivity headroom floor
    for p in pts:
        if p.max_n > 0:
            assert p.received_power_dbm > -35.0
