"""Serving runtime tests: plan registry LRU, dynamic batcher policy,
server end-to-end bit-identity vs the per-image engine path, and
hardware-time telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, serve
from repro.cnn.layers import ConvKind
from repro.serve.batcher import DynamicBatcher
from repro.serve.registry import PlanRegistry

jax.config.update("jax_platform_name", "cpu")


def _tiny_factory(seed=0, f=6, s=5):
    def factory():
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(f, 1, 1, s)), jnp.float32)
        return [engine.LayerDef("pc", ConvKind.PC, w, act="relu")]
    return factory


def _tiny_registry(names, capacity=4):
    reg = PlanRegistry(capacity=capacity)
    for i, name in enumerate(names):
        reg.register(name, _tiny_factory(seed=i), input_shape=(4, 4, 5))
    return reg


# ---------------------------------------------------------------------------
# PlanRegistry
# ---------------------------------------------------------------------------

def test_registry_lru_eviction_and_deterministic_reload():
    reg = _tiny_registry(["a", "b", "c"], capacity=2)
    pa = reg.get("a").plan
    reg.get("b")
    assert reg.loaded == ["a", "b"]
    reg.get("a")                          # refresh a -> b is now LRU
    reg.get("c")                          # evicts b
    assert reg.loaded == ["a", "c"]
    st = reg.stats()
    assert st["evictions"] == 1 and st["resident"] == 2
    assert (st["hits"], st["misses"]) == (1, 3)
    # reload of an evicted model re-imprints bit-identical DKVs
    reg.get("b")                          # evicts a
    assert reg.loaded == ["c", "b"]
    pa2 = reg.get("a").plan               # evicts c; recompiled from factory
    np.testing.assert_array_equal(np.asarray(pa.layers[0].rhs),
                                  np.asarray(pa2.layers[0].rhs))
    assert reg.stats()["evictions"] == 3


def test_registry_guards_nondeterministic_factory():
    reg = PlanRegistry(capacity=1)
    shapes = iter([(6, 1, 1, 5), (7, 1, 1, 5)])    # structure drifts

    def factory():
        w = jnp.zeros(next(shapes), jnp.float32)
        return [engine.LayerDef("pc", ConvKind.PC, w)]

    reg.register("drifty", factory, input_shape=(4, 4, 5))
    reg.register("other", _tiny_factory(), input_shape=(4, 4, 5))
    reg.get("drifty")
    reg.get("other")                      # evicts drifty
    with pytest.raises(ValueError, match="structurally different"):
        reg.get("drifty")


def test_registry_unknown_and_duplicate_names():
    reg = _tiny_registry(["a"])
    with pytest.raises(KeyError, match="not registered"):
        reg.get("nope")
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", _tiny_factory(), input_shape=(4, 4, 5))


def test_get_plan_rejects_reused_key():
    """Engine-level twin of the registry guard (satellite: ValueError, not
    a bare assert strippable under python -O)."""
    engine.plan_cache_clear()
    w1 = jnp.zeros((4, 1, 1, 9), jnp.float32)
    w2 = jnp.zeros((5, 1, 1, 9), jnp.float32)
    engine.get_plan("reused", [engine.LayerDef("pc", ConvKind.PC, w1)])
    with pytest.raises(ValueError, match="structurally different"):
        engine.get_plan("reused", [engine.LayerDef("pc", ConvKind.PC, w2)])
    engine.plan_cache_clear()


# ---------------------------------------------------------------------------
# DynamicBatcher
# ---------------------------------------------------------------------------

def test_batcher_max_batch_and_max_wait():
    b = DynamicBatcher(max_batch=4, max_wait_s=1.0)
    for _ in range(3):
        b.submit("m", None, now=0.0)
    assert b.pop_batch(now=0.5) is None           # not full, not stale
    b.submit("m", None, now=0.6)
    fb = b.pop_batch(now=0.7)                     # full -> dispatch
    assert fb is not None and fb.size == 4
    b.submit("m", None, now=1.0)
    assert b.pop_batch(now=1.5) is None
    fb = b.pop_batch(now=2.0)                     # oldest waited >= 1s
    assert fb is not None and fb.size == 1
    assert fb.queue_waits() == [1.0]


def test_batcher_round_robin_and_ragged_flush():
    b = DynamicBatcher(max_batch=2, max_wait_s=0.0)
    rids = [b.submit("m1", None, 0.0) for _ in range(4)]
    rids += [b.submit("m2", None, 0.0) for _ in range(3)]
    order = []
    while True:
        fb = b.pop_batch(now=0.0, force=True)
        if fb is None:
            break
        order.append((fb.model, fb.size))
    # alternates between models; m2's last batch is ragged
    assert order == [("m1", 2), ("m2", 2), ("m1", 2), ("m2", 1)]
    assert b.pending() == 0
    assert sorted(rids) == list(range(7))


# ---------------------------------------------------------------------------
# CNNServer end-to-end
# ---------------------------------------------------------------------------

def _micro_serving_registry():
    """One tiny but representative model: SC stem + DC + PC + FC."""
    def factory():
        rng = np.random.default_rng(7)
        return [
            engine.LayerDef("stem", ConvKind.SC,
                            jnp.asarray(rng.normal(size=(6, 3, 3, 3)),
                                        jnp.float32), act="relu", stride=2),
            engine.LayerDef("dw", ConvKind.DC,
                            jnp.asarray(rng.normal(size=(6, 3, 3)),
                                        jnp.float32), act="relu6"),
            engine.LayerDef("pw", ConvKind.PC,
                            jnp.asarray(rng.normal(size=(8, 1, 1, 6)),
                                        jnp.float32), act="relu"),
            engine.LayerDef("fc", ConvKind.FC,
                            jnp.asarray(rng.normal(size=(4, 4 * 4 * 8)),
                                        jnp.float32)),
        ]
    reg = PlanRegistry(capacity=2)
    reg.register("micro", factory, input_shape=(8, 8, 3))
    return reg


def test_server_serves_bit_identical_to_per_image_engine():
    reg = _micro_serving_registry()
    srv = serve.CNNServer(reg, max_batch=4, max_wait_s=0.0)
    rng = np.random.default_rng(0)
    xs = {srv.submit("micro", x): x
          for x in rng.normal(size=(6, 8, 8, 3)).astype(np.float32)}
    outs = srv.run_until_drained()
    assert set(outs) == set(xs)
    entry = reg.get("micro")
    for rid, x in xs.items():
        want = engine.forward(entry.plan, jnp.asarray(x), interpret=True)
        np.testing.assert_array_equal(outs[rid], np.asarray(want)[0])
    # 6 requests / max_batch 4 -> one full + one ragged batch
    sizes = sorted(r.batch_size for r in srv.telemetry.records)
    assert sizes == [2, 4]


def test_server_telemetry_reports_hardware_time():
    reg = _micro_serving_registry()
    srv = serve.CNNServer(reg, max_batch=4, max_wait_s=0.0,
                          hw_points=(serve.OperatingPoint("RMAM", 1.0),
                                     serve.OperatingPoint("AMM", 1.0)))
    rng = np.random.default_rng(1)
    for x in rng.normal(size=(5, 8, 8, 3)).astype(np.float32):
        srv.submit("micro", x)
    srv.run_until_drained()
    s = srv.telemetry.summary()
    assert s["requests"] == 5
    assert s["latency_p99_s"] >= s["latency_p50_s"] >= 0.0
    assert s["images_per_s_wall"] > 0.0
    hw = s["hardware"]
    assert set(hw) == {"RMAM@1G", "AMM@1G"}
    for point in hw.values():
        assert point["modeled_fps"] > 0
        assert point["modeled_fps_per_watt"] > 0
    # per-batch records agree with costing the batch through the simulator
    # directly (same specs, same batch size)
    from repro.core import simulator as sim
    from repro.core import tpc
    entry = reg.get("micro")
    for rec in srv.telemetry.records:
        want = sim.simulate(tpc.build_accelerator("RMAM", 1.0),
                            entry.sim_specs, batch=rec.batch_size)
        assert rec.hw["RMAM@1G"].fps == pytest.approx(want.fps)
        assert rec.hw["RMAM@1G"].fps_per_watt == pytest.approx(
            want.fps_per_watt)
        assert rec.exec_s > 0


def test_server_rejects_malformed_input_at_submit():
    """A wrong-shaped image must be rejected at the door — once a batch is
    formed its requests have left the queue, so a late stack failure would
    silently drop the whole batch."""
    reg = _tiny_registry(["m1"])
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0)
    with pytest.raises(ValueError, match="expects input shape"):
        srv.submit("m1", np.zeros((3, 3, 5), np.float32))   # wants (4, 4, 5)
    good = srv.submit("m1", np.zeros((4, 4, 5), np.float32))
    outs = srv.run_until_drained()
    assert set(outs) == {good}


def test_server_unknown_model_rejected_at_submit_leaves_queue_empty():
    """An unregistered model must fail at submit — if the request were
    queued, step() would crash mid-loop with the batch already popped and
    every other request in it silently dropped."""
    reg = _tiny_registry(["m1"])
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0)
    with pytest.raises(KeyError, match="not registered"):
        srv.submit("ghost", np.zeros((4, 4, 5), np.float32))
    assert srv.pending() == 0             # nothing queued by the bad submit
    assert srv.run_until_drained() == {}
    # same contract for the malformed-shape path
    with pytest.raises(ValueError, match="expects input shape"):
        srv.submit("m1", np.zeros((3, 3, 5), np.float32))
    assert srv.pending() == 0
    assert srv.telemetry.summary()["requests"] == 0


# ---------------------------------------------------------------------------
# SLO admission control + fleet degradation
# ---------------------------------------------------------------------------

def test_slo_flush_dispatches_before_batching_eats_the_deadline():
    """With an SLO, a ragged queue force-flushes once the oldest request
    has waited flush_fraction of the deadline — batching must not eat
    the whole latency budget waiting for a full batch."""
    reg = _tiny_registry(["m1"])
    srv = serve.CNNServer(reg, max_batch=8, max_wait_s=60.0,
                          slo=serve.ServeSLO(deadline_s=1.0,
                                             flush_fraction=0.5))
    rid = srv.submit("m1", np.zeros((4, 4, 5), np.float32), now=0.0)
    assert srv.step(now=0.1) == 0         # under the flush threshold: hold
    assert srv.step(now=0.6) == 1         # 0.6s >= 0.5 * 1.0s: dispatch
    assert rid in srv.results


def test_admission_sheds_typed_on_degraded_fleet_then_recovers():
    """ISSUE acceptance: under an injected 2-of-3 instance loss, submit
    sheds with a typed AdmissionRejected (carrying the estimate that
    justified it) instead of queueing the request to blow p99 — and
    readmits the fleet (and the traffic) when quarantine probes pass."""
    clock = {"t": 0.0}
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), probe_cooldown_s=5.0,
        time_fn=lambda: clock["t"], sleep_fn=lambda s: None)
    reg = _tiny_registry(["m1"])
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0,
                          dispatcher=fleet, time_fn=lambda: clock["t"])
    x = np.zeros((4, 4, 5), np.float32)
    srv.submit("m1", x)
    srv.submit("m1", x)
    srv.run_until_drained()               # seeds the service-rate EMA
    ema = srv._frame_s_ema
    assert ema is not None and ema > 0
    srv.slo = serve.ServeSLO(deadline_s=2 * ema)
    # healthy fleet, empty queue: one frame ahead at full capacity
    assert srv.estimated_completion_s() == pytest.approx(ema)
    fleet._quarantine(fleet.instances[0])     # injected 2-of-3 loss
    fleet._quarantine(fleet.instances[1])
    assert fleet.healthy_capacity_fraction() == pytest.approx(1 / 3)
    with pytest.raises(serve.AdmissionRejected) as ei:
        srv.submit("m1", x)
    err = ei.value
    assert err.model == "m1"
    assert err.deadline_s == pytest.approx(2 * ema)
    assert err.est_s == pytest.approx(3 * ema)    # 1/3 capacity, 3x drain
    assert err.healthy_fraction == pytest.approx(1 / 3)
    assert srv.pending() == 0             # shed at the door, never queued
    assert srv.admission["shed"] == 1
    flt = srv.telemetry.summary()["fleet"]
    assert flt["admission"]["shed"] == 1
    assert flt["admission"]["slo_deadline_s"] == pytest.approx(2 * ema)
    assert flt["healthy_fraction"] == pytest.approx(1 / 3)
    clock["t"] = 10.0                     # probes come due — and pass
    assert len(fleet.active_instances()) == 3
    assert fleet.counters["readmissions"] == 2
    rid = srv.submit("m1", x)             # capacity back: admission resumes
    outs = srv.run_until_drained()
    assert rid in outs
    assert srv.admission["shed"] == 1     # no further sheds
    fleet.close()


def test_server_reset_starts_a_fresh_trace():
    reg = _tiny_registry(["m1"])
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0)
    rng = np.random.default_rng(3)
    first = [srv.submit("m1", rng.normal(size=(4, 4, 5)).astype(np.float32))
             for _ in range(2)]
    srv.run_until_drained()
    srv.reset()
    assert srv.results == {} and srv.telemetry.records == []
    second = srv.submit("m1", rng.normal(size=(4, 4, 5)).astype(np.float32))
    outs = srv.run_until_drained()
    assert set(outs) == {second}          # no stale rids from the first trace
    assert srv.telemetry.summary()["requests"] == 1
    srv.submit("m1", rng.normal(size=(4, 4, 5)).astype(np.float32))
    with pytest.raises(RuntimeError, match="still queued"):
        srv.reset()
    assert first[0] != second             # rids keep increasing across traces


def test_server_mixed_models_keyed_correctly():
    reg = _tiny_registry(["m1", "m2"], capacity=2)
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0)
    rng = np.random.default_rng(2)
    subs = []
    for i in range(6):
        model = "m1" if i % 2 == 0 else "m2"
        x = rng.normal(size=(4, 4, 5)).astype(np.float32)
        subs.append((srv.submit(model, x), model, x))
    outs = srv.run_until_drained()
    for rid, model, x in subs:
        want = engine.forward(reg.get(model).plan, jnp.asarray(x),
                              interpret=True)
        np.testing.assert_array_equal(outs[rid], np.asarray(want))
    served_models = {r.model for r in srv.telemetry.records}
    assert served_models == {"m1", "m2"}


def test_paper_cnn_zoo_specs_consistent():
    """Serving-zoo factories are deterministic, executable and their
    derived analytic specs match the executed plan layer-for-layer."""
    for name in serve.SERVING_MODELS:
        d1 = serve.serving_defs(name, seed=0)
        d2 = serve.serving_defs(name, seed=0)
        for a, b in zip(d1, d2):
            np.testing.assert_array_equal(np.asarray(a.weights),
                                          np.asarray(b.weights))
        specs = serve.specs_for_defs(d1, serve.serving_input_shape(name))
        assert len(specs) == len(d1)
        for spec, ld in zip(specs, d1):
            assert spec.kind is ld.kind
        # spans both GEMM modes + the depthwise path (the paper's mix)
        plan = engine.compile_model(f"zoo_{name}", d1)
        modes = {lp.mode for lp in plan.layers}
        assert modes == {engine.MODE_DENSE, engine.MODE_PACKED,
                         engine.MODE_DEPTHWISE}


def test_batcher_round_robin_fairness_deterministic():
    """Two models submitting interleaved traffic alternate batches exactly,
    and the pop order is a function of the submit trace alone (rotation =
    first-submission order, never the queue dict's iteration order)."""
    def trace(first, second):
        b = DynamicBatcher(max_batch=2, max_wait_s=0.0)
        for i in range(8):
            b.submit(first if i % 2 == 0 else second, None, now=0.0)
        assert b.rotation == [first, second]
        order = []
        while True:
            fb = b.pop_batch(now=0.0, force=True)
            if fb is None:
                break
            order.append(fb.model)
        return order

    # strict alternation; m1 leads because it submitted first
    assert trace("m1", "m2") == ["m1", "m2", "m1", "m2"]
    # swapping the submit order swaps the lead — and names whose hash
    # ordering differs from their arrival order change nothing
    assert trace("m2", "m1") == ["m2", "m1", "m2", "m1"]
    assert trace("zz", "aa") == ["zz", "aa", "zz", "aa"]
    # repeat runs of the same trace pop identically (regression guard)
    assert trace("m1", "m2") == trace("m1", "m2")


def test_batcher_rotation_skips_empty_but_keeps_order():
    b = DynamicBatcher(max_batch=2, max_wait_s=0.0)
    for m in ("a", "b", "c"):
        b.submit(m, None, now=0.0)
    b.submit("b", None, now=0.0)
    # a(1), then b(2), then c(1); a ragged, b full, rotation order kept
    got = []
    while True:
        fb = b.pop_batch(now=0.0, force=True)
        if fb is None:
            break
        got.append((fb.model, fb.size))
    assert got == [("a", 1), ("b", 2), ("c", 1)]


def test_telemetry_records_activation_stream_bytes():
    """Per-batch activation-stream bytes: the quantized-domain stream vs
    the f32 estimate, aggregated into summary()["activation_stream"]."""
    from repro.serve.telemetry import activation_stream_bytes
    reg = _micro_serving_registry()
    srv = serve.CNNServer(reg, max_batch=4, max_wait_s=0.0)
    rng = np.random.default_rng(3)
    for x in rng.normal(size=(6, 8, 8, 3)).astype(np.float32):
        srv.submit("micro", x)
    srv.run_until_drained()
    entry = reg.get("micro")
    per_q, per_f = activation_stream_bytes(entry.exec_specs)
    assert 0 < per_q < per_f
    for rec in srv.telemetry.records:
        assert rec.act_stream_bytes_int8 == rec.batch_size * per_q
        assert rec.act_stream_bytes_f32 == rec.batch_size * per_f
    s = srv.telemetry.summary()["activation_stream"]
    assert s["int8_bytes"] == 6 * per_q
    assert s["f32_bytes"] == 6 * per_f
    # micro has a DC layer (int32 lattice on the VPU path, no saving
    # there), so the model-level ratio lands strictly between 1x and 4x
    assert 1.0 < s["ratio"] < 4.0
    assert s["ratio"] == pytest.approx(per_f / per_q)
    # per-model block carries the same accounting
    sm = srv.telemetry.summary()["models"]["micro"]["activation_stream"]
    assert sm["int8_bytes"] == 6 * per_q


def test_activation_stream_bytes_per_kind():
    """SC/PC/FC stream int8 and share one DIV stream across kernels; DC
    streams one window set per channel on the int32 VPU path (no
    quantized-domain saving, matching kernel_bench's HBM model)."""
    from repro.cnn.layers import dc, fc, pc, sc
    from repro.serve.telemetry import activation_stream_bytes
    assert activation_stream_bytes([sc("s", 3, 4, 10, 5, 5)]) \
        == (5 * 5 * 3 * 3 * 4, 4 * 5 * 5 * 3 * 3 * 4)
    assert activation_stream_bytes([pc("p", 4, 10, 5, 5)]) \
        == (5 * 5 * 4, 4 * 5 * 5 * 4)
    assert activation_stream_bytes([fc("f", 64, 10)]) == (64, 256)
    n_dc = 5 * 5 * 3 * 3 * 8
    assert activation_stream_bytes([dc("d", 3, 8, 5, 5)]) \
        == (4 * n_dc, 4 * n_dc)
