"""Cycle-true simulator tests: Figs. 10-11 headline claims + invariants."""
import math

import pytest

from repro.cnn.models import MODEL_ZOO, PAPER_CNNS
from repro.core import simulator as sim
from repro.core import tpc
from repro.core.mapping import map_layer


@pytest.fixture(scope="module")
def results():
    tables = {name: MODEL_ZOO[name]() for name in PAPER_CNNS}
    return sim.evaluate_suite(tables)


def _g(nf, name, br):
    return sim.gmean(nf[name][br].values())


def test_rmam_beats_all_at_every_bitrate(results):
    """Fig. 10: RMAM has the best FPS of all accelerators at each BR."""
    for br in tpc.PAPER_BIT_RATES:
        for cnn in PAPER_CNNS:
            best = results["RMAM"][br][cnn].fps
            for other in ("MAM", "AMM", "CROSSLIGHT"):
                assert best > results[other][br][cnn].fps


def test_fig10_headline_ratios(results):
    """RMAM@1G vs baselines (gmean): 1.8x / 17.1x / 65x in the paper.

    Our mechanistic simulator reproduces the ordering and magnitudes within
    the documented fidelity band (EXPERIMENTS.md §Fidelity): MAM ratio within
    15%, AMM within ~2x, CROSSLIGHT within ~2x.
    """
    nf = sim.normalized_fps(results)
    assert _g(nf, "MAM", 1.0) == pytest.approx(1 / 1.8, rel=0.15)
    assert 17.1 / 2.0 < 1 / _g(nf, "AMM", 1.0) < 17.1 * 2.0
    assert 65 / 2.0 < 1 / _g(nf, "CROSSLIGHT", 1.0) < 65 * 2.0


def test_fig11_headline_ratios(results):
    """FPS/W @1G (gmean): 1.5x / 27.2x / 171x in the paper."""
    nw = sim.normalized_fps_per_watt(results)
    assert _g(nw, "MAM", 1.0) == pytest.approx(1 / 1.5, rel=0.20)
    assert 27.2 / 2.0 < 1 / _g(nw, "AMM", 1.0) < 27.2 * 2.0
    assert 1 / _g(nw, "CROSSLIGHT", 1.0) == pytest.approx(171, rel=0.25)


def test_ramm_crosslight_fps_per_watt(results):
    """Paper: RAMM achieves 9.7x better FPS/W than CROSSLIGHT at 1 Gbps."""
    nw = sim.normalized_fps_per_watt(results)
    ratio = _g(nw, "RAMM", 1.0) / _g(nw, "CROSSLIGHT", 1.0)
    assert ratio == pytest.approx(9.7, rel=0.25)


def test_ramm_identical_mapping_to_amm_at_5g():
    """Paper: at 5 Gbps RAMM's y = 0, so it degenerates to AMM exactly."""
    ramm = tpc.build_accelerator("RAMM", 5.0)
    amm = tpc.build_accelerator("AMM", 5.0)
    assert ramm.y == 0
    for layer in MODEL_ZOO["shufflenet_v2"]():
        m1 = map_layer(ramm.tpc_config, layer)
        m2 = map_layer(amm.tpc_config, layer)
        assert m1.groups == m2.groups


def test_reconfiguration_improves_mean_utilization(results):
    for br in (1.0, 3.0):
        for cnn in PAPER_CNNS:
            assert (results["RMAM"][br][cnn].mean_utilization
                    > results["MAM"][br][cnn].mean_utilization)


def test_crosslight_to_tuning_dominates(results):
    """CROSSLIGHT's 4 us thermo-optic retune makes it the slowest design."""
    for br in tpc.PAPER_BIT_RATES:
        for cnn in PAPER_CNNS:
            slowest = min(results[a][br][cnn].fps for a in tpc.ACCELERATORS)
            assert results["CROSSLIGHT"][br][cnn].fps == slowest


def test_energy_accounting(results):
    rep = results["RMAM"][1.0]["xception"]
    assert rep.energy_per_frame_j > 0
    assert rep.avg_power_w >= rep.accelerator.power_static_w() * 0.999
    assert rep.avg_power_w <= rep.peak_power_w * 1.001
    assert rep.fps_per_watt == pytest.approx(1 / rep.energy_per_frame_j)


def test_batching_amortizes_overheads():
    layers = MODEL_ZOO["shufflenet_v2"]()
    acc = tpc.build_accelerator("RMAM", 1.0)
    fps1 = sim.simulate(acc, layers, batch=1).fps
    fps8 = sim.simulate(acc, layers, batch=8).fps
    assert fps8 > fps1


def test_batching_per_frame_accounting():
    """Batch>1: DIV-DAC samples scale linearly (same fresh points per
    frame), so per-frame dynamic energy is constant, while per-frame
    latency amortizes the per-round overheads (retune + weight DACs)."""
    layers = MODEL_ZOO["shufflenet_v2"]()
    acc = tpc.build_accelerator("RMAM", 1.0)
    r1 = sim.simulate(acc, layers, batch=1)
    r8 = sim.simulate(acc, layers, batch=8)
    for l1, l8 in zip(r1.layers, r8.layers):
        assert l8.div_samples == 8 * l1.div_samples
        # overheads are per round, streams are per frame: a layer's total
        # time grows strictly sub-linearly in batch
        assert l1.time_s < l8.time_s < 8 * l1.time_s
    # per-frame DIV work identical -> identical per-frame dynamic energy
    assert (sum(l.div_samples for l in r8.layers) / 8
            == sum(l.div_samples for l in r1.layers))
    # per-frame latency and energy amortize; FPS/W strictly improves
    assert r8.frame_latency_s < r1.frame_latency_s
    assert r8.energy_per_frame_j < r1.energy_per_frame_j
    assert r8.fps_per_watt > r1.fps_per_watt


def test_gmean_empty_raises():
    with pytest.raises(ValueError, match="empty"):
        sim.gmean([])
    assert sim.gmean([2.0, 8.0]) == pytest.approx(4.0)


def test_area_proportionate_counts_close_to_table8():
    """Our transparent area model lands near Table VIII at 1 Gbps.

    At 3/5 Gbps the paper's counts barely move (568 -> 547) even though its
    own Table V ADC area grows 50x, so the paper's area spreadsheet weights
    ADCs differently than a straight per-SE accounting; we assert the 1 Gbps
    agreement (+-25%) and the within-family orderings, and report the full
    model table in benchmarks/table8_bench (EXPERIMENTS.md documents the
    residual).  The simulator itself always uses the paper's counts.
    """
    ours = tpc.area_proportionate_counts(1.0)
    for name, ref in tpc.PAPER_TABLE_VIII.items():
        if name == "CROSSLIGHT":
            continue
        assert ours[name] == pytest.approx(ref[1.0], rel=0.25), name
    for br in tpc.PAPER_BIT_RATES:
        o = tpc.area_proportionate_counts(br)
        # reconfiguration hardware costs VDPE count at equal area (RAMM@5G
        # has y = 0 comb switches, i.e. it *is* AMM -> equal counts)
        ramm_y = tpc.build_accelerator("RAMM", br).y
        assert o["RAMM"] < o["AMM"] if ramm_y else o["RAMM"] == o["AMM"]
        assert o["RMAM"] < o["MAM"]


def test_power_hierarchy():
    """AMM-family provisions M x N input DACs -> higher provisioned power."""
    for br in tpc.PAPER_BIT_RATES:
        mam = tpc.build_accelerator("MAM", br)
        amm = tpc.build_accelerator("AMM", br)
        rmam = tpc.build_accelerator("RMAM", br)
        assert amm.power_w() > rmam.power_w() > mam.power_w()
