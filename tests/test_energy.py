"""Component-level energy ledger + FPS/W-aware planning tests.

The ledger's contract is exactness by construction: every power/energy
total in the stack is *defined* as the sum of its component rows
(``AcceleratorConfig.power_breakdown`` -> ``power_static_w``;
``LayerCost.components`` -> ``energy_j`` -> ``energy_per_frame_j``), so
these tests assert tight (1e-9 relative) agreement across the full
accelerator x bit-rate x CNN-zoo sweep, not loose sanity bounds.  The
planner side pins the objective guarantees (EDP plan's EDP never exceeds
the latency plan's; power-capped plans never choose infeasible points)
and that objectives/caps never change model outputs bitwise.
"""
import math
import warnings

import jax
import numpy as np
import pytest

from repro import engine, serve
from repro.cnn.models import MODEL_ZOO
from repro.core import mapping
from repro.core import simulator as sim
from repro.core import tpc
from repro.core.operating_point import OperatingPoint
from repro.core.tpc import (DEFAULT_LIBRARY, LEDGER_COMPONENTS,
                            accelerator_at, build_accelerator,
                            component_powers)
from repro.serve import models as zoo

jax.config.update("jax_platform_name", "cpu")

REL = 1e-9
SWEEP = [(name, br) for name in tpc.ACCELERATORS
         for br in tpc.PAPER_BIT_RATES]


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-30)


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    yield
    engine.plan_cache_clear()


# ---------------------------------------------------------------------------
# ComponentLibrary + power_breakdown
# ---------------------------------------------------------------------------

def test_power_breakdown_rows_sum_exactly_to_static_power():
    for name, br in SWEEP:
        acc = build_accelerator(name, br)
        bd = acc.power_breakdown()
        assert tuple(bd) == LEDGER_COMPONENTS, (name, br)
        assert all(v >= 0.0 for v in bd.values()), (name, br)
        # power_static_w is DEFINED as the ledger sum — exact equality
        assert sum(bd.values()) == acc.power_static_w(), (name, br)
        # peak fills the DIV-DAC idle fraction up to full rate
        assert acc.power_w() >= acc.power_static_w()


def test_component_powers_accessor_matches_method():
    acc = build_accelerator("RMAM", 1.0)
    assert component_powers(acc) == acc.power_breakdown()
    assert component_powers(acc, DEFAULT_LIBRARY) == acc.power_breakdown()


def test_module_constants_alias_the_library():
    assert tpc.DAC_POWER == DEFAULT_LIBRARY["dac"].power_w
    assert tpc.TIA_POWER == DEFAULT_LIBRARY["tia"].power_w
    assert tpc.PD_POWER == DEFAULT_LIBRARY["pd"].power_w
    assert tpc.EDRAM_POWER == DEFAULT_LIBRARY["edram"].power_w
    for br, (area, p) in tpc.ADC_TABLE.items():
        e = DEFAULT_LIBRARY.adc_at(br)
        assert (area, p) == (e.area_mm2, e.power_w)
    with pytest.raises(KeyError):
        DEFAULT_LIBRARY["no_such_component"]
    with pytest.raises(KeyError):
        DEFAULT_LIBRARY.adc_at(2.0)


def test_breakdown_moves_with_retuned_geometry():
    acc = build_accelerator("RMAM", 1.0)
    base = acc.power_breakdown()
    fixed = accelerator_at(acc, mapping.FIXED_POINT_OPTION)
    retuned = accelerator_at(acc, mapping.PointOption(x=9))
    # the fixed point drops the per-lane comb-switch SEs -> fewer ADCs
    assert fixed.power_breakdown()["adc_pd_tia"] < base["adc_pd_tia"]
    assert retuned.power_breakdown()["adc_pd_tia"] >= base["adc_pd_tia"]
    # laser/tuning/periphery rows don't move with x
    for row in ("laser", "tuning", "memory_noc", "periphery"):
        assert fixed.power_breakdown()[row] == base[row]


# ---------------------------------------------------------------------------
# ledger exactness across the full sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cnn", sorted(MODEL_ZOO))
def test_ledger_exact_across_accelerator_sweep(cnn):
    specs = MODEL_ZOO[cnn]()
    for name, br in SWEEP:
        rep = sim.simulate(build_accelerator(name, br), specs)
        total = rep.energy_per_frame_j
        rows = rep.layer_costs()
        # per-row: energy_j is DEFINED as the component sum — exact
        for row in rows:
            assert tuple(row.components) == LEDGER_COMPONENTS
            assert row.energy_j == sum(row.components.values())
        # rows sum to the frame energy within 1e-9 relative
        assert _rel_err(sum(r.energy_j for r in rows), total) <= REL, (
            cnn, name, br)
        # report-level breakdown also sums to the frame energy
        bd = rep.energy_breakdown()
        assert tuple(bd) == LEDGER_COMPONENTS
        assert _rel_err(sum(bd.values()), total) <= REL
        # column sums of the per-layer ledger reproduce the breakdown
        for c in LEDGER_COMPONENTS:
            col = sum(r.components[c] for r in rows)
            assert _rel_err(col, bd[c]) <= 1e-6, (cnn, name, br, c)


def test_batch_amortization_keeps_ledger_exact():
    specs = MODEL_ZOO["shufflenet_v2"]()
    for batch in (1, 4, 16):
        rep = sim.simulate(build_accelerator("RMAM", 1.0), specs,
                           batch=batch)
        rows = rep.layer_costs()
        assert _rel_err(sum(r.energy_j for r in rows),
                        rep.energy_per_frame_j) <= REL
        assert _rel_err(sum(r.time_s for r in rows),
                        rep.frame_latency_s) <= REL


# ---------------------------------------------------------------------------
# InferenceReport power API (satellite c)
# ---------------------------------------------------------------------------

def test_report_power_naming_and_deprecation():
    rep = sim.simulate(build_accelerator("RMAM", 1.0),
                       MODEL_ZOO["mobilenet_v1"]())
    assert rep.avg_power_w == rep.energy_per_frame_j / rep.frame_latency_s
    assert rep.peak_power_w == rep.accelerator.power_w()
    # static <= frame-averaged <= peak
    assert (rep.accelerator.power_static_w() <= rep.avg_power_w * (1 + REL)
            <= rep.peak_power_w * (1 + REL))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with pytest.raises(DeprecationWarning):
            rep.power_w
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert rep.power_w == rep.avg_power_w


# ---------------------------------------------------------------------------
# OperatingPoint unification (satellite a)
# ---------------------------------------------------------------------------

def test_operating_point_accelerator_view():
    op = OperatingPoint("AMM", 5.0)
    acc = op.to_accelerator()
    ref = build_accelerator("AMM", 5.0)
    assert acc == ref and op.label == "AMM@5G"
    # comb-switch overrides route through accelerator_at
    op9 = OperatingPoint("RMAM", 1.0, x=9)
    assert op9.to_accelerator() == accelerator_at(
        build_accelerator("RMAM", 1.0), x=9)
    fixed = OperatingPoint("RMAM", 1.0, reconfigurable=False)
    assert fixed.to_accelerator().y == 0


def test_operating_point_engine_roundtrip():
    ep = engine.EnginePoint(x=0, bits=8)
    op = OperatingPoint.from_engine(ep, "RMAM", 1.0)
    assert op.to_engine() == ep
    # defaults map to the engine's defaults
    assert OperatingPoint().to_engine() == engine.DEFAULT_POINT


def test_hardware_point_is_deprecated_alias():
    with pytest.warns(DeprecationWarning, match="HardwarePoint is deprec"):
        hp = serve.HardwarePoint("RMAM", 5.0)   # historical positional form
    assert isinstance(hp, OperatingPoint)
    assert hp.label == "RMAM@5G"
    assert hp.to_accelerator() == build_accelerator("RMAM", 5.0)
    assert serve.OperatingPoint is OperatingPoint
    assert all(isinstance(p, OperatingPoint)
               for p in serve.DEFAULT_HW_POINTS)


# ---------------------------------------------------------------------------
# planner objectives (tentpole 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cnn", sorted(MODEL_ZOO))
def test_edp_and_energy_objectives_dominate_latency_plan(cnn):
    specs = MODEL_ZOO[cnn]()
    acc = build_accelerator("RMAM", 1.0)
    reps = {o: engine.search_points(specs, acc=acc, objective=o)
            for o in engine.OBJECTIVES}
    assert reps["edp"].edp <= reps["latency"].edp * (1 + REL), cnn
    assert (reps["energy"].total_energy_j
            <= reps["latency"].total_energy_j * (1 + REL)), cnn
    assert (reps["energy"].total_energy_j
            <= reps["edp"].total_energy_j * (1 + REL)), cnn
    for rep in reps.values():
        # the reported totals decompose over choices + switch charges
        assert rep.total_time_s == pytest.approx(
            sum(c.time_s for c in rep.choices)
            + rep.switches * rep.switch_penalty_s)
        assert rep.total_energy_j == pytest.approx(
            sum(c.energy_j for c in rep.choices)
            + rep.switches * rep.switch_penalty_s
            * acc.power_static_w())
        assert rep.avg_power_w > 0 and rep.fixed_edp > 0


def test_objective_validation():
    with pytest.raises(ValueError, match="objective"):
        engine.search_points(MODEL_ZOO["mobilenet_v1"]()[:3],
                             objective="fps")


def test_power_cap_screens_infeasible_points():
    specs = MODEL_ZOO["xception"]()[:16]
    acc = build_accelerator("RMAM", 1.0)
    opts = mapping.point_options(acc.n)
    powers = sorted(accelerator_at(acc, o).power_w() for o in opts)
    fixed_p = accelerator_at(acc, mapping.FIXED_POINT_OPTION).power_w()
    assert fixed_p == powers[0]     # fixed point is always cheapest
    # a cap between the cheapest and priciest point drops some options
    cap = (powers[0] + powers[-1]) / 2
    rep = engine.search_points(specs, acc=acc, power_cap_w=cap)
    assert rep.cap_excluded
    assert all(c.point_power_w <= cap for c in rep.choices)
    assert rep.max_point_power_w <= cap
    assert rep.power_cap_w == cap
    # the tightest feasible cap forces the all-fixed sequence
    tight = engine.search_points(specs, acc=acc,
                                 power_cap_w=fixed_p * (1 + REL))
    assert set(tight.labels) == {mapping.FIXED_POINT_OPTION.label}
    # an infeasible cap is a hard error, not a silent empty plan
    with pytest.raises(ValueError, match="power_cap_w"):
        engine.search_points(specs, acc=acc, power_cap_w=fixed_p * 0.5)


def test_uncapped_unfiltered_latency_search_unchanged():
    # objective/power_cap_w default to the historical behavior: same
    # labels and totals as a call that never mentions them
    specs = MODEL_ZOO["shufflenet_v2"]()
    a = engine.search_points(specs)
    b = engine.search_points(specs, objective="latency", power_cap_w=None)
    assert a.labels == b.labels
    assert a.total_time_s == b.total_time_s
    assert a.uplift >= 1.0


# ---------------------------------------------------------------------------
# bitwise identity across objectives/caps (acceptance)
# ---------------------------------------------------------------------------

def test_objectives_and_caps_never_change_outputs():
    name = "xception_mini"
    defs = zoo.serving_defs(name, 0)
    shape = zoo.serving_input_shape(name)
    rng = np.random.default_rng(5)
    xb = rng.normal(size=(3, *shape)).astype(np.float32)
    acc = build_accelerator("RMAM", 1.0)
    cap = accelerator_at(acc, mapping.PointOption(x=9)).power_w()
    variants = {
        "latency": engine.plan_model(f"{name}#lat", defs, shape),
        "edp": engine.plan_model(f"{name}#edp", defs, shape,
                                 objective="edp"),
        "energy": engine.plan_model(f"{name}#en", defs, shape,
                                    objective="energy"),
        "capped": engine.plan_model(f"{name}#cap", defs, shape,
                                    power_cap_w=cap),
        "fixed": engine.compile_model(f"{name}#fix", defs),
    }
    ref = np.asarray(engine.forward(variants["fixed"], xb))
    for label, plan in variants.items():
        np.testing.assert_array_equal(
            np.asarray(engine.forward(plan, xb)), ref, err_msg=label)
        np.testing.assert_array_equal(
            np.asarray(engine.forward_jit(plan, xb)), ref, err_msg=label)
    # the planner record reflects the requested objective/cap
    assert variants["edp"].planner.objective == "edp"
    assert variants["capped"].planner.power_cap_w == cap


# ---------------------------------------------------------------------------
# serving surface: fleet power cap + per-component telemetry
# ---------------------------------------------------------------------------

def _mini_entry():
    reg = serve.paper_cnn_registry()
    return reg.get("xception_mini")


def test_fleet_power_cap_respected_and_exported():
    entry = _mini_entry()
    rng = np.random.default_rng(9)
    xb = rng.normal(size=(6, *zoo.serving_input_shape(
        "xception_mini"))).astype(np.float32)
    instances = [
        serve.AcceleratorInstance("a0", OperatingPoint("RMAM", 1.0)),
        serve.AcceleratorInstance("a1", OperatingPoint("RMAM", 1.0)),
        serve.AcceleratorInstance("a2", OperatingPoint("RMAM", 5.0)),
    ]
    p1 = OperatingPoint("RMAM", 1.0).to_accelerator().power_w()
    uncapped = serve.ShardedDispatcher(instances)
    ref, _ = uncapped.run(entry.plan, xb)
    # budget for exactly the two 1G instances: the 5G one must idle
    capped = serve.ShardedDispatcher(instances,
                                     fleet_power_cap_w=2.05 * p1)
    out, runs = capped.run(entry.plan, xb)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert {r.instance.name for r in runs} == {"a0", "a1"}
    assert capped.counters["power_deferrals"] >= 1
    health = capped.fleet_health()
    assert health["power_cap_w"] == pytest.approx(2.05 * p1)
    assert health["admitted_power_w"] <= health["power_cap_w"]
    assert health["peak_power_w"] == pytest.approx(
        sum(health["instances"][n]["power_w"] for n in health["instances"]))
    assert health["instances"]["a2"]["power_w"] > p1
    assert health["instances"]["a2"]["frames"] == 0
    # a budget no instance fits under is rejected at construction
    with pytest.raises(ValueError, match="fleet_power_cap_w"):
        serve.ShardedDispatcher(instances, fleet_power_cap_w=p1 * 0.5)
    uncapped.close()
    capped.close()


def test_telemetry_reports_component_energy_rows():
    entry = _mini_entry()
    log = serve.TelemetryLog(points=(OperatingPoint("RMAM", 1.0),))
    log.record_batch(model="xception_mini", sim_specs=entry.sim_specs,
                     batch_size=4, t_formed=0.0, exec_s=0.01,
                     queue_waits_s=[0.0] * 4, latencies_s=[0.01] * 4,
                     shards=[("a0", 4, OperatingPoint("RMAM", 1.0), 0.01)])
    s = log.summary()
    hw = s["hardware"]["RMAM@1G"]
    comps = hw["energy_components_j"]
    assert tuple(comps) == LEDGER_COMPONENTS
    assert sum(comps.values()) == pytest.approx(
        hw["modeled_energy_per_frame_j"], rel=REL)
    disp = s["dispatch"]["a0"]
    assert sum(disp["energy_components_j"].values()) == pytest.approx(
        disp["modeled_energy_per_frame_j"], rel=REL)
    # per-layer attribution carries the same ledger rows and stays exact
    layers = s["layers"]["xception_mini"]
    assert layers["coverage"] == pytest.approx(1.0, rel=REL)
    model_comps = layers["energy_components_j"]
    by_layer_total = sum(
        row["energy_components_j"][c]
        for row in layers["by_layer"].values() for c in LEDGER_COMPONENTS)
    assert sum(model_comps.values()) == pytest.approx(by_layer_total,
                                                      rel=REL)
    for row in layers["by_layer"].values():
        assert math.isclose(sum(row["energy_components_j"].values()),
                            row["energy_j"], rel_tol=1e-9)
