"""Sharding-variant tests: the §Perf levers stay wired up."""
import jax
import pytest

from repro.configs import get_config, load_all
from repro.launch.dryrun import VARIANTS, _pad_heads_cfg

jax.config.update("jax_platform_name", "cpu")
load_all()


def test_variants_registry():
    assert "baseline" in VARIANTS
    for name in ("tp_infer", "serve_opt", "kv_ctx", "bf16_scores",
                 "ep_pod", "pad_heads"):
        assert name in VARIANTS


def test_pad_heads_llava():
    cfg = _pad_heads_cfg(get_config("llava-next-34b"))
    assert cfg.n_heads == 64
    assert cfg.resolved_head_dim == 128          # pinned, not 7168/64
    assert cfg.n_heads % cfg.n_kv_heads == 0


def test_pad_heads_gemma2():
    cfg = _pad_heads_cfg(get_config("gemma2-2b"))
    assert cfg.n_heads == 16 and cfg.resolved_head_dim == 256


def test_pad_heads_noop_when_divisible():
    cfg = get_config("deepseek-67b")
    assert _pad_heads_cfg(cfg) is cfg


def test_pad_heads_rejects_gqa_mismatch():
    with pytest.raises(ValueError):
        _pad_heads_cfg(get_config("hymba-1.5b"))   # 25 -> 32 % kv=5 != 0


def test_shardings_flags():
    import dataclasses
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    from repro.models.sharding import Shardings
    cfg = get_config("deepseek-67b")
    sh = Shardings(mesh=make_host_mesh(), cfg=cfg, batch=8)
    tp = dataclasses.replace(sh, fsdp=False)
    assert sh.w_in()[0] is not None or sh.mesh.shape["data"] == 1
    assert tp.w_in() == P(None, "model")
    kv = dataclasses.replace(sh, kv_ctx=True)
    spec = kv.kv_cache(8, 128)
    assert spec[2] == "model"                    # context dim sharded
