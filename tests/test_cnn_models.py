"""CNN model-zoo tests: paper Table III census + published MAC counts."""
import pytest

from repro.cnn.layers import dkv_census, total_macs
from repro.cnn.models import (MODEL_ZOO, PAPER_CNNS, efficientnet,
                              mobilenet_v1, resnet50)

#: Paper Table III — (kind, S) -> total kernel count F for EfficientNet-B7.
TABLE_III = {
    ("DC", 9): 25024, ("DC", 25): 45216,
    ("PC", 8): 288, ("PC", 12): 2016, ("PC", 16): 64, ("PC", 20): 3360,
    ("PC", 32): 312, ("PC", 40): 9600, ("PC", 48): 2016, ("PC", 56): 13440,
    ("PC", 64): 48, ("PC", 80): 3360, ("PC", 96): 29952, ("PC", 160): 21120,
    ("PC", 192): 56, ("PC", 224): 13440, ("PC", 288): 452, ("PC", 384): 29952,
    ("PC", 480): 780, ("PC", 640): 14080, ("PC", 960): 2064,
    ("PC", 1344): 2960, ("PC", 2304): 6496, ("PC", 3840): 2400,
    ("SC", 27): 64,
}


def test_table3_exact():
    """Our EfficientNet-B7 generator reproduces Table III exactly."""
    census = dkv_census(efficientnet("B7"))
    ours = {(kind, s): f for kind, _, f, s in census if kind != "FC"}
    assert ours == TABLE_III


def test_table3_fc_row():
    """Table III's FC row: S = 2560 (head width)."""
    fc = [l for l in efficientnet("B7") if l.kind.value == "FC"]
    assert len(fc) == 1 and fc[0].dkv_size == 2560


@pytest.mark.parametrize("name,ref_gmacs,tol", [
    ("efficientnet_b7", 37.0, 0.05),   # published 37 GFLOPs (MAC convention)
    ("xception", 8.4, 0.05),
    ("shufflenet_v2", 0.146, 0.05),
    ("nasnet_mobile", 0.564, 0.15),    # cell-census approximation
    ("mobilenet_v1", 0.569, 0.05),
    ("resnet50", 3.86, 0.05),
])
def test_published_mac_counts(name, ref_gmacs, tol):
    gmacs = total_macs(MODEL_ZOO[name]()) / 1e9
    assert gmacs == pytest.approx(ref_gmacs, rel=tol)


def test_efficientnet_b0_macs():
    assert total_macs(efficientnet("B0")) / 1e9 == pytest.approx(0.39, rel=0.05)


@pytest.mark.parametrize("name", list(MODEL_ZOO))
def test_layer_tables_wellformed(name):
    layers = MODEL_ZOO[name]()
    assert layers, name
    for l in layers:
        assert l.dkv_size >= 1
        assert l.f >= 1
        assert l.n_positions >= 1
        assert l.macs == l.f * l.n_positions * l.dkv_size
        if l.kind.value == "DC":
            assert l.d == 1          # one 2-D kernel per channel
        if l.kind.value == "PC":
            assert l.k == 1


def test_paper_cnns_have_mixed_tensors():
    """The paper's premise: the four CNNs mix small DCs with large PCs."""
    for name in PAPER_CNNS:
        sizes = {l.dkv_size for l in MODEL_ZOO[name]()}
        assert min(sizes) <= 25
        assert max(sizes) >= 464
