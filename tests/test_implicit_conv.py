"""Implicit-GEMM conv kernels + whole-model jitted pipeline.

Bitwise contracts under test:

* kernels/vdpe_conv.py == the materialized im2col -> GEMM oracle at the
  raw-int32 and fused-epilogue levels (scalar-SMEM and per-image scales);
* engine forward_layer (implicit) == forward_layer_im2col across
  SC/PC/DC, strides 1/2, SAME/VALID, single images and batches;
* engine.forward_jit (one XLA dispatch, bucketed batches) == the eager
  layer loop for ragged batch sizes, compiling once per (plan, bucket);
* the shared alignment helpers (kernels/common.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.cnn.layers import ConvKind
from repro.core import vdp
from repro.engine import executor as ex
from repro.kernels import common, ops, ref
from repro.kernels import vdpe_conv as kconv
from repro.serve import models as zoo

jax.config.update("jax_platform_name", "cpu")


def _rand_int8(rng, shape, lo=-7, hi=8):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int8)


# ---------------------------------------------------------------------------
# Shared helpers (kernels/common.py)
# ---------------------------------------------------------------------------

def test_round_up():
    assert common.round_up(0, 128) == 0
    assert common.round_up(1, 128) == 128
    assert common.round_up(128, 128) == 128
    assert common.round_up(129, 128) == 256
    assert common.round_up(27, 32) == 32


def test_pad_to():
    a = jnp.ones((3, 5), jnp.int8)
    p = common.pad_to(a, 8, 128)
    assert p.shape == (8, 128)
    np.testing.assert_array_equal(np.asarray(p[:3, :5]), np.asarray(a))
    assert int(jnp.abs(p).sum()) == 15          # padding is zeros


def test_single_round_up_definition():
    """The alignment helper has ONE home; the old copy-paste sites import
    from it instead of redefining it."""
    from repro.engine import plan as plan_mod
    assert ops._round_up is common.round_up
    assert plan_mod._round_up is common.round_up
    assert ex._round_up is common.round_up


# ---------------------------------------------------------------------------
# Kernel level: implicit gather == materialized im2col contraction
# ---------------------------------------------------------------------------

def _im2col_int(x_q, k, stride, ho, wo):
    """Oracle DIV matrix from the already-padded quantized image batch."""
    b, hp, wp, d = x_q.shape
    cols = []
    for kk in range(k * k):
        di, dj = divmod(kk, k)
        cols.append(x_q[:, di:di + stride * (ho - 1) + 1:stride,
                        dj:dj + stride * (wo - 1) + 1:stride, :])
    return jnp.stack(cols, axis=3).reshape(b, ho * wo, k * k * d)


@pytest.mark.parametrize("k,stride", [(1, 1), (1, 2), (3, 1), (3, 2)])
def test_vdpe_conv_matches_im2col_gemm_raw(k, stride):
    """Raw int32 accumulators: the in-kernel tap gather == the (B, P, S)
    DIV matrix contraction, for every tap geometry."""
    rng = np.random.default_rng(10 * k + stride)
    b, d, f_pad = 2, 5, 128
    ho = wo = 4
    hp = stride * (ho - 1) + k
    x_q = _rand_int8(rng, (b, hp, hp, d))
    s = k * k * d
    rhs = _rand_int8(rng, (s, f_pad))
    got = kconv.vdpe_conv(x_q, rhs, k, stride, ho, wo, interpret=True)
    divs = _im2col_int(x_q, k, stride, ho, wo)
    want = jax.lax.dot_general(
        divs.astype(jnp.int32), rhs.astype(jnp.int32),
        (((2,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("scale_kind", ["scalar", "per_image"])
def test_vdpe_conv_fused_epilogue_variants(scale_kind):
    """Both epilogue variants == epilogue_ref over the raw accumulator."""
    rng = np.random.default_rng(7)
    b, d, f_pad, k, stride = 3, 4, 128, 3, 1
    ho = wo = 5
    hp = stride * (ho - 1) + k
    x_q = _rand_int8(rng, (b, hp, hp, d))
    rhs = _rand_int8(rng, (k * k * d, f_pad))
    bias = jnp.asarray(rng.normal(size=(1, f_pad)), jnp.float32)
    if scale_kind == "scalar":
        scale = jnp.float32(0.037)
        scale_bc = scale
    else:
        scale = jnp.asarray(rng.random(b) * 0.1 + 0.01, jnp.float32)
        scale_bc = scale[:, None, None]
    raw = kconv.vdpe_conv(x_q, rhs, k, stride, ho, wo, interpret=True)
    got = kconv.vdpe_conv(x_q, rhs, k, stride, ho, wo, interpret=True,
                          scale=scale, bias=bias, act="relu6")
    want = ref.epilogue_ref(raw, scale_bc, bias[None], "relu6")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_pack_conv_zs_rejects_block_diagonal_operand():
    """Structural zero-skipping: the (y*x, F) block-diagonal pack cannot
    enter — only the (x, F) segment-sum rides the Mode-2 conv kernel."""
    rng = np.random.default_rng(0)
    x_q = _rand_int8(rng, (1, 4, 4, 9))
    y = ops.N_TPU // ops.X_TPU
    rhs_bd = _rand_int8(rng, (y * ops.X_TPU, 128))
    with pytest.raises(AssertionError, match="segment-sum"):
        kconv.vdpe_pack_conv_zs(x_q, rhs_bd, 1, 1, 4, 4, x=ops.X_TPU,
                                interpret=True)


def test_pack_conv_zs_matches_mode1_conv():
    """The zero-skipping conv == the dense Mode-1 conv on the same weights
    (segment rows beyond S are zero, so both contract the same S taps)."""
    rng = np.random.default_rng(3)
    b, d, k, f = 2, 3, 3, 16
    ho = wo = 4
    x_q = _rand_int8(rng, (b, ho + k - 1, wo + k - 1, d))
    s = k * k * d                                 # 27 <= x = 32
    dkvs = _rand_int8(rng, (f, s))
    rhs_seg = common.pad_to(ops.pack_mode2_segments(dkvs, ops.X_TPU),
                            ops.X_TPU, 128)
    rhs_m1 = common.pad_to(jnp.transpose(dkvs), s, 128)
    got = kconv.vdpe_pack_conv_zs(x_q, rhs_seg, k, 1, ho, wo,
                                  x=ops.X_TPU, interpret=True)
    want = kconv.vdpe_conv(x_q, rhs_m1, k, 1, ho, wo, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_window_bounds_guard():
    """An activation smaller than the tap window is rejected, not read OOB."""
    rng = np.random.default_rng(1)
    x_q = _rand_int8(rng, (1, 4, 4, 2))
    rhs = _rand_int8(rng, (3 * 3 * 2, 128))
    with pytest.raises(AssertionError, match="pad"):
        kconv.vdpe_conv(x_q, rhs, 3, 2, 4, 4, interpret=True)


# ---------------------------------------------------------------------------
# Executor level: implicit path == im2col oracle path, bitwise
# ---------------------------------------------------------------------------

def _layer_def(kind, k, stride, padding, bias, act, rng, d=6, f=20):
    if kind is ConvKind.DC:
        w = jnp.asarray(rng.normal(size=(d, k, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(d,)), jnp.float32) if bias else None
    else:
        kk = 1 if kind is ConvKind.PC else k
        w = jnp.asarray(rng.normal(size=(f, kk, kk, d)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(f,)), jnp.float32) if bias else None
    return engine.LayerDef("l", kind, w, bias=b, act=act,
                           stride=stride, padding=padding)


@pytest.mark.parametrize("kind", [ConvKind.SC, ConvKind.PC, ConvKind.DC])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_implicit_matches_im2col_oracle(kind, stride, padding):
    """forward_layer (implicit) == forward_layer_im2col, bitwise, for
    single images and batches (both epilogue variants), biased relu6."""
    rng = np.random.default_rng(hash((kind.value, stride, padding)) % 2**32)
    ld = _layer_def(kind, 3, stride, padding, bias=True, act="relu6", rng=rng)
    plan = engine.compile_model(
        f"imp_{kind.value}_{stride}_{padding}", [ld])
    (lp,) = plan.layers
    for b in (1, 3):                  # scalar-SMEM and per-image epilogues
        x = jnp.asarray(rng.normal(size=(b, 9, 9, 6)), jnp.float32)
        xin = x[0] if b == 1 else x   # also cover the single-image API
        got = engine.forward_layer(plan, lp, xin, interpret=True)
        want = engine.forward_layer_im2col(plan, lp, xin, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("kind,act,bias", [
    (ConvKind.SC, "none", False),
    (ConvKind.PC, "relu", False),
    (ConvKind.DC, "relu", True),
])
def test_implicit_matches_im2col_oracle_epilogue_mix(kind, act, bias):
    """Bias-free and activation-mix coverage of the same bitwise contract."""
    rng = np.random.default_rng(17)
    ld = _layer_def(kind, 3, 1, "SAME", bias=bias, act=act, rng=rng)
    plan = engine.compile_model(f"mix_{kind.value}_{act}_{bias}", [ld])
    (lp,) = plan.layers
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 6)), jnp.float32)
    got = engine.forward_layer(plan, lp, x, interpret=True)
    want = engine.forward_layer_im2col(plan, lp, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_implicit_dense_mode1_conv_matches_oracle():
    """A conv with S > X_TPU routes to the dense implicit kernel and still
    matches the oracle bitwise."""
    rng = np.random.default_rng(23)
    ld = _layer_def(ConvKind.PC, 1, 1, "SAME", bias=True, act="relu",
                    rng=rng, d=48, f=12)
    plan = engine.compile_model("imp_dense_pc", [ld])
    (lp,) = plan.layers
    assert lp.mode == engine.MODE_DENSE
    assert engine.layer_route(lp) == ex.ROUTE_CONV_M1
    x = jnp.asarray(rng.normal(size=(2, 5, 5, 48)), jnp.float32)
    got = engine.forward_layer(plan, lp, x, interpret=True)
    want = engine.forward_layer_im2col(plan, lp, x, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_layer_route_census():
    """Every serving-zoo layer routes off the im2col path: conv layers to
    the implicit kernels, DC to the windowed VPU path, FC to the GEMM."""
    for name in zoo.SERVING_MODELS:
        plan = engine.compile_model(
            f"route_{name}", zoo.serving_defs(name, 0))
        routes = [engine.layer_route(lp) for lp in plan.layers]
        assert routes[-1] == ex.ROUTE_FC_GEMM
        assert set(routes[:-1]) <= {ex.ROUTE_CONV_M1, ex.ROUTE_CONV_ZS,
                                    ex.ROUTE_DEPTHWISE}
        assert any(r in (ex.ROUTE_CONV_M1, ex.ROUTE_CONV_ZS)
                   for r in routes)


def test_whole_model_implicit_matches_im2col():
    """Whole serving-zoo models, batched: implicit == im2col, bitwise."""
    rng = np.random.default_rng(5)
    for name in zoo.SERVING_MODELS:
        plan = engine.compile_model(
            f"wm_{name}", zoo.serving_defs(name, 0))
        x = jnp.asarray(rng.normal(size=(2, 16, 16, 3)), jnp.float32)
        got = engine.forward(plan, x, interpret=True)
        want = engine.forward_im2col(plan, x, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Whole-model jitted pipeline
# ---------------------------------------------------------------------------

def test_batch_bucket():
    assert [engine.batch_bucket(b) for b in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 16]


def test_forward_jit_bitwise_ragged_batches():
    """Bucket-padded jitted pipeline == the eager layer loop, bitwise, for
    ragged batch sizes (pad images never leak into real outputs)."""
    engine.pipeline_cache_clear()
    rng = np.random.default_rng(9)
    plan = engine.compile_model(
        "jit_ragged", zoo.serving_defs("xception_mini", 0))
    for b in (1, 2, 3, 5):
        x = jnp.asarray(rng.normal(size=(b, 16, 16, 3)), jnp.float32)
        got = engine.forward_jit(plan, x, interpret=True)
        want = engine.forward(plan, x, interpret=True)
        assert got.shape[0] == b
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_forward_jit_compiles_once_per_plan_bucket():
    """The compile-stall contract: one trace per (plan, batch bucket);
    every later batch in the bucket reuses the executable."""
    engine.pipeline_cache_clear()
    rng = np.random.default_rng(2)
    plan = engine.compile_model(
        "jit_cache", zoo.serving_defs("shufflenet_mini", 0))

    def compiles():
        return engine.pipeline_cache_info()["compiles"]

    x3 = jnp.asarray(rng.normal(size=(3, 16, 16, 3)), jnp.float32)
    engine.forward_jit(plan, x3, interpret=True)        # bucket 4: compile
    assert compiles() == 1
    x4 = jnp.asarray(rng.normal(size=(4, 16, 16, 3)), jnp.float32)
    engine.forward_jit(plan, x4, interpret=True)        # same bucket: hit
    assert compiles() == 1
    x2 = jnp.asarray(rng.normal(size=(2, 16, 16, 3)), jnp.float32)
    engine.forward_jit(plan, x2, interpret=True)        # bucket 2: compile
    assert compiles() == 2
    engine.forward_jit(plan, x3, interpret=True)        # bucket 4 again: hit
    assert compiles() == 2
    # a distinct plan compiles its own pipeline
    other = engine.compile_model(
        "jit_cache_other", zoo.serving_defs("shufflenet_mini", 1))
    engine.forward_jit(plan, x4, interpret=True)
    engine.forward_jit(other, x4, interpret=True)
    assert compiles() == 3


def test_forward_jit_rejects_single_image():
    plan = engine.compile_model(
        "jit_shape", zoo.serving_defs("shufflenet_mini", 2))
    with pytest.raises(ValueError, match="batches"):
        engine.forward_jit(plan, jnp.zeros((16, 16, 3), jnp.float32),
                           interpret=True)


def test_pipeline_cache_bounded_lru(monkeypatch):
    """Beyond CACHE_CAPACITY plans, the least-recently-used pipeline (and
    its strong plan reference) is dropped — unregistered callers cannot
    pin every imprint they ever served."""
    from repro.engine import pipeline
    engine.pipeline_cache_clear()
    monkeypatch.setattr(pipeline, "CACHE_CAPACITY", 2)
    plans = [engine.compile_model(f"lru_{i}",
                                  zoo.serving_defs("shufflenet_mini", 10 + i))
             for i in range(3)]
    x = jnp.zeros((1, 16, 16, 3), jnp.float32)
    engine.forward_jit(plans[0], x, interpret=True)
    engine.forward_jit(plans[1], x, interpret=True)
    engine.forward_jit(plans[0], x, interpret=True)   # refresh plan 0
    engine.forward_jit(plans[2], x, interpret=True)   # evicts plan 1 (LRU)
    info = engine.pipeline_cache_info()
    assert info["size"] == 2 and info["evictions"] == 1
    assert id(plans[1]) not in pipeline._PIPELINES
    assert id(plans[0]) in pipeline._PIPELINES
    engine.pipeline_cache_clear()


def test_pipeline_evict_drops_plan_entry():
    engine.pipeline_cache_clear()
    plan = engine.compile_model(
        "jit_evict", zoo.serving_defs("shufflenet_mini", 3))
    x = jnp.zeros((1, 16, 16, 3), jnp.float32)
    engine.forward_jit(plan, x, interpret=True)
    assert engine.pipeline_cache_info()["size"] == 1
    engine.pipeline_evict(plan)
    assert engine.pipeline_cache_info()["size"] == 0


def test_server_counts_pipeline_compile_stalls():
    """A served model pays one pipeline compile per batch bucket; warmed
    buckets pay zero (registry.warm_pipelines)."""
    from repro import serve
    engine.pipeline_cache_clear()
    rng = np.random.default_rng(4)
    reg = serve.paper_cnn_registry(capacity=3)
    srv = serve.CNNServer(reg, max_batch=2, max_wait_s=0.0)
    model = "shufflenet_mini"

    def _submit(n):
        for _ in range(n):
            srv.submit(model, rng.normal(size=(16, 16, 3)))

    _submit(2)
    srv.run_until_drained()
    assert srv.pipeline_compiles == 1          # bucket 2, cold
    _submit(2)
    srv.run_until_drained()
    assert srv.pipeline_compiles == 1          # bucket 2 again, warm
    _submit(1)
    srv.run_until_drained()
    assert srv.pipeline_compiles == 2          # bucket 1, cold

    # pre-warming removes the stalls entirely for a fresh registry
    engine.pipeline_cache_clear()
    reg2 = serve.paper_cnn_registry(capacity=3)
    srv2 = serve.CNNServer(reg2, max_batch=2, max_wait_s=0.0)
    assert reg2.warm_pipelines(model, max_batch=2) == [1, 2]
    for n in (2, 1):
        for _ in range(n):
            srv2.submit(model, rng.normal(size=(16, 16, 3)))
        srv2.run_until_drained()
    assert srv2.pipeline_compiles == 0


def test_forward_jit_fc_row_batches():
    """FC-first plans serve (B, S) row batches through the pipeline too."""
    engine.pipeline_cache_clear()
    rng = np.random.default_rng(8)
    w = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    plan = engine.compile_model(
        "jit_fc", [engine.LayerDef("fc", ConvKind.FC, w)])
    xb = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
    got = engine.forward_jit(plan, xb, interpret=True)
    want = engine.forward(plan, xb, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
