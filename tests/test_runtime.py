"""Runtime substrate tests: optimizer, compression, checkpoint, FT, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.optim.compression import compress_gradients, compression_init
from repro.optim.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, dequantize_moment,
                                   make_schedule, quantize_moment)
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerDetector,
                                           plan_elastic_remesh,
                                           run_with_restarts)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (64, 32)),
            "b": jnp.zeros((32,))}


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(cfg, g, state, params,
                                     jnp.float32(0.05))
    assert float(loss(params)) < 1e-3


def test_quantized_adamw_tracks_float():
    """int8-moment AdamW stays close to the f32 version."""
    p0 = _params()
    cfg_f = AdamWConfig(weight_decay=0.0)
    cfg_q = AdamWConfig(weight_decay=0.0, quantized=True)
    sf, sq = adamw_init(p0), adamw_init(p0, quantized=True)
    pf = pq = p0
    loss = lambda p: jnp.sum((p["w"] @ jnp.ones((32,)) - 1.0) ** 2)  # noqa
    for _ in range(30):
        gf = jax.grad(loss)(pf)
        gq = jax.grad(loss)(pq)
        pf, sf = adamw_update(cfg_f, gf, sf, pf, jnp.float32(1e-3))
        pq, sq = adamw_update(cfg_q, gq, sq, pq, jnp.float32(1e-3))
    rel = (np.abs(np.asarray(pf["w"]) - np.asarray(pq["w"])).max()
           / np.abs(np.asarray(pf["w"])).max())
    assert rel < 0.05


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 500),
       lead=st.integers(1, 3))
def test_moment_quantization_roundtrip(seed, n, lead):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(lead, n)) * rng.uniform(0.01, 100))
    q, s = quantize_moment(x)
    assert q.shape[:-1] == x.shape[:-1]           # param-shaped int8 store
    assert q.shape[-1] % 128 == 0
    back = dequantize_moment(q, s, x.shape)
    assert back.shape == x.shape
    err = np.abs(np.asarray(back) - np.asarray(x))
    scale_per_elem = np.repeat(np.asarray(s), 128, axis=-1)[..., :n]
    assert (err <= scale_per_elem / 2 + 1e-9).all()


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = make_schedule("cosine", 1.0, warmup=10, total=100)
    wsd = make_schedule("wsd", 1.0, warmup=10, total=100)
    assert float(cos(jnp.float32(0))) == 0.0
    assert float(cos(jnp.float32(10))) == pytest.approx(1.0)
    assert float(cos(jnp.float32(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(wsd(jnp.float32(50))) == pytest.approx(1.0)   # stable
    assert float(wsd(jnp.float32(100))) == pytest.approx(0.0, abs=1e-6)


def test_gradient_compression_error_feedback():
    """EF residual makes the compressed stream unbiased over steps."""
    params = {"w": jnp.ones((256,))}
    state = compression_init(params)
    true_g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=256)
                               * 1e-3)}
    acc = jnp.zeros((256,))
    for _ in range(50):
        cg, state = compress_gradients(true_g, state)
        acc = acc + cg["w"]
    avg = np.asarray(acc) / 50
    np.testing.assert_allclose(avg, np.asarray(true_g["w"]),
                               atol=5e-5)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, tree, process_index=0)
        assert latest_step(d) == 5
        back = restore_checkpoint(d, 5, tree, process_index=0)
        np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))


def test_checkpoint_manager_auto_resume_and_gc():
    tree = {"w": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, save_every=1)
        for step in range(1, 6):
            mgr.maybe_save(step, {"w": jnp.full((4,), float(step))},
                           blocking=True)
        step, restored = mgr.resume(tree)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.full((4,), 5.0))
        kept = [n for n in os.listdir(d) if n.startswith("step_")]
        assert len(kept) == 2


def test_checkpoint_atomicity_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        # a stale tmp dir must be invisible to latest_step
        os.makedirs(os.path.join(d, "step_00000009.tmp_dead"))
        save_checkpoint(d, 3, {"w": jnp.zeros(2)}, process_index=0)
        assert latest_step(d) == 3


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_monitor():
    # single injectable clock: beats and deadness checks read time_fn —
    # there is no caller-supplied `now` mixed with a hidden wall clock
    clock = {"t": 100.0}
    hb = HeartbeatMonitor(timeout_s=10.0, time_fn=lambda: clock["t"])
    hb.beat(0)
    clock["t"] = 105.0
    hb.beat(1)
    clock["t"] = 108.0
    assert hb.dead_hosts() == []
    clock["t"] = 112.0
    assert hb.dead_hosts() == [0]
    hb.beat(0)                         # a fresh beat clears suspicion
    assert hb.dead_hosts() == []


def test_straggler_detector():
    sd = StragglerDetector(threshold=2.0)
    for _ in range(10):
        for host in range(4):
            sd.record(host, 1.0 if host != 2 else 3.5)
    assert sd.stragglers() == [2]


def test_elastic_remesh_shrinks_data_axis_only():
    plan = plan_elastic_remesh(("pod", "data", "model"), (2, 16, 16),
                               healthy_chips=480)
    assert plan.new_shape == (2, 8, 16)      # largest pow2 data that fits
    assert plan.global_batch_scale == 0.5
    plan2 = plan_elastic_remesh(("data", "model"), (16, 16),
                                healthy_chips=255)
    assert plan2.new_shape == (8, 16)


def test_run_with_restarts_recovers():
    calls = {"n": 0, "restores": 0}

    def step(i):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("chip lost")

    def restore():
        calls["restores"] += 1
        return 0

    last = run_with_restarts(step, 0, 5, restore, max_restarts=2)
    assert last == 5
    assert calls["restores"] == 1


def test_run_with_restarts_backoff_doubles_to_cap():
    sleeps = []
    calls = {"n": 0}

    def step(i):
        calls["n"] += 1
        if calls["n"] <= 4:
            raise RuntimeError(f"crash {calls['n']}")

    last = run_with_restarts(step, 0, 2, lambda: 0, max_restarts=4,
                             backoff_base_s=0.1, backoff_cap_s=0.25,
                             sleep_fn=sleeps.append)
    assert last == 2
    # exponential from the base, saturating at the cap; one sleep per
    # restart, taken BEFORE hitting the checkpoint store again
    assert sleeps == [0.1, 0.2, 0.25, 0.25]


def test_run_with_restarts_exhaustion_chains_failure_history():
    calls = {"n": 0}

    def step(i):
        calls["n"] += 1
        raise RuntimeError(f"crash {calls['n']}")

    with pytest.raises(RuntimeError, match="crash 3") as ei:
        run_with_restarts(step, 0, 5, lambda: 0, max_restarts=2,
                          sleep_fn=lambda s: None)
    # the terminal exception chains the previous attempt explicitly
    # (`raise exc from last_exc`) — the post-mortem sees the sequence
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert "crash 2" in str(ei.value.__cause__)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_determinism_and_restart():
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, load_all
    from repro.data.pipeline import SyntheticTokenPipeline
    from repro.launch.mesh import make_host_mesh
    load_all()
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = make_host_mesh()
    pipe = SyntheticTokenPipeline(cfg=cfg, mesh=mesh, batch_spec=P(None),
                                  global_batch=4, seq_len=16, seed=1)
    b1 = pipe.batch_at(3)
    b2 = pipe.batch_at(3)       # replay after "restart"
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.batch_at(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert np.asarray(b1["tokens"]).max() < cfg.vocab
