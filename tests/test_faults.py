"""Fault-injection tests: deterministic schedules, typed faults, and the
dispatcher's retry/quarantine/readmission loop staying bitwise-identical
to the healthy single-accelerator path under injected chaos."""
import time

import jax
import numpy as np
import pytest

from repro import engine, serve
from repro.serve import models as zoo
from repro.serve.faults import FAILING_KINDS

jax.config.update("jax_platform_name", "cpu")

MODEL = "shufflenet_mini"


@pytest.fixture(autouse=True)
def _fresh_caches():
    engine.plan_cache_clear()
    yield
    engine.plan_cache_clear()


def _plan(key):
    return engine.compile_model(f"{MODEL}#{key}", zoo.serving_defs(MODEL))


def _batch(b, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(b, *zoo.serving_input_shape(MODEL))).astype(
        np.float32)


# ---------------------------------------------------------------------------
# fault schedule semantics
# ---------------------------------------------------------------------------

def test_fault_event_windows():
    finite = serve.FaultEvent("a", serve.FaultKind.CRASH, start=2,
                              duration=3)
    assert [finite.active_at(n) for n in range(7)] == \
        [False, False, True, True, True, False, False]
    forever = serve.FaultEvent("a", serve.FaultKind.CRASH, start=1)
    assert not forever.active_at(0)
    assert all(forever.active_at(n) for n in (1, 5, 1000))


def test_injector_deterministic_by_dispatch_count():
    """Replay is keyed on per-instance dispatch counts, never wall time."""
    schedule = [
        serve.FaultEvent("a", serve.FaultKind.STRAGGLE, start=1,
                         duration=2, severity=0.25),
        serve.FaultEvent("a", serve.FaultKind.CRASH, start=4),
        serve.FaultEvent("b", serve.FaultKind.THERMAL_DRIFT, start=0,
                         duration=1, severity=0.125),
    ]
    trace = []
    for _ in range(2):
        inj = serve.FaultInjector(schedule)
        run = [(inst, e.delay_s, e.fault)
               for inst in ("a", "a", "b", "a", "b", "a", "a")
               for e in [inj.on_dispatch(inst)]]
        trace.append(run)
    assert trace[0] == trace[1]
    # a: n=0 clean, n=1..2 straggle 0.25s, n=3 clean, n=4+ crash
    assert trace[0][0] == ("a", 0.0, None)
    assert trace[0][1] == ("a", 0.25, None)          # a's n=1
    assert trace[0][3] == ("a", 0.25, None)          # a's n=2
    assert trace[0][5] == ("a", 0.0, None)           # a's n=3
    assert trace[0][6] == ("a", 0.0, serve.FaultKind.CRASH)   # a's n=4
    # b: n=0 drifts, n=1 clean
    assert trace[0][2] == ("b", 0.125, None)
    assert trace[0][4] == ("b", 0.0, None)


def test_random_schedule_is_seeded():
    names = ("acc0", "acc1", "acc2")
    a = serve.random_schedule(7, names, n_events=6)
    b = serve.random_schedule(7, names, n_events=6)
    assert a == b
    assert len(a) == 6
    assert {e.instance for e in a} <= set(names)
    assert all(isinstance(e.kind, serve.FaultKind) for e in a)
    c = serve.random_schedule(8, names, n_events=6)
    assert c != a


def test_typed_faults_and_raise_for():
    inj = serve.FaultInjector([])
    with pytest.raises(serve.InstanceCrashed):
        inj.raise_for(serve.FaultKind.CRASH, "a")
    with pytest.raises(serve.ReconfigStuck):
        inj.raise_for(serve.FaultKind.STUCK_RECONFIG, "a")
    for kind in FAILING_KINDS:
        with pytest.raises(serve.ServingFault):
            inj.raise_for(kind, "a")
    assert issubclass(serve.AdmissionRejected, serve.ServingFault)
    assert issubclass(serve.ShardDeadlineExceeded, serve.ServingFault)


def test_overlapping_delays_accumulate_and_failing_fault_wins():
    inj = serve.FaultInjector([
        serve.FaultEvent("a", serve.FaultKind.STRAGGLE, start=0,
                         duration=2, severity=0.2),
        serve.FaultEvent("a", serve.FaultKind.THERMAL_DRIFT, start=0,
                         duration=1, severity=0.05),
        serve.FaultEvent("a", serve.FaultKind.CRASH, start=1, duration=1),
    ])
    e0 = inj.on_dispatch("a")
    assert e0.delay_s == pytest.approx(0.25) and e0.fault is None
    e1 = inj.on_dispatch("a")              # straggle + crash overlap
    assert e1.delay_s == pytest.approx(0.2)
    assert e1.fault is serve.FaultKind.CRASH
    e2 = inj.on_dispatch("a")              # everything expired
    assert e2.delay_s == 0.0 and e2.fault is None


# ---------------------------------------------------------------------------
# chaos dispatch: bitwise identity + health loop
# ---------------------------------------------------------------------------

def test_crash_retry_is_bitwise_and_counts():
    plan = _plan("crash")
    xb = _batch(5, seed=1)
    single = np.asarray(engine.forward_jit(plan, xb))
    inj = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.CRASH, start=0)])
    d = serve.ShardedDispatcher(serve.default_fleet(3), fault_injector=inj,
                                probe_cooldown_s=60.0)
    out, runs = d.run(plan, xb)
    d.close()
    np.testing.assert_array_equal(np.asarray(out), single)
    assert sum(r.batch_size for r in runs) == 5
    assert any(r.attempt > 0 for r in runs)          # retried frames ran
    assert d.counters["faults"] == 1
    assert d.counters["retries"] == 1
    assert d.counters["quarantines"] == 1
    assert d.health["acc1"].state == "quarantined"
    assert d.health["acc1"].frames == 0              # never served a frame


def test_all_instances_lost_raises_no_healthy_with_cause():
    plan = _plan("lost")
    inj = serve.FaultInjector([
        serve.FaultEvent(f"acc{i}", serve.FaultKind.CRASH, start=0)
        for i in range(2)])
    d = serve.ShardedDispatcher(serve.default_fleet(2), fault_injector=inj,
                                probe_cooldown_s=60.0)
    with pytest.raises(serve.NoHealthyInstances) as ei:
        d.run(plan, _batch(4))
    d.close()
    assert isinstance(ei.value.__cause__, serve.InstanceCrashed)


def test_persistent_deadline_misses_exhaust_retries():
    """A fleet that keeps missing its deadline fails typed, with the
    last shard failure chained as the cause."""
    plan = _plan("exhaust")
    engine.forward_jit(plan, _batch(2))              # pay compile up front
    inj = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.STRAGGLE, start=0,
                         severity=0.2)])             # forever
    d = serve.ShardedDispatcher(
        serve.default_fleet(1), fault_injector=inj, deadline_s=0.03,
        max_retries=2, backoff_base_s=0.001, probe_cooldown_s=0.0)
    with pytest.raises(serve.RetriesExhausted) as ei:
        d.run(plan, _batch(2))
    d.close()
    assert isinstance(ei.value.__cause__, serve.ShardDeadlineExceeded)
    assert d.counters["timeouts"] >= 3               # initial + 2 retries
    # probes passed (straggle is a delay, not a refusal) so the instance
    # kept being readmitted — and kept missing the deadline
    assert d.counters["readmissions"] >= 2


def test_finite_fault_expires_through_probes_and_readmits():
    plan = _plan("readmit")
    xb = _batch(4, seed=2)
    single = np.asarray(engine.forward_jit(plan, xb))
    inj = serve.FaultInjector([
        serve.FaultEvent("acc0", serve.FaultKind.STUCK_RECONFIG, start=0,
                         duration=2)])
    d = serve.ShardedDispatcher(serve.default_fleet(2), fault_injector=inj,
                                probe_cooldown_s=0.005)
    out, _ = d.run(plan, xb)                         # acc0 faults, acc1 serves
    np.testing.assert_array_equal(np.asarray(out), single)
    assert d.health["acc0"].state == "quarantined"
    deadline = time.monotonic() + 5.0
    while (len(d.active_instances()) < 2 and time.monotonic() < deadline):
        time.sleep(0.005)
    assert d.health["acc0"].state == "healthy"
    assert d.counters["readmissions"] == 1
    assert d.counters["probe_failures"] >= 1         # n=1 probe still stuck
    out2, runs2 = d.run(plan, xb)                    # both instances serve
    d.close()
    np.testing.assert_array_equal(np.asarray(out2), single)
    assert {r.instance.name for r in runs2} == {"acc0", "acc1"}
    assert d.health["acc0"].frames > 0


def test_fleet_health_export_shape():
    inj = serve.FaultInjector([
        serve.FaultEvent("acc1", serve.FaultKind.CRASH, start=0)])
    d = serve.ShardedDispatcher(serve.default_fleet(2), fault_injector=inj,
                                probe_cooldown_s=60.0)
    plan = _plan("health")
    d.run(plan, _batch(3))
    d.close()
    h = d.fleet_health()
    assert set(h) == {"instances", "counters", "healthy_fraction",
                      "suspect_dead", "power_cap_w", "peak_power_w",
                      "admitted_power_w"}
    assert h["healthy_fraction"] == pytest.approx(0.5)
    assert h["power_cap_w"] is None                  # uncapped fleet
    assert h["peak_power_w"] == pytest.approx(
        sum(i["power_w"] for i in h["instances"].values()))
    assert h["instances"]["acc1"]["state"] == "quarantined"
    assert h["instances"]["acc0"]["state"] == "healthy"
    assert h["instances"]["acc0"]["frames"] == 3
    assert h["counters"]["completed_shards"] >= 2
    assert h["instances"]["acc0"]["last_beat_age_s"] is not None
