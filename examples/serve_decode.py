"""Batched decode serving with continuous batching (CPU, reduced config).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch qwen1.5-0.5b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs import load_all
from repro.launch.serve import BatchedServer


def main() -> None:
    load_all()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-tokens", type=int, default=8)
    args = ap.parse_args()
    srv = BatchedServer(args.arch, batch=4, ctx=128)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for _ in range(args.requests):
        srv.submit(list(map(int, rng.integers(1, 100, 4))),
                   args.max_tokens)
    outs = srv.run_until_done()
    dt = time.monotonic() - t0
    total = sum(len(v) for v in outs.values())
    print(f"served {len(outs)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s greedy, reduced config)")
    for rid, toks in sorted(outs.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
