"""Batched photonic CNN serving: registry + dynamic batcher + telemetry.

Submits a mixed stream of requests for the three paper-CNN serving
stand-ins, lets the dynamic batcher fold them into weight-stationary
batches, and prints the two-sided telemetry: wall-clock serving metrics
on this host and modeled photonic FPS / FPS-per-W per accelerator
operating point from the cycle-true simulator.

Run:  PYTHONPATH=src python examples/serve_cnn.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro import serve

registry = serve.paper_cnn_registry(capacity=2)     # < 3 models -> LRU evicts
server = serve.CNNServer(registry, max_batch=4, max_wait_s=0.005)

rng = np.random.default_rng(0)
print("== submitting a mixed-model request stream ==")
rids = {}
for i in range(12):
    model = list(serve.SERVING_MODELS)[i % 3]
    x = rng.normal(size=serve.serving_input_shape(model)).astype(np.float32)
    rids[server.submit(model, x)] = model

outputs = server.run_until_drained()
assert sorted(outputs) == sorted(rids)

s = server.telemetry.summary()
print(f"  served {s['requests']} requests in {s['batches']} batches "
      f"(mean batch {s['mean_batch_size']:.1f})")
print(f"  wall: {s['images_per_s_wall']:.1f} img/s, "
      f"p50 {s['latency_p50_s'] * 1e3:.0f} ms, "
      f"p99 {s['latency_p99_s'] * 1e3:.0f} ms")
print(f"  registry: {registry.stats()}")

print("\n== modeled photonic hardware time (paper-scale tables) ==")
for label, hw in s["hardware"].items():
    print(f"  {label:8s} {hw['modeled_fps']:10.1f} FPS  "
          f"{hw['modeled_fps_per_watt']:8.2f} FPS/W")
for model, m in s["models"].items():
    rmam = m["hardware"]["RMAM@1G"]
    print(f"  {model:18s} RMAM@1G {rmam['modeled_fps']:10.1f} FPS "
          f"(batch-amortized over {m['mean_batch_size']:.1f} frames)")
