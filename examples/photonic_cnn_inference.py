"""Photonic CNN inference: run a small depthwise-separable CNN through the
decomposed-VDP numerics AND the cycle-true accelerator model.

Functional path: 4-bit quantize -> im2col DIVs -> sliced VDPs on the RMAM
TPC -> psum reduction (bit-exact vs direct quantized conv); then the same
network through the weight-stationary engine (repro.engine): weights are
quantized + packed ONCE into a cached plan — the paper's one-time DKV
imprint — and forward runs the Pallas kernels with the dequant/ReLU
epilogue fused, producing bit-identical outputs.  Finally the performance
path: the same layers scheduled on the area-proportionate accelerators.

Run:  PYTHONPATH=src python examples/photonic_cnn_inference.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.cnn.layers import ConvKind
from repro.cnn.layers import dc as dc_spec, pc as pc_spec, sc as sc_spec
from repro.core import simulator as sim
from repro.core import tpc, vdp
from repro.core.mapping import TPCConfig

rng = np.random.default_rng(0)
RMAM_TPC = TPCConfig("MAM", 43, 43, True)

# A MobileNet-style micro CNN: SC stem + two DSC blocks (DC + PC).
x = jnp.asarray(rng.normal(size=(16, 16, 3)), jnp.float32)

print("== functional inference through decomposed VDPs ==")
stem = jnp.asarray(rng.normal(size=(8, 3, 3, 3)), jnp.float32)
out, ref = vdp.conv2d_vdp(x, stem, RMAM_TPC)
assert jnp.array_equal(out, ref)
h = jax.nn.relu(out)
print(f"  stem SC   3x3x3 x8   -> {h.shape}, bit-exact: True")

dw = jnp.asarray(rng.normal(size=(8, 3, 3)), jnp.float32)
out, ref = vdp.depthwise_conv2d_vdp(h, dw, RMAM_TPC)
assert jnp.array_equal(out, ref)
h = jax.nn.relu(out)
print(f"  DC        3x3 per-ch -> {h.shape}, bit-exact: True")

pw = jnp.asarray(rng.normal(size=(16, 1, 1, 8)), jnp.float32)
out, ref = vdp.conv2d_vdp(h, pw, RMAM_TPC)
assert jnp.array_equal(out, ref)
h = jax.nn.relu(out)
print(f"  PC        1x1x8 x16  -> {h.shape}, bit-exact: True")

print("\n== weight-stationary engine: pack once, fused epilogue ==")
layer_defs = [
    engine.LayerDef("stem", ConvKind.SC, stem, act="relu"),
    engine.LayerDef("dc1", ConvKind.DC, dw, act="relu"),
    engine.LayerDef("pc1", ConvKind.PC, pw, act="relu"),
]
plan = engine.get_plan("micro_cnn", layer_defs)
out_engine = engine.forward(plan, x)
assert jnp.array_equal(out_engine, h), "engine != eager VDP path"
census = {"mode1": plan.mode_census.get(engine.MODE_DENSE, 0),
          "mode2": plan.mode_census.get(engine.MODE_PACKED, 0),
          "depthwise": plan.mode_census.get(engine.MODE_DEPTHWISE, 0)}
print(f"  plan: {census}, bit-exact vs eager path: True")
assert engine.get_plan("micro_cnn", layer_defs) is plan  # imprinted once
print(f"  plan cache: {engine.plan_cache_info()}")

print("\n== analog-noise ablation (Eq. 9/10 PD noise at the SEs) ==")
divs = vdp.im2col(x, 3, 1, "SAME")
dkvs = vdp.dkv_matrix(stem)
divs_q, sa = vdp.quantize_symmetric(divs)
dkvs_q, sb = vdp.quantize_symmetric(dkvs)
clean = vdp.sliced_vdp_gemm(divs_q, dkvs_q, RMAM_TPC)
for br in (1e9, 5e9):
    noisy = vdp.noisy_vdp_gemm(jax.random.PRNGKey(0), divs_q, dkvs_q,
                               RMAM_TPC, br_hz=br)
    err = float(jnp.mean(jnp.abs(noisy - clean)))
    print(f"  BR={br / 1e9:g} Gbps: mean |error| = {err:.3f} LSB")

print("\n== cycle-true performance of the same network ==")
layers = [
    sc_spec("stem", 3, 3, 8, 16, 16),
    dc_spec("dc1", 3, 8, 16, 16),
    pc_spec("pc1", 8, 16, 16, 16),
]
for name in ("RMAM", "MAM", "AMM"):
    acc = tpc.build_accelerator(name, 1.0)
    rep = sim.simulate(acc, layers)
    print(f"  {name:5s} {rep.fps:12.0f} FPS  "
          f"util {100 * rep.mean_utilization:5.1f}%")
