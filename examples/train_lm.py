"""End-to-end training driver: ~100M-param LM for a few hundred steps (CPU).

Exercises the full production path on one host: config -> model -> sharded
data pipeline -> AdamW(+WSD) -> checkpoint/auto-resume -> loss curve.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

from repro.configs import load_all
from repro.configs.base import ModelConfig, register
from repro.launch.train import train_loop

#: ~110M parameters: 12L x d768 x ff2048, 32k vocab (tied embeddings).
LM_100M = ModelConfig(
    arch_id="lm-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=32000,
    param_dtype="float32",
)


def main() -> None:
    load_all()
    register(LM_100M)
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    args = ap.parse_args()
    print(f"params: {LM_100M.n_params() / 1e6:.1f}M")
    out = train_loop("lm-100m", steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=args.ckpt_dir,
                     save_every=50, reduced=False)
    print(f"loss: {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"over {out['steps']} steps")
    assert out["final_loss"] < out["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
