"""Quickstart: the paper's pipeline end-to-end in one minute (CPU).

1. Scalability analysis  -> the VDPE sizes of Table II
2. Map mixed-size DKVs   -> Cases 1/2/3, utilization (Fig. 6)
3. Cycle-true simulation -> FPS / FPS/W of RMAM vs baselines (Figs. 10-11)
4. Numerics              -> a conv executed through the decomposed VDP path
5. TPU kernels           -> Mode-2 block-diagonal packing on the MXU model

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scalability as sc
from repro.core import simulator as sim
from repro.core import tpc, vdp
from repro.core.mapping import TPCConfig, map_layer, vdpe_utilization_for_s
from repro.cnn.models import MODEL_ZOO
from repro.cnn.layers import pc
from repro.kernels import ops

print("== 1. Scalability (paper Table II) ==")
for arch, rows in sc.table2().items():
    print(f"  {arch:5s} N @ 4-bit:", rows)

print("\n== 2. Mapping a mixed-size layer (paper Sec. V-B) ==")
rmam = TPCConfig("MAM", 43, 43, True)
for s in (9, 25, 96, 3840):
    layer = pc(f"S{s}", s, 64, 14, 14)
    m = map_layer(rmam, layer)
    modes = sorted({g.mode for g in m.groups})
    print(f"  S={s:5d}: case {m.case}, modes {modes}, "
          f"utilization {100 * m.utilization:.1f}% "
          f"(fixed-N MAM: {100 * vdpe_utilization_for_s(TPCConfig('MAM', 44, 44, False), s):.1f}%)")

print("\n== 3. Cycle-true FPS (paper Figs. 10-11, ShuffleNetV2) ==")
layers = MODEL_ZOO["shufflenet_v2"]()
for name in tpc.ACCELERATORS:
    acc = tpc.build_accelerator(name, 1.0)
    rep = sim.simulate(acc, layers)
    print(f"  {name:10s} {rep.fps:10.1f} FPS   {rep.fps_per_watt:8.2f} FPS/W"
          f"   util {100 * rep.mean_utilization:.1f}%")

print("\n== 4. Conv through the decomposed-VDP path (bit-exact) ==")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 8, 16)), jnp.float32)
k = jnp.asarray(rng.normal(size=(12, 3, 3, 16)), jnp.float32)
out_vdp, out_ref = vdp.conv2d_vdp(x, k, rmam)
print(f"  sliced-VDP == direct quantized GEMM: "
      f"{bool(jnp.array_equal(out_vdp, out_ref))}")

print("\n== 5. Mode-2 Pallas kernel (TPU MXU analogue) ==")
divs = jnp.asarray(rng.integers(-7, 8, (64, 9)), jnp.int8)
dkvs = jnp.asarray(rng.integers(-7, 8, (32, 9)), jnp.int8)
got = ops.mixed_size_gemm(divs, dkvs)
want = vdp.direct_quantized_gemm(divs, dkvs)
print(f"  packed kernel == oracle: {bool(jnp.array_equal(got, want))} "
      f"(y={ops.N_TPU // ops.X_TPU} small DKVs per 128-lane MXU pass)")
