"""Bench-regression gate: fresh smoke benches vs the committed baselines.

Snapshots the committed ``BENCH_serve.json`` / ``BENCH_kernels.json`` /
``BENCH_fps.json``, re-runs the benches that write them —
``benchmarks.serve_bench --smoke``, ``benchmarks.chaos_bench --smoke``,
``benchmarks.sdc_bench --smoke``, ``benchmarks.obs_bench --smoke``,
``benchmarks.overload_bench --smoke`` (all
five merge-write BENCH_serve.json) plus the full ``kernel_bench`` and
``noise_ablation`` (both merge-write BENCH_kernels.json; the smoke
variant of kernel_bench is assertion-only and writes no JSON) and the
``fig10_11_fps`` calibration sweep (writes BENCH_fps.json; budget ~2 min
per round, and a first-round regression triggers a second confirming
round — CI gives the job a 20-minute timeout) — and fails when
a gated throughput family regresses by more than ``--threshold`` (default
30%), or when a metric with an absolute floor (``ABS_FLOORS`` — e.g. the
tracing-overhead ratio ``obs.overhead.ratio`` >= 0.95) lands below it.

Tracked metrics are *same-run speedup ratios* (higher is better) plus
chaos invariants:

* serve: whole-model-jit vs layer-loop images/s at batch 1 and 8, and
  the batch-8-vs-batch-1 amortization ratio
* serve_fleet: device-paced fleet-K vs fleet-1 dispatch throughput and
  concurrent-vs-sequential fleet=2 dispatch (GATED — these defend the
  concurrency win that reversed the old fleet=2 regression)
* serve_fault: chaos-harness invariants (GATED) — bitwise-identical
  outputs under injected crash/straggle/stuck-reconfiguration faults,
  typed load shedding on a degraded fleet with zero sheds after
  recovery, and full fleet healing via quarantine probes; booleans are
  encoded 1.0/0.01 so one violation craters its family geomean
* kernels: zero-skipping vs block-diagonal Mode-2 GEMM per shape,
  implicit-GEMM vs im2col+GEMM per serving-zoo conv layer, and the
  quantized-domain int8 path vs the quantize-then-float oracle per
  serving-zoo layer (conv and FC)
* serve_overload: brownout-ladder invariants (GATED) — virtual-clock
  goodput at 1x/4x/10x offered load (deterministic ratios of modeled
  time, with the 10x point floor-gated at 0.8x capacity), interactive
  p99 inside its SLO while the batch class absorbs the damage, nonzero
  ladder downshifts under 10x, rung-by-rung recovery with zero
  post-recovery sheds, and bitwise-identical outputs across every rung
  (including the chaos+SDC overload composition)
* obs: tracing enabled-vs-disabled throughput ratio and per-layer
  hardware-time attribution coverage — gated against fixed ABS_FLOORS
  (the values are already same-run normalized ratios, so a fixed bar is
  meaningful where a baseline drift bound would let them erode)
* fps_w: the component-energy-ledger calibration (GATED) — per-
  accelerator FPS/W-gmean accuracy vs the paper's Figs. 10-11 values
  (min(modeled/paper, paper/modeled), a deterministic simulator output),
  EDP-objective dominance ratios (latency plan's EDP / EDP plan's EDP,
  >= 1 by construction), and the ledger-exactness residual, floor-gated
  at 1 - 1e-9

Absolute wall img/s swings several-fold with host load on shared CI
runners (and on a laptop), which would page people for nothing; each
speedup ratio divides two measurements taken back-to-back on the same
host in the same process, so load cancels and what remains is the actual
execution-path economics the benches exist to defend.

The gate fires on the *geomean* of each kernel metric family: individual
sub-ms interpret-mode timings still jitter past 30% run-to-run, but a
real regression — a kernel falling off its fast path, fusion or
zero-skipping breaking — drags its whole family, and the family geomean
over ~3-16 members averages the per-layer jitter away.  Individual metric
drops are printed as warnings (the nightly artifacts carry the trend).
A first-round family regression triggers one full re-run of the smoke
benches and only families regressed in BOTH rounds fail the gate.

The serve-side ratios (jit-vs-loop, batch amortization) are REPORTED but
do not gate: measured on identical code they swing 2-3x with the host's
dispatch-overhead profile (two back-to-back runs have shown 4x and 11x
for the same binary), so a 30% bar on them flags hosts, not code.  The
kernel families divide two kernels timed back-to-back in one process on
identical operands, which is the comparison that is actually stable.

Metrics present in only one side are reported but never fail the gate, so
schema evolution does not break CI.

Usage:
    python scripts/check_bench.py [--threshold 0.30] [--no-run]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import Dict, Iterator, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
BENCH_FILES = ("BENCH_serve.json", "BENCH_kernels.json", "BENCH_fps.json")
SMOKE_COMMANDS = (
    # order matters: serve_bench, chaos_bench and obs_bench all
    # merge-write BENCH_serve.json (each preserves the others' sections)
    [sys.executable, "-m", "benchmarks.serve_bench", "--smoke"],
    [sys.executable, "-m", "benchmarks.chaos_bench", "--smoke"],
    [sys.executable, "-m", "benchmarks.sdc_bench", "--smoke"],
    [sys.executable, "-m", "benchmarks.obs_bench", "--smoke"],
    [sys.executable, "-m", "benchmarks.overload_bench", "--smoke"],
    [sys.executable, "-m", "benchmarks.run", "--only", "kernel_bench"],
    [sys.executable, "-m", "benchmarks.noise_ablation"],
    # energy-ledger calibration sweep (writes BENCH_fps.json)
    [sys.executable, "-m", "benchmarks.run", "--only", "fig10_11_fps"],
)


#: families whose geomean gates the PR; everything else is report-only.
#: serve_fleet.* are same-run speedup ratios (paced fleet-K vs fleet-1,
#: concurrent vs sequential) — host load cancels out of them like the
#: kernel ratios.  serve_fault.* are pass/fail invariants from the chaos
#: harness (bitwise under faults, typed shedding, fleet healing) encoded
#: as 1.0/0.01 so any violation craters its family geomean.
GATED_FAMILY_PREFIXES = ("kernels.", "serve_fleet.", "serve_fault.",
                         "serve_sdc.", "serve_overload.", "fps_w.")

#: metrics gated by an absolute floor on the FRESH value instead of a
#: ratio against the baseline.  The overhead ratio and attribution
#: coverage are already normalized (enabled/disabled throughput on the
#: same host in the same process; fraction of modeled time attributed),
#: so the bar is a fixed number, not a drift bound: tracing disabled must
#: keep >= 95% of untraced throughput, and the per-layer attribution must
#: cover >= 95% of the modeled hardware time.
ABS_FLOORS = {
    "obs.overhead.ratio": 0.95,
    "obs.attribution.coverage": 0.95,
    # SDC defense (benchmarks/sdc_bench.py): >=99% of corrupted dispatches
    # flagged, recovered outputs bitwise-identical to the fault-free
    # trace, integrity checking keeps >=95% of batch-8 throughput
    "serve_sdc.detection.rate": 0.99,
    "serve_sdc.recovery.bitwise": 0.99,
    "serve_sdc.overhead.ratio": 0.95,
    # analog-noise ablation (benchmarks/noise_ablation.py): headroom of
    # the 4-bit/1-Gbps design point under its 1.5-LSB RMS noise budget
    # (floor_lsb / measured rms; 1.0 = exactly at budget)
    "kernels.analog_noise.headroom.b4_br1": 1.0,
    # overload harness (benchmarks/overload_bench.py): at 10x offered
    # load the brownout ladder must sustain >= 0.8x the measured nominal
    # capacity (goodput_vs_capacity is a ratio of modeled virtual-clock
    # times — deterministic, so the floor is meaningful)
    "serve_overload.goodput.r10x": 0.8,
    # component-energy ledger (benchmarks/fig10_11_fps.py §energy):
    # per-layer ledger rows must reproduce energy_per_frame_j; the metric
    # is 1 - max relative residual over the full sweep, so the floor IS
    # the 1e-9 exactness acceptance bar
    "fps_w.ledger.exactness": 1.0 - 1e-9,
}


def serve_metrics(doc: Dict) -> Iterator[Tuple[str, float]]:
    sweep = doc.get("batch_sweep", {})
    for bs, v in sorted(sweep.get("jit_speedup", {}).items()):
        yield f"serve.jit_speedup.b{bs}", float(v)
    if "batch8_speedup_wall" in sweep:
        yield "serve.amortization.batch8", float(sweep["batch8_speedup_wall"])
    # gated: device-paced fleet scaling (same-run ratio vs fleet=1) — the
    # number that proves concurrent dispatch turned the old fleet=2
    # regression into a speedup
    fleets = doc.get("dispatch", {}).get("fleets", {})
    for k, row in sorted(fleets.items(), key=lambda kv: int(kv[0])):
        v = row.get("paced_speedup")
        if v and int(k) > 1:
            yield f"serve_fleet.paced_speedup.k{k}", float(v)
    # gated: chaos-harness invariants (benchmarks/chaos_bench.py)
    scen = doc.get("fault_tolerance", {}).get("scenarios", {})
    for name, row in sorted(scen.items()):
        if "bitwise" in row:
            yield (f"serve_fault.bitwise.{name}",
                   1.0 if row["bitwise"] else 0.01)
    cvs = scen.get("concurrent_vs_sequential", {})
    if "concurrent_speedup" in cvs:
        yield ("serve_fleet.concurrent_speedup.k2",
               float(cvs["concurrent_speedup"]))
    rec = scen.get("full_fleet_recovery", {})
    if rec:
        yield ("serve_fault.shed_typed.full_fleet_recovery",
               1.0 if (rec.get("degraded_shed", 0) > 0
                       and rec.get("recovered_shed", 1) == 0) else 0.01)
    for name in ("straggler_storm", "full_fleet_recovery"):
        row = scen.get(name, {})
        if "healed_instances" in row:
            yield (f"serve_fault.healed.{name}",
                   1.0 if row["healed_instances"] == 3 else 0.01)
    # gated: SDC-defense invariants (benchmarks/sdc_bench.py) — booleans
    # as 1.0/0.01 like the chaos rows; rate/ratio also floor-gated
    sdc = doc.get("sdc", {}).get("scenarios", {})
    dr = sdc.get("detect_recover", {})
    if "detection_rate" in dr:
        yield "serve_sdc.detection.rate", float(dr["detection_rate"])
    if "bitwise" in dr:
        yield ("serve_sdc.recovery.bitwise",
               1.0 if dr["bitwise"] else 0.01)
    ov_sdc = sdc.get("detection_overhead", {})
    if "throughput_ratio" in ov_sdc:
        yield "serve_sdc.overhead.ratio", float(ov_sdc["throughput_ratio"])
    sc = sdc.get("silent_corruption", {})
    if "bitwise" in sc:
        # the threat-model row: corruption with the defense OFF must
        # actually corrupt (bitwise=False is the pass state)
        yield ("serve_sdc.threat.corrupts",
               1.0 if not sc["bitwise"] else 0.01)
    cy = sdc.get("canary_sweep", {})
    if "bitwise" in cy:
        yield ("serve_sdc.canary.bitwise",
               1.0 if (cy["bitwise"]
                       and cy.get("canary_failures", 0) > 0) else 0.01)
    slo_row = sdc.get("corruption_slo", {})
    if slo_row:
        yield ("serve_sdc.slo.shed_typed",
               1.0 if (slo_row.get("poisoned_shed", 0) > 0
                       and slo_row.get("recovered_shed", 1) == 0
                       and slo_row.get("bitwise")) else 0.01)
    # gated: brownout-ladder overload invariants
    # (benchmarks/overload_bench.py) — goodput ratios are deterministic
    # virtual-clock numbers; booleans encode 1.0/0.01 like the chaos rows
    over = doc.get("overload", {}).get("scenarios", {})
    for name, row in sorted(over.items()):
        if not name.startswith("rate_"):
            continue
        rate = name[len("rate_"):]       # "10x"
        if "goodput_vs_capacity" in row:
            yield (f"serve_overload.goodput.r{rate}",
                   float(row["goodput_vs_capacity"]))
        if "interactive_p99_ok" in row:
            yield (f"serve_overload.slo.r{rate}",
                   1.0 if (row["interactive_p99_ok"]
                           and row.get("batch_absorbs")) else 0.01)
    r10 = over.get("rate_10x", {})
    if r10:
        yield ("serve_overload.ladder.downshifts",
               1.0 if r10.get("brownout", {}).get("counters", {})
               .get("downshifts", 0) > 0 else 0.01)
    rec_over = over.get("recovery", {})
    if rec_over:
        yield ("serve_overload.recovery.clean",
               1.0 if (rec_over.get("recovered")
                       and rec_over.get("post_recovery_sheds", 1) == 0)
               else 0.01)
    br = over.get("bitwise_rungs", {})
    if "bitwise" in br:
        yield ("serve_overload.bitwise.rungs",
               1.0 if br["bitwise"] else 0.01)
    co = over.get("chaos_overload", {})
    if "bitwise" in co:
        yield ("serve_overload.bitwise.chaos",
               1.0 if (co["bitwise"] and co.get("all_served")
                       and co.get("typed_sheds", 0) > 0) else 0.01)
    # floor-gated observability metrics (benchmarks/obs_bench.py)
    observ = doc.get("observability", {})
    ov = observ.get("overhead", {})
    if "ratio" in ov:
        yield "obs.overhead.ratio", float(ov["ratio"])
    tc = observ.get("traced_chaos", {})
    if "layers_coverage" in tc:
        yield "obs.attribution.coverage", float(tc["layers_coverage"])


def kernel_metrics(doc: Dict) -> Iterator[Tuple[str, float]]:
    for shape, row in sorted(doc.get("shapes", {}).items()):
        zs, bd = row.get("mode2_zs_s"), row.get("mode2_blockdiag_s")
        if zs and bd:
            yield f"kernels.zs_speedup.{shape}", float(bd) / float(zs)
    layers = doc.get("implicit_conv", {}).get("layers", {})
    for layer, row in sorted(layers.items()):
        v = row.get("implicit_speedup")
        if v:
            yield f"kernels.implicit_speedup.{layer}", float(v)
    q8 = doc.get("quantized_domain", {}).get("layers", {})
    for layer, row in sorted(q8.items()):
        v = row.get("q8_speedup")
        if v:
            yield f"kernels.q8_speedup.{layer}", float(v)
    # analog-noise ablation (benchmarks/noise_ablation.py): design-point
    # noise headroom = budget / measured RMS, floor-gated at 1.0
    noise = doc.get("analog_noise", {})
    design = noise.get("rows", {}).get("b4_br1", {})
    floor = noise.get("floor_lsb_b4_br1")
    if floor and design.get("feasible") and design.get("rms_lsb"):
        yield ("kernels.analog_noise.headroom.b4_br1",
               float(floor) / float(design["rms_lsb"]))


def fps_metrics(doc: Dict) -> Iterator[Tuple[str, float]]:
    """BENCH_fps.json §energy: calibration accuracy + ledger exactness.

    Everything here is a deterministic simulator output (no wall-clock
    jitter), so the 30% family bar only fires on a genuine model change
    that was not re-recorded in the committed baseline.
    """
    energy = doc.get("energy", {})
    for acc, row in sorted(energy.get("calibration", {})
                           .get("accuracy", {}).items()):
        for key in ("fps", "fpsw"):
            if key in row:
                yield f"fps_w.calibration.{acc}.{key}", float(row[key])
    if "ledger_max_rel_err" in energy:
        yield ("fps_w.ledger.exactness",
               1.0 - float(energy["ledger_max_rel_err"]))
    for model, by_obj in sorted(energy.get("objectives", {}).items()):
        lat, edp = by_obj.get("latency", {}), by_obj.get("edp", {})
        if lat.get("edp") and edp.get("edp"):
            # >= 1.0 by construction (candidate selection by true EDP)
            yield (f"fps_w.objective.edp_dominance.{model}",
                   float(lat["edp"]) / float(edp["edp"]))


def collect(bench_dir: Path) -> Dict[str, float]:
    out: Dict[str, float] = {}
    extractors = {"BENCH_serve.json": serve_metrics,
                  "BENCH_kernels.json": kernel_metrics,
                  "BENCH_fps.json": fps_metrics}
    for fname, extract in extractors.items():
        path = bench_dir / fname
        if not path.exists():
            print(f"check_bench: {path} missing, skipping its metrics")
            continue
        out.update(extract(json.loads(path.read_text())))
    return out


def run_smoke_benches() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO_ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    for cmd in SMOKE_COMMANDS:
        print(f"check_bench: running {' '.join(cmd)}")
        subprocess.run(cmd, cwd=REPO_ROOT, env=env, check=True)


def family(name: str) -> str:
    """Metric family: everything before the member suffix."""
    return name.rsplit(".", 1)[0]


def regressions(baseline: Dict[str, float], fresh: Dict[str, float],
                threshold: float, verbose: bool = True,
                ) -> Dict[str, Tuple[float, int]]:
    """Family-geomean ratios below the bar: {family: (geomean, members)}."""
    ratios: Dict[str, list] = {}
    for name in sorted(baseline):
        if name not in fresh:
            if verbose:
                print(f"check_bench: {name}: only in baseline (skipped)")
            continue
        base, new = baseline[name], fresh[name]
        ratio = new / base if base > 0 else float("inf")
        ratios.setdefault(family(name), []).append(ratio)
        if verbose:
            status = "warn" if ratio < 1.0 - threshold else "ok"
            print(f"check_bench: {name}: baseline={base:.3f} "
                  f"fresh={new:.3f} ratio={ratio:.2f} [{status}]")
    if verbose:
        for name in sorted(set(fresh) - set(baseline)):
            print(f"check_bench: {name}: new metric (no baseline)")
    out: Dict[str, Tuple[float, int]] = {}
    for fam, rs in sorted(ratios.items()):
        gm = math.exp(sum(math.log(max(r, 1e-12)) for r in rs) / len(rs))
        gated = fam.startswith(GATED_FAMILY_PREFIXES)
        status = "ok" if gated else "report-only"
        if gm < 1.0 - threshold and gated:
            status = "REGRESSION"
            out[fam] = (gm, len(rs))
        if verbose:
            print(f"check_bench: family {fam}: geomean_ratio={gm:.2f} "
                  f"over {len(rs)} metric(s) [{status}]")
    return out


def floor_failures(fresh: Dict[str, float], verbose: bool = True,
                   ) -> Dict[str, float]:
    """Fresh metrics below their ABS_FLOORS bar: {metric: value}.

    Unlike ``regressions`` this checks the fresh value against a fixed
    floor, not against the committed baseline — a slow erosion of an
    already-normalized ratio should fail the gate even if each PR's drop
    stays under the drift threshold.  A metric absent from the fresh run
    is reported but never fails (schema evolution must not break CI).
    """
    out: Dict[str, float] = {}
    for name, floor in sorted(ABS_FLOORS.items()):
        value = fresh.get(name)
        if value is None:
            if verbose:
                print(f"check_bench: {name}: absent — floor {floor} "
                      f"not checked")
            continue
        ok = value >= floor
        if verbose:
            print(f"check_bench: {name}: value={value:.4f} "
                  f"floor={floor} [{'ok' if ok else 'BELOW FLOOR'}]")
        if not ok:
            out[name] = value
    return out


def report(failures: Dict[str, Tuple[float, int]], threshold: float,
           n_metrics: int, floored: Dict[str, float]) -> int:
    rc = 0
    if failures:
        print(f"check_bench: FAIL — {len(failures)} metric famil"
              f"{'y' if len(failures) == 1 else 'ies'} regressed more "
              f"than {threshold:.0%}:")
        for fam, (gm, n) in sorted(failures.items()):
            print(f"  {fam}: geomean {gm:.2f}x over {n} metric(s)")
        rc = 1
    if floored:
        print(f"check_bench: FAIL — {len(floored)} metric(s) below "
              f"their absolute floor:")
        for name, value in sorted(floored.items()):
            print(f"  {name}: {value:.4f} < floor {ABS_FLOORS[name]}")
        rc = 1
    if rc == 0:
        print(f"check_bench: PASS — no metric family regressed more than "
              f"{threshold:.0%} ({n_metrics} baseline metrics, "
              f"{len(ABS_FLOORS)} floor-gated)")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max tolerated throughput drop (fraction)")
    ap.add_argument("--no-run", action="store_true",
                    help="compare the current BENCH_*.json in place "
                         "against git HEAD's committed copies")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory(prefix="bench_baseline_") as tmp:
        tmp_dir = Path(tmp)
        if args.no_run:
            # baseline from git HEAD, fresh = working tree as-is
            for fname in BENCH_FILES:
                blob = subprocess.run(
                    ["git", "show", f"HEAD:{fname}"], cwd=REPO_ROOT,
                    capture_output=True, text=True)
                if blob.returncode == 0:
                    (tmp_dir / fname).write_text(blob.stdout)
        else:
            # baseline = committed files on disk, then re-run the benches
            for fname in BENCH_FILES:
                src = REPO_ROOT / fname
                if src.exists():
                    shutil.copy(src, tmp_dir / fname)
            run_smoke_benches()
        baseline = collect(tmp_dir)
        fresh = collect(REPO_ROOT)
        if not baseline:
            print("check_bench: no baseline metrics found — nothing to gate")
            return 0
        failed = regressions(baseline, fresh, args.threshold)
        floored = floor_failures(fresh)
        if (failed or floored) and not args.no_run:
            # confirm before failing the PR: a single interpret-mode round
            # can flake past the bar; a real regression reproduces
            print(f"check_bench: {len(failed)} first-round family "
                  f"regression(s), {len(floored)} floor miss(es) — "
                  f"re-running the smoke benches to confirm")
            run_smoke_benches()
            fresh2 = collect(REPO_ROOT)
            second = regressions(baseline, fresh2, args.threshold,
                                 verbose=False)
            confirmed = {k: second[k] for k in failed if k in second}
            for k in sorted(set(failed) - set(confirmed)):
                print(f"check_bench: family {k}: not reproduced on re-run "
                      f"(first geomean {failed[k][0]:.2f}x) — treated as "
                      f"noise")
            failed = confirmed
            second_floor = floor_failures(fresh2, verbose=False)
            for k in sorted(set(floored) - set(second_floor)):
                print(f"check_bench: {k}: floor miss not reproduced on "
                      f"re-run (first value {floored[k]:.4f}) — treated "
                      f"as noise")
            floored = {k: second_floor[k] for k in floored
                       if k in second_floor}
        if not args.no_run:
            # put the committed baselines back: the gate's bench runs must
            # not leave this host's smoke output in the working tree,
            # where a later `git commit -a` would enshrine it as the
            # baseline every future gate compares against
            for fname in BENCH_FILES:
                snap = tmp_dir / fname
                if snap.exists():
                    shutil.copy(snap, REPO_ROOT / fname)
            print("check_bench: restored committed BENCH_*.json baselines "
                  "to the working tree")
    return report(failed, args.threshold, len(baseline), floored)


if __name__ == "__main__":
    sys.exit(main())
