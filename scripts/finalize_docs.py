"""Inject dry-run/roofline tables + train summary into EXPERIMENTS.md."""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import report  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def main() -> None:
    recs = [r for r in report.load_records() if "variant" not in r
            or r.get("variant") in (None, "baseline")]
    summary = report.summarize(recs)
    dr_table = report.dryrun_table(recs)
    rl_single = report.roofline_table(recs, "16x16")
    rl_multi = report.roofline_table(recs, "2x16x16")

    train_log = os.path.join(ROOT, "experiments", "train_lm100m.log")
    train_summary = ""
    if os.path.exists(train_log):
        steps = [ln for ln in open(train_log) if ln.startswith(("step", "loss"))]
        if steps:
            train_summary = (
                "```\n" + steps[0].strip() + "\n...\n"
                + "".join(steps[-3:]).strip() + "\n```\n"
                "(synthetic uniform tokens: the achievable floor is "
                "ln(32256) = 10.38; the run converges toward it)")

    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- TRAIN_LM_SUMMARY -->", train_summary)
    text = text.replace("<!-- DRYRUN_SUMMARY -->",
                        f"**Result: {summary}.**")
    text = text.replace("<!-- DRYRUN_TABLE -->", dr_table)
    text = text.replace(
        "<!-- ROOFLINE_TABLE -->",
        "### Single pod (16×16)\n\n" + rl_single
        + "\n\n### Multi-pod (2×16×16)\n\n" + rl_multi)
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated:", summary)


if __name__ == "__main__":
    main()
