"""Observability report: inspect the exported trace + metrics artifacts.

Reads the artifacts the obs bench leaves under ``experiments/obs/`` (and
the ``observability`` section of ``BENCH_serve.json``) and prints the
human view of them:

* trace validation — the Chrome trace-event schema check that Perfetto
  runs implicitly, including the dual-clock requirement (events on both
  the host process and the modeled-hardware process);
* an event census per category and per phase, plus the modeled hardware
  occupancy (busy seconds per fleet instance from the hw tracks);
* the per-layer hardware-time hotspot table (top-K layers by attributed
  modeled time, with kind / operating point / share columns);
* with ``--prom``, the metrics snapshot re-rendered as Prometheus text
  exposition via ``MetricsRegistry.from_snapshot`` — exactly what a
  scrape endpoint would serve.

``--check`` turns the report into a gate: any validation failure or a
missing artifact exits nonzero (CI's obs-smoke job runs this after the
bench to prove the committed artifacts stay loadable).

Usage:
    PYTHONPATH=src python scripts/obs_report.py [--trace PATH]
        [--metrics PATH] [--bench PATH] [--top 5] [--prom] [--check]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.obs import (                                       # noqa: E402
    MetricsRegistry, event_census, hw_occupancy, load_trace,
    validate_chrome_trace)

DEFAULT_TRACE = REPO_ROOT / "experiments" / "obs" / "chaos_trace.json"
DEFAULT_METRICS = REPO_ROOT / "experiments" / "obs" / "metrics.json"
DEFAULT_BENCH = REPO_ROOT / "BENCH_serve.json"


def report_trace(path: Path) -> int:
    """Validate + summarize the trace; returns the number of problems."""
    if not path.exists():
        print(f"obs_report: trace missing: {path}")
        return 1
    doc = load_trace(path)
    try:
        n = validate_chrome_trace(doc, require_dual_clock=True)
    except ValueError as exc:
        print(f"obs_report: INVALID trace {path}: {exc}")
        return 1
    print(f"obs_report: trace {path.relative_to(REPO_ROOT)}: {n} events, "
          f"valid dual-clock Perfetto trace")
    census = event_census(doc)
    for cat, count in census.items():
        print(f"  cat {cat:<16} {count:>6} event(s)")
    busy = hw_occupancy(doc)
    for inst, s in busy.items():
        print(f"  hw occupancy {inst:<12} {s * 1e3:9.3f} ms modeled busy")
    if not busy:
        print("obs_report: no modeled-hardware occupancy tracks")
        return 1
    return 0


def report_hotspots(bench_path: Path, top: int) -> int:
    """Print the per-layer hotspot table from the bench's obs section."""
    if not bench_path.exists():
        print(f"obs_report: bench file missing: {bench_path}")
        return 1
    doc = json.loads(bench_path.read_text())
    tc = doc.get("observability", {}).get("traced_chaos", {})
    hotspots = tc.get("top_hotspots")
    if not hotspots:
        print(f"obs_report: no observability.traced_chaos.top_hotspots "
              f"in {bench_path}")
        return 1
    cov = tc.get("layers_coverage")
    print(f"obs_report: per-layer hardware-time hotspots "
          f"(coverage {cov:.4f})" if cov is not None else
          "obs_report: per-layer hardware-time hotspots")
    print(f"  {'layer':<14} {'kind':<5} {'point':<10} "
          f"{'time':>10} {'share':>7}")
    for row in hotspots[:top]:
        t_us = row.get("time_s", 0.0) * 1e6
        print(f"  {row.get('layer', '?'):<14} {row.get('kind', '?'):<5} "
              f"{row.get('point', '?'):<10} {t_us:8.1f}us "
              f"{row.get('share', 0.0):6.1%}")
    return 0


def report_prom(metrics_path: Path) -> int:
    """Re-render the metrics snapshot as Prometheus text exposition."""
    if not metrics_path.exists():
        print(f"obs_report: metrics snapshot missing: {metrics_path}")
        return 1
    snap = json.loads(metrics_path.read_text())
    reg = MetricsRegistry.from_snapshot(snap)
    sys.stdout.write(reg.prometheus_text())
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", type=Path, default=DEFAULT_TRACE)
    ap.add_argument("--metrics", type=Path, default=DEFAULT_METRICS)
    ap.add_argument("--bench", type=Path, default=DEFAULT_BENCH)
    ap.add_argument("--top", type=int, default=5,
                    help="hotspot rows to print")
    ap.add_argument("--prom", action="store_true",
                    help="also dump the Prometheus text exposition")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on any missing/invalid artifact")
    args = ap.parse_args()

    problems = report_trace(args.trace)
    problems += report_hotspots(args.bench, args.top)
    if args.prom:
        problems += report_prom(args.metrics)
    if problems and args.check:
        print(f"obs_report: CHECK FAILED — {problems} problem(s)")
        return 1
    if args.check:
        print("obs_report: CHECK PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
