"""Overload harness: open-loop Poisson load against the brownout ladder.

Drives the continuous-batching serving core (CNNServer with priorities,
deadlines, bounded queues and a BrownoutController) with multi-model
Poisson arrival traces at 1x / 4x / 10x the measured serving capacity,
entirely on a *virtual clock*: service time is the modeled hardware time
of each served batch (core/simulator.simulate at the server's current
operating point), so every number in the table — goodput, shed/expired/
downshift counts, per-class p50/p99 — is deterministic across hosts and
reproducible from the seed.

The brownout ladder under test is the paper's own knob: the nominal
serving point is the power-lean *fixed* (non-reconfigurable) RMAM comb
configuration; under sustained overload the controller walks
stretch_wait -> shed_batch -> downshift, where the downshift retunes the
comb-switch to the reconfigurable RMAM point (~1.8x the modeled FPS on
the paper-scale EfficientNetB7 table for ~35% higher peak device power)
and replans — bitwise-identical outputs, verified per rung in the
``bitwise_rungs`` scenario.

Scenarios (recorded under ``BENCH_serve.json["overload"]`` and gated via
``serve_overload.*`` in scripts/check_bench.py):

* ``rate_1x`` / ``rate_4x`` / ``rate_10x`` — open-loop Poisson at the
  named multiple of capacity; 10x must sustain goodput >= 0.8x capacity
  with interactive p99 inside its SLO while the batch class absorbs the
  shedding.
* ``recovery``      — a 10x overload phase followed by a light tail: the
  ladder must walk back to rung 0 and shed nothing after recovery.
* ``bitwise_rungs`` — every rung's operating point (planner replan
  included) serves bitwise-identical outputs.
* ``chaos_overload`` — PR-6/PR-8 composition: availability faults AND
  value-corrupting SDC fire *during* an overload burst on a sharded
  fleet; every admitted request's output stays bitwise-correct and all
  refusals are typed.

Usage:  PYTHONPATH=src python -m benchmarks.overload_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import serve
from repro.core import simulator as sim
from repro.core.operating_point import OperatingPoint

REPO_ROOT = Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"

MODELS = tuple(serve.SERVING_MODELS)

#: nominal rung-0 point: the power-lean fixed comb configuration
FIXED_POINT = OperatingPoint("RMAM", 1.0, reconfigurable=False)
#: brownout downshift target: the reconfigurable comb-switch point
#: (DEFAULT_LADDER's rung 3) — throughput-optimal at higher peak power
RECONF_POINT = serve.DEFAULT_LADDER[-1].point

INTERACTIVE_DEADLINE_S = 0.5
INTERACTIVE_FRACTION = 1.0 / 3.0
MAX_BATCH = 8
MAX_WAIT_S = 0.02
MAX_QUEUE = 64
AGE_PROMOTE_S = 1.0


class VirtualClock:
    def __init__(self) -> None:
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_service_model(reg: serve.PlanRegistry):
    """Modeled batch service time at the server's *current* point.

    ``(model, batch, point) -> seconds`` through the paper-scale
    simulator tables; memoized per full point (fixed vs reconfigurable
    variants share a label but not a speed).
    """
    memo: Dict[Tuple[str, int, OperatingPoint], float] = {}

    def service_s(model: str, batch: int, point: OperatingPoint) -> float:
        key = (model, batch, point)
        s = memo.get(key)
        if s is None:
            specs = reg.get(model).sim_specs
            rep = sim.simulate(point.to_accelerator(), specs, batch=batch)
            s = batch / rep.fps
            memo[key] = s
        return s

    return service_s


def measured_capacity_fps(service_s, point: OperatingPoint) -> float:
    """Saturated mixed-model throughput at ``point``: full ``MAX_BATCH``
    buckets round-robined across the zoo (exactly what a drained queue
    serves), frames over modeled seconds."""
    frames = wall = 0.0
    for model in MODELS:
        frames += MAX_BATCH
        wall += service_s(model, MAX_BATCH, point)
    return frames / wall


def make_trace(n_requests: int, rate_per_s: float, seed: int,
               t0: float = 0.0) -> List[Tuple[float, str, str]]:
    """Poisson arrivals: (t, model, priority-class) rows from one seed."""
    rng = np.random.default_rng(seed)
    t = t0 + np.cumsum(rng.exponential(1.0 / rate_per_s, size=n_requests))
    rows = []
    for i in range(n_requests):
        model = MODELS[int(rng.integers(len(MODELS)))]
        cls = (serve.INTERACTIVE
               if rng.uniform() < INTERACTIVE_FRACTION else serve.BATCH)
        rows.append((float(t[i]), model, cls))
    return rows


def make_server(reg: serve.PlanRegistry, clock: VirtualClock,
                brownout: Optional[serve.BrownoutController],
                ) -> serve.CNNServer:
    return serve.CNNServer(
        reg, max_batch=MAX_BATCH, max_wait_s=MAX_WAIT_S,
        hw_points=(FIXED_POINT,), time_fn=clock.now,
        slo=serve.ServeSLO(deadline_s=INTERACTIVE_DEADLINE_S),
        continuous=True, max_queue=MAX_QUEUE, age_promote_s=AGE_PROMOTE_S,
        brownout=brownout, service_model=make_service_model(reg))


def replay(srv: serve.CNNServer, clock: VirtualClock,
           trace: List[Tuple[float, str, str]],
           inputs: Dict[str, np.ndarray]) -> Dict:
    """Open-loop replay: arrivals fire at their trace times regardless of
    server state (the defining property of an overload test); the clock
    advances by each served batch's modeled service time."""
    i, n = 0, len(trace)
    sheds: List[Tuple[float, str, str]] = []   # (t, class, kind)
    submitted: Dict[int, str] = {}
    while i < n or srv.pending() > 0:
        while i < n and trace[i][0] <= clock.t + 1e-12:
            _, model, cls = trace[i]
            deadline = (INTERACTIVE_DEADLINE_S
                        if cls == serve.INTERACTIVE else None)
            try:
                rid = srv.submit(model, inputs[model], priority=cls,
                                 deadline_s=deadline)
                submitted[rid] = cls
            except serve.BrownoutShed:
                sheds.append((clock.t, cls, "brownout"))
            except serve.QueueOverflow:
                sheds.append((clock.t, cls, "queue"))
            except serve.AdmissionRejected:
                sheds.append((clock.t, cls, "admission"))
            i += 1
        served = srv.step(force=(i >= n))
        if served:
            clock.advance(srv.telemetry.records[-1].exec_s)
        elif i < n:
            clock.t = max(clock.t, trace[i][0])
        elif srv.pending() == 0:
            break
    expired_by_class = {
        cls: sum(1 for rid, c in submitted.items()
                 if c == cls and rid in srv.failures)
        for cls in serve.PRIORITIES}
    return {"sheds": sheds, "submitted": submitted,
            "expired_by_class": expired_by_class}


def _class_stats(srv: serve.CNNServer, events: Dict) -> Dict[str, Dict]:
    summary = srv.telemetry.summary()
    out: Dict[str, Dict] = {}
    for cls in serve.PRIORITIES:
        row = dict(summary.get("classes", {}).get(cls, {"requests": 0}))
        row["shed"] = sum(1 for _, c, _k in events["sheds"] if c == cls)
        row["expired"] = events["expired_by_class"][cls]
        out[cls] = row
    return out


def overload_scenario(rate_x: float, n_requests: int, seed: int) -> Dict:
    reg = serve.paper_cnn_registry(capacity=3, planner=True)
    clock = VirtualClock()
    brown = serve.BrownoutController(
        queue_high=32, queue_low=4, escalate_dwell_s=0.05,
        recover_cooldown_s=0.5)
    srv = make_server(reg, clock, brown)
    service_s = srv.service_model
    capacity = measured_capacity_fps(service_s, FIXED_POINT)
    rng = np.random.default_rng(seed + 1)
    inputs = {m: rng.normal(size=serve.serving_input_shape(m))
              .astype(np.float32) for m in MODELS}
    trace = make_trace(n_requests, rate_x * capacity, seed)
    events = replay(srv, clock, trace, inputs)
    span = max(clock.t - trace[0][0], 1e-9)
    served = srv.telemetry.summary().get("requests", 0)
    goodput = served / span
    classes = _class_stats(srv, events)
    inter = classes[serve.INTERACTIVE]
    batch = classes[serve.BATCH]
    batch_damage = batch["shed"] + batch["expired"]
    inter_damage = inter["shed"] + inter["expired"]
    row = {
        "rate_x": rate_x,
        "offered": n_requests,
        "served": served,
        "capacity_fps": capacity,
        "goodput_fps": goodput,
        "goodput_vs_capacity": goodput / capacity,
        "interactive_p99_s": inter.get("latency_p99_s"),
        "interactive_p99_ok": (
            inter.get("latency_p99_s") is not None
            and inter["latency_p99_s"] <= 1.5 * INTERACTIVE_DEADLINE_S),
        "batch_absorbs": (batch_damage >= inter_damage),
        "classes": classes,
        "admission": dict(srv.admission),
        "brownout": brown.report(),
        "final_point": {"label": srv.serving_point.label,
                        "reconfigurable":
                            bool(srv.serving_point.reconfigurable)},
    }
    print(f"overload_bench,rate_{rate_x:g}x,served={served}/{n_requests},"
          f"goodput_vs_capacity={row['goodput_vs_capacity']:.2f},"
          f"interactive_p99_s={row['interactive_p99_s']},"
          f"rung={brown.rung.name},downshifts="
          f"{brown.counters['downshifts']}")
    return row


def recovery_scenario(n_requests: int, seed: int) -> Dict:
    """10x overload phase, then a light tail: the ladder must climb, then
    walk back to rung 0 (cooldown-gated) and shed nothing afterwards."""
    reg = serve.paper_cnn_registry(capacity=3, planner=True)
    clock = VirtualClock()
    brown = serve.BrownoutController(
        queue_high=32, queue_low=4, escalate_dwell_s=0.05,
        recover_cooldown_s=0.5)
    srv = make_server(reg, clock, brown)
    capacity = measured_capacity_fps(srv.service_model, FIXED_POINT)
    rng = np.random.default_rng(seed + 1)
    inputs = {m: rng.normal(size=serve.serving_input_shape(m))
              .astype(np.float32) for m in MODELS}
    storm = make_trace(n_requests, 10.0 * capacity, seed)
    tail = make_trace(n_requests, 0.2 * capacity, seed + 7,
                      t0=storm[-1][0] + 1.0)
    events = replay(srv, clock, storm + tail, inputs)
    recoveries = [tr for tr in brown.transitions if tr.dst == 0]
    t_recovered = recoveries[-1].t if recoveries else None
    post_sheds = (sum(1 for t, _c, _k in events["sheds"]
                      if t > t_recovered) if t_recovered is not None
                  else len(events["sheds"]))
    row = {
        "peak_rung": max((tr.dst for tr in brown.transitions), default=0),
        "final_rung": brown.rung_index,
        "recovered": brown.rung_index == 0 and t_recovered is not None,
        "post_recovery_sheds": post_sheds,
        "transitions": [
            {"t": tr.t, "src": brown.rungs[tr.src].name,
             "dst": brown.rungs[tr.dst].name,
             "direction": tr.direction}
            for tr in brown.transitions],
        "brownout": brown.report(),
    }
    print(f"overload_bench,recovery,peak_rung={row['peak_rung']},"
          f"final_rung={row['final_rung']},"
          f"post_recovery_sheds={post_sheds}")
    return row


def bitwise_rungs_scenario(seed: int) -> Dict:
    """Every rung's operating point serves bitwise-identical outputs.

    The registry compiles through the planner, so a rung with a distinct
    point triggers a full replan against its accelerator — the planner's
    contract (packing geometry moves, quantization never does) is what
    makes a mid-traffic downshift invisible to requesters.
    """
    reg = serve.paper_cnn_registry(capacity=3, planner=True)
    clock = VirtualClock()
    srv = make_server(reg, clock, brownout=None)
    rng = np.random.default_rng(seed)
    inputs = {m: rng.normal(size=serve.serving_input_shape(m))
              .astype(np.float32) for m in MODELS}
    points = []
    for rung in serve.DEFAULT_LADDER:
        points.append((rung.name,
                       rung.point if rung.point is not None else FIXED_POINT))
    outs_by_rung: Dict[str, Dict[str, np.ndarray]] = {}
    for name, point in points:
        srv.set_operating_point(point)
        rids = {m: srv.submit(m, inputs[m]) for m in MODELS}
        res = srv.run_until_drained()
        outs_by_rung[name] = {m: res[r] for m, r in rids.items()}
        srv.reset()
    base = outs_by_rung[points[0][0]]
    bitwise = all((outs_by_rung[name][m] == base[m]).all()
                  for name, _ in points for m in MODELS)
    row = {"bitwise": bool(bitwise),
           "rungs": [name for name, _ in points],
           "replans": reg.stats()["replans"]}
    print(f"overload_bench,bitwise_rungs,bitwise={bitwise},"
          f"replans={row['replans']}")
    return row


def chaos_overload_scenario(n: int, seed: int) -> Dict:
    """PR-6/PR-8 composition: faults + SDC during an overload burst.

    A 3-instance fleet with ABFT integrity checking takes a burst far
    past its bounded queue while a crash, a straggler and value-
    corrupting faults fire.  Everything admitted must come back
    bitwise-identical to the healthy single-accelerator run; everything
    refused must be a typed fault.
    """
    model = "shufflenet_mini"
    rng = np.random.default_rng(seed + 1)
    xs = rng.normal(size=(n, *serve.serving_input_shape(model))
                    ).astype(np.float32)
    # healthy oracle
    reg0 = serve.paper_cnn_registry()
    srv0 = serve.CNNServer(reg0, max_batch=4)
    ref_rids = [srv0.submit(model, x) for x in xs]
    ref_out = srv0.run_until_drained()
    reference = [ref_out[r] for r in ref_rids]

    injector = serve.FaultInjector(serve.random_schedule(
        seed, [f"acc{i}" for i in range(3)], n_events=4,
        kinds=(serve.FaultKind.CRASH, serve.FaultKind.STRAGGLE,
               serve.FaultKind.ANALOG_NOISE, serve.FaultKind.ADC_BITFLIP)),
        seed=seed)
    fleet = serve.ShardedDispatcher(
        serve.default_fleet(3), fault_injector=injector,
        deadline_s=2.0, integrity=serve.IntegrityConfig(check_every=1))
    reg = serve.paper_cnn_registry()
    brown = serve.BrownoutController(queue_high=max(4, n // 3),
                                     queue_low=2,
                                     escalate_dwell_s=0.0,
                                     recover_cooldown_s=0.1)
    srv = serve.CNNServer(reg, max_batch=4, max_wait_s=0.0,
                          dispatcher=fleet, continuous=True,
                          max_queue=max(2, n // 2), brownout=brown)
    # open-loop burst, then client-style retry waves: a typed refusal
    # (queue/brownout shed) or a batch lost to exhausted retries gets
    # re-submitted next wave.  Fault windows are finite in dispatch
    # counts, so the waves converge; the contract under test is that
    # every frame EVENTUALLY completes bitwise-correct and every loss
    # along the way was a typed ServingFault.
    rid_to_idx: Dict[int, int] = {}
    typed_sheds = 0
    exec_faults = 0
    outs: Dict[int, np.ndarray] = {}
    lost = list(range(n))
    waves = 0
    while lost and waves < 20:
        if waves:
            # client-style backoff: quarantine readmission probes are on
            # a wall-clock cooldown, so immediate re-drive of a fully
            # quarantined fleet would only exhaust retries again
            time.sleep(0.05 * min(waves, 4))
        waves += 1
        for i in lost:
            cls = serve.INTERACTIVE if i % 3 == 0 else serve.BATCH
            try:
                rid_to_idx[srv.submit(model, xs[i], priority=cls)] = i
            except serve.ServingFault:
                typed_sheds += 1
        try:
            outs = srv.run_until_drained()
        except serve.ServingFault:
            exec_faults += 1
            outs = srv.results
        done_idx = {i for r, i in rid_to_idx.items() if r in outs}
        lost = [i for i in range(n) if i not in done_idx]
    completed = {r: i for r, i in rid_to_idx.items() if r in outs}
    bitwise = (bool(completed)
               and all((outs[r] == reference[i]).all()
                       for r, i in completed.items()))
    fleet.close()
    trips = {k: v for k, v in injector.trips.items() if v}
    row = {
        "offered": n,
        "completed": len({i for i in completed.values()}),
        "waves": waves,
        "lost_after_retries": len(lost),
        "all_served": not lost,
        "typed_sheds": typed_sheds,
        "exec_faults": exec_faults,
        "bitwise": bool(bitwise),
        "fault_trips": trips,
        "sdc_detections": fleet.counters.get("sdc_detections", 0),
        "brownout": brown.report(),
    }
    print(f"overload_bench,chaos_overload,"
          f"completed={row['completed']}/{n} in {waves} waves,"
          f"bitwise={row['bitwise']},typed_sheds={typed_sheds},"
          f"exec_faults={exec_faults},trips={trips},"
          f"sdc_detections={row['sdc_detections']}")
    return row


def run(smoke: bool = True, seed: int = 0) -> Dict:
    # the arrival window must span many service intervals or the ladder
    # has no burst left to act on (one batch is ~0.04 virtual seconds;
    # 400 requests at 10x capacity arrive over ~0.18s ≈ 4-5 steps of
    # climb time) — wall cost stays small, the mini-models execute in ms
    n = 400 if smoke else 1200
    scenarios = {
        "rate_1x": overload_scenario(1.0, n, seed),
        "rate_4x": overload_scenario(4.0, n, seed + 1),
        "rate_10x": overload_scenario(10.0, n, seed + 2),
        "recovery": recovery_scenario(max(60, n // 2), seed + 3),
        "bitwise_rungs": bitwise_rungs_scenario(seed + 4),
        "chaos_overload": chaos_overload_scenario(12 if smoke else 32,
                                                  seed + 5),
    }
    doc = {}
    if OUT_PATH.exists():
        try:
            doc = json.loads(OUT_PATH.read_text())
        except json.JSONDecodeError:
            doc = {}
    doc["overload"] = {
        "smoke": smoke, "seed": seed,
        "ladder": [
            {"rung": i, "name": r.name,
             "max_wait_scale": r.max_wait_scale,
             "admit_batch": r.admit_batch,
             "point": (None if r.point is None else r.point.label),
             "reconfigurable": (None if r.point is None
                                else bool(r.point.reconfigurable))}
            for i, r in enumerate(serve.DEFAULT_LADDER)],
        "scenarios": scenarios,
    }
    OUT_PATH.write_text(json.dumps(doc, indent=2, default=float) + "\n")
    print(f"overload_bench,json,{OUT_PATH}")
    return scenarios


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small overload traces for CI")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
