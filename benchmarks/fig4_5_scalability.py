"""Paper Figs. 4-5: N and received power vs precision x bit rate."""
from repro.core import scalability as sc


def run() -> None:
    for arch in ("MAM", "AMM"):
        for p in sc.sweep(arch):
            print(f"fig4_5,{arch},bits={p.precision_bits},"
                  f"br={p.bit_rate_gbps:g},N={p.max_n},"
                  f"rx_dbm={p.received_power_dbm:.2f}")
